"""Launch-layer tests: mesh construction, input specs, sharding rules,
and a reduced-config end-to-end lowering — run in SUBPROCESSES so the
forced host-device count never leaks into other tests."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 32):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=500)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_production_mesh_shapes():
    out = _run("""
import jax
from repro.launch.mesh import make_production_mesh
# 512 host devices: both meshes must build
m1 = make_production_mesh()
assert dict(m1.shape) == {"data": 16, "model": 16}, m1.shape
m2 = make_production_mesh(multi_pod=True)
assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}, m2.shape
print("ok")
""", devices=512)
    assert "ok" in out


def test_input_specs_cover_all_arch_shape_pairs():
    """input_specs builds (no allocation) for every cell of the matrix."""
    out = _run("""
import jax
from repro.configs import ALL_ARCHS, SHAPES, get_arch, get_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.launch.dryrun import skip_reason
mesh = make_production_mesh()
n = 0
for arch in ALL_ARCHS:
    for shape in SHAPES:
        cfg, shp = get_arch(arch), get_shape(shape)
        if skip_reason(cfg, shp):
            continue
        params, batch = input_specs(cfg, shp, mesh)
        for leaf in jax.tree.leaves(params) + jax.tree.leaves(batch):
            assert hasattr(leaf, "sharding") and leaf.sharding is not None
        n += 1
print("built", n)
""", devices=512)
    assert "built" in out


def test_reduced_e2e_lowering_small_mesh():
    """A reduced arch lowers + compiles on a small (2,2) mesh with the
    production sharding rules — the dry-run pipeline end to end."""
    out = _run("""
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs import get_arch
from repro.models import build_model
from repro.runtime import sharding as sh
from repro.runtime.shardctx import mesh_context
from repro.runtime.steps import make_meta_train_step
mesh = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
cfg = get_arch("mixtral-8x22b").reduced()
model = build_model(cfg)
with mesh_context(mesh):
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    shardings = sh.param_shardings(shapes, mesh)
    params = jax.tree.map(
        lambda s, d: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=d),
        shapes, shardings)
    batch = {
      "tokens": jax.ShapeDtypeStruct((2, 4, 32), jnp.int32,
          sharding=NamedSharding(mesh, P(None, "data", None))),
      "labels": jax.ShapeDtypeStruct((2, 4, 32), jnp.int32,
          sharding=NamedSharding(mesh, P(None, "data", None))),
    }
    step = make_meta_train_step(model)
    compiled = jax.jit(step, donate_argnums=(0,)).lower(params, batch).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list): cost = cost[0]
    assert cost.get("flops", 0) > 0
print("lowered ok")
""", devices=8)
    assert "lowered ok" in out


def test_collective_parser():
    from repro.launch.dryrun import parse_collective_bytes
    hlo = '''
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={}
  %ar = (f32[64]{0}, f32[32]{0}) all-reduce(%a, %b), to_apply=%sum
  %cp = f32[16]{0} collective-permute(%y), source_target_pairs={{0,1}}
  %nothing = f32[4]{0} add(%p, %q)
'''
    by, counts = parse_collective_bytes(hlo)
    assert by["all-gather"] == 8 * 128 * 2
    assert by["all-reduce"] == 64 * 4 + 32 * 4
    assert by["collective-permute"] == 16 * 4
    assert counts["all-gather"] == 1 and counts["all-reduce"] == 1


def test_skip_matrix_documented():
    """Exactly the documented cells skip, all others run."""
    from repro.configs import ALL_ARCHS, SHAPES, get_arch, get_shape
    from repro.launch.dryrun import skip_reason
    skips = {(a, s) for a in ALL_ARCHS for s in SHAPES
             if skip_reason(get_arch(a), get_shape(s))}
    expected = {(a, "long_500k") for a in
                ("tinyllama-1.1b", "glm4-9b", "minicpm-2b", "paligemma-3b",
                 "whisper-tiny")}
    assert skips == expected, skips ^ expected


def test_train_launcher_mesh_flags():
    """--mesh data/--mesh pod run the reduced launcher across forced
    host devices end to end; --devices without --mesh and --mesh pod
    with --buffer-size are parse-time errors."""
    out = _run("""
import json, subprocess, sys, os
base = [sys.executable, "-m", "repro.launch.train", "--arch",
        "tinyllama-1.1b", "--reduced", "--rounds", "2", "--seq", "32",
        "--batch", "8", "--k-inner", "2"]
env = dict(os.environ)
for extra in (["--mesh", "data", "--devices", "4"], ["--mesh", "pod"]):
    r = subprocess.run(base + extra, capture_output=True, text=True,
                       env=env, timeout=400)
    assert r.returncode == 0, r.stderr[-2000:]
    rows = [json.loads(l) for l in r.stdout.splitlines() if l.startswith("{")]
    assert len(rows) == 2 and all("loss" in row for row in rows)
for bad in (["--devices", "2"], ["--mesh", "pod", "--buffer-size", "2"]):
    r = subprocess.run(base + bad, capture_output=True, text=True,
                       env=env, timeout=120)
    assert r.returncode != 0
print("launcher mesh flags ok")
""", devices=4)
    assert "launcher mesh flags ok" in out


def test_train_launcher_engine_strategies():
    """--strategy routes to the engine: a tifed run prints one summary
    row (int8 comm bill, finite eval), and incompatible flag combos are
    parse-time errors, not mid-run crashes."""
    out = _run("""
import json, subprocess, sys, os
base = [sys.executable, "-m", "repro.launch.train", "--strategy", "tifed",
        "--rounds", "4", "--clients", "4"]
env = dict(os.environ)
r = subprocess.run(base, capture_output=True, text=True, env=env,
                   timeout=400)
assert r.returncode == 0, r.stderr[-2000:]
rows = [json.loads(l) for l in r.stdout.splitlines() if l.startswith("{")]
assert len(rows) == 1, r.stdout
row = rows[0]
assert row["strategy"] == "tifed" and row["rounds"] == 4
assert row["query_loss"] == row["query_loss"]      # finite, not NaN
n_params = 1153
assert abs(row["comm_mb"] - 2 * 4 * 4 * n_params / 2 ** 20) < 1e-3
bads = (
    ["--strategy", "tifed", "--arch", "tinyllama-1.1b"],
    ["--strategy", "tifed", "--mesh", "data"],
    ["--strategy", "tifed", "--ckpt-every", "0"],
    ["--strategy", "tifed", "--resume"],            # no --ckpt-dir
    ["--strategy", "transfer", "--buffer-size", "2"],
    ["--strategy", "reptile", "--buffer-size", "2"],   # no --pool-size
    ["--strategy", "reptile", "--availability", "diurnal"],
    ["--strategy", "reptile", "--pool-size", "2", "--clients", "4"],
)
for bad in bads:
    r = subprocess.run([sys.executable, "-m", "repro.launch.train",
                        "--rounds", "2"] + bad, capture_output=True,
                       text=True, env=env, timeout=120)
    assert r.returncode != 0, bad
    assert not r.stdout.strip(), bad            # rejected before running
print("engine strategy launcher ok")
""", devices=2)
    assert "engine strategy launcher ok" in out


def test_train_launcher_engine_ckpt_resume():
    """--ckpt-dir/--resume on the ENGINE path (PR 7): a run leaves
    durable ckpt_*.npz snapshots, and --resume with a larger --rounds
    continues past the original horizon and prints the new summary row."""
    out = _run("""
import json, os, subprocess, sys, tempfile
env = dict(os.environ)
d = tempfile.mkdtemp()
base = [sys.executable, "-m", "repro.launch.train", "--strategy",
        "reptile", "--clients", "2", "--ckpt-dir", d,
        "--ckpt-every", "2"]
r = subprocess.run(base + ["--rounds", "4"], capture_output=True,
                   text=True, env=env, timeout=400)
assert r.returncode == 0, r.stderr[-2000:]
names = sorted(n for n in os.listdir(d) if n.endswith(".npz"))
assert names and names[-1] == "ckpt_00000004.npz", names
r = subprocess.run(base + ["--rounds", "6", "--resume"],
                   capture_output=True, text=True, env=env, timeout=400)
assert r.returncode == 0, r.stderr[-2000:]
rows = [json.loads(l) for l in r.stdout.splitlines() if l.startswith("{")]
assert len(rows) == 1 and rows[0]["rounds"] == 6, r.stdout
names = sorted(n for n in os.listdir(d) if n.endswith(".npz"))
assert names[-1] == "ckpt_00000006.npz", names
print("launcher ckpt resume ok")
""", devices=2)
    assert "launcher ckpt resume ok" in out


def test_pod_client_meta_step():
    """Beyond-paper scale-out: pods as federated clients (shard_map manual
    over 'pod', auto over data/model). alpha=0 must be the identity."""
    out = _run("""
import jax, numpy as np
from jax.sharding import Mesh
from repro.configs import get_arch
from repro.models import build_model
from repro.core.federated import make_pod_client_meta_step
from repro.runtime.shardctx import mesh_context
mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 2, 2),
            ("pod", "data", "model"))
cfg = get_arch("tinyllama-1.1b").reduced()
model = build_model(cfg)
with mesh_context(mesh):
    params = jax.jit(model.init)(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (2, 4, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (2, 4, 32), 0, cfg.vocab_size)}
    step = make_pod_client_meta_step(model, mesh, beta=0.02, alpha=0.5)
    new_phi, metrics = jax.jit(step)(params, batch)
    assert np.isfinite(float(metrics["loss"]))
    step0 = make_pod_client_meta_step(model, mesh, beta=0.02, alpha=0.0)
    same, _ = jax.jit(step0)(params, batch)
    for a, b in zip(jax.tree.leaves(same), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)
print("pod-client ok")
""", devices=8)
    assert "pod-client ok" in out
