"""Behavioural tests for the paper's algorithms (the paper's own claims,
scaled down to test budgets):

- TinyReptile learns an initialization that adapts (Fig. 2/3);
- Reptile does too; FedAVG/transfer do NOT beat them in the meta regime;
- TinyReptile's memory model shows the >= 2x reduction (Table II);
- one online pass == sequence of single-sample SGD steps (Algorithm 1).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import KWS_CONV, SINE_MLP
from repro.core import (evaluate_init, fedavg_train, finetune_online,
                        reptile_train, tinyreptile_train, transfer_train)
from repro.core.fedavg import fedsgd_train
from repro.data import KWSTasks, SineTasks
from repro.metering import algorithm_memory_report
from repro.models.paper_nets import (init_paper_model, paper_model_accuracy,
                                     paper_model_loss, param_count)

LOSS = functools.partial(paper_model_loss, SINE_MLP)
EVAL = dict(num_tasks=6, support=8, k_steps=8, lr=0.02, query=32)


@pytest.fixture(scope="module")
def sine_setup():
    params = init_paper_model(SINE_MLP, jax.random.PRNGKey(0))
    dist = SineTasks()
    base = evaluate_init(LOSS, params, dist, np.random.default_rng(7), **EVAL)
    return params, dist, base


def test_paper_model_sizes():
    assert param_count(init_paper_model(SINE_MLP, jax.random.PRNGKey(0))) == 1153


def test_tinyreptile_learns(sine_setup):
    params, dist, base = sine_setup
    out = tinyreptile_train(LOSS, params, dist, rounds=150, alpha=1.0,
                            beta=0.02, support=32, eval_every=150,
                            eval_kwargs=EVAL, seed=1)
    final = out["history"][-1]["query_loss"]
    assert final < base["query_loss"] * 0.5, (final, base)


def test_reptile_learns_and_tinyreptile_comparable(sine_setup):
    params, dist, base = sine_setup
    ev = dict(EVAL, num_tasks=20)  # sine eval is heavy-tailed in amplitude
    rep = reptile_train(LOSS, params, dist, rounds=1000, alpha=1.0,
                        beta=0.02, support=32, epochs=8, eval_every=1000,
                        eval_kwargs=ev, seed=1)
    tiny = tinyreptile_train(LOSS, params, dist, rounds=1000, alpha=1.0,
                             beta=0.02, support=32, eval_every=1000,
                             eval_kwargs=ev, seed=1)
    r, t = (rep["history"][-1]["query_loss"],
            tiny["history"][-1]["query_loss"])
    assert r < base["query_loss"] * 0.5, (r, base)
    # paper claim: comparable performance (allow 2x band at test budgets)
    assert t < 2.0 * r + 0.2, (t, r)


def test_fedavg_fails_meta_regime(sine_setup):
    """Paper Fig. 2: FedAVG cannot learn a meaningful init for adaptation."""
    params, dist, base = sine_setup
    tiny = tinyreptile_train(LOSS, params, dist, rounds=120, alpha=1.0,
                             beta=0.02, support=32, eval_every=120,
                             eval_kwargs=EVAL, seed=3)
    fed = fedavg_train(LOSS, params, dist, rounds=24, beta=0.02, support=32,
                       epochs=8, clients_per_round=5, eval_every=24,
                       eval_kwargs=EVAL, seed=3)
    assert (tiny["history"][-1]["query_loss"]
            < fed["history"][-1]["query_loss"] * 0.7)


def test_fedsgd_no_better_than_tinyreptile(sine_setup):
    params, dist, _ = sine_setup
    tiny = tinyreptile_train(LOSS, params, dist, rounds=120, alpha=1.0,
                             beta=0.02, support=32, eval_every=120,
                             eval_kwargs=EVAL, seed=4)
    fsgd = fedsgd_train(LOSS, params, dist, rounds=24, beta=0.02, support=32,
                        clients_per_round=5, eval_every=24,
                        eval_kwargs=EVAL, seed=4)
    assert (tiny["history"][-1]["query_loss"]
            <= fsgd["history"][-1]["query_loss"])


def test_transfer_learning_averages_out(sine_setup):
    """Fig. 1: joint training converges toward E[f] ~ 0 — near-zero outputs,
    poor after-finetune loss relative to meta-learned init."""
    params, dist, _ = sine_setup
    out = transfer_train(LOSS, params, dist, rounds=200, beta=0.02,
                         eval_every=200, eval_kwargs=EVAL, seed=5)
    from repro.models.paper_nets import paper_model_apply
    xs = jnp.linspace(-5, 5, 50)[:, None]
    preds = paper_model_apply(SINE_MLP, out["params"], xs)
    assert float(jnp.abs(preds).mean()) < 1.0  # collapsed toward the mean


def test_online_equals_sequential_sgd():
    """Algorithm 1 line 9: the scanned stream IS per-sample SGD."""
    params = init_paper_model(SINE_MLP, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    task = SineTasks().sample_task(rng)
    xs, ys = zip(*task.support_stream(rng, 5))
    xs, ys = jnp.stack(xs), jnp.stack(ys)
    fast, _ = finetune_online(LOSS, params, xs, ys, jnp.float32(0.02))
    slow = params
    for i in range(5):
        g = jax.grad(LOSS)(slow, {"x": xs[i][None], "y": ys[i][None]})
        slow = jax.tree.map(lambda w, gg: w - 0.02 * gg, slow, g)
    for a, b in zip(jax.tree.leaves(fast), jax.tree.leaves(slow)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_memory_model_table2():
    """Table II: >= 2x memory reduction; sine fits the 256 KB Arduino."""
    for cfg in (SINE_MLP, KWS_CONV):
        rep = algorithm_memory_report(cfg, support=32)
        assert rep["reduction_factor"] >= 2.0, rep
    sine = algorithm_memory_report(SINE_MLP, support=32)
    assert sine["fits_arduino_256kb_tinyreptile"]


def test_kws_tasks_learnable():
    """The contributed KWS dataset is a usable meta-learning benchmark:
    TinyReptile beats chance after adaptation."""
    loss = functools.partial(paper_model_loss, KWS_CONV)
    acc = functools.partial(paper_model_accuracy, KWS_CONV)
    params = init_paper_model(KWS_CONV, jax.random.PRNGKey(1))
    dist = KWSTasks()
    out = tinyreptile_train(loss, params, dist, rounds=60, alpha=1.0,
                            beta=0.01, support=16, eval_every=60,
                            eval_kwargs=dict(num_tasks=5, support=8,
                                             k_steps=8, lr=0.01, query=32,
                                             metric_fn=acc), seed=6)
    assert out["history"][-1]["query_metric"] > 0.35  # chance = 0.25


def test_evaluate_init_zero_support(sine_setup):
    """S_test = 0 (paper Fig. 6 leftmost point): evaluation without
    adaptation must work and be worse than S_test = 8."""
    params, dist, _ = sine_setup
    e0 = evaluate_init(LOSS, params, dist, np.random.default_rng(1),
                       num_tasks=4, support=0, k_steps=8, lr=0.02, query=16)
    assert np.isfinite(e0["query_loss"])
