"""Per-architecture smoke tests: REDUCED variant of each assigned family
(2 layers, d_model<=256, <=4 experts) — one forward/train step on CPU,
asserting output shapes and no NaNs. Full configs are exercised only via
the dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, get_arch
from repro.models import build_model
from repro.runtime.steps import make_meta_train_step, microbatch


def _batch_for(cfg, key, B=2, S=32):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_loss(arch):
    cfg = get_arch(arch).reduced()
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    loss = model.loss_fn(params, _batch_for(cfg, key))
    assert loss.shape == ()
    assert jnp.isfinite(loss)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_meta_train_step(arch):
    """One TinyReptile round on the reduced arch: finite loss, params move."""
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    batch = microbatch(_batch_for(cfg, key, B=4), 2)  # K=2 inner steps
    step = make_meta_train_step(model, beta=0.05, alpha=0.7)
    new_params, metrics = jax.jit(step)(params, batch)
    assert jnp.isfinite(metrics["loss"])
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, new_params)
    assert max(jax.tree.leaves(moved)) > 0.0
    for leaf in jax.tree.leaves(new_params):
        assert jnp.isfinite(leaf.astype(jnp.float32)).all()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_step(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    B, S_cache = 2, 64
    cache = model.init_cache(B, S_cache)
    batch = {"tokens": jax.random.randint(key, (B, 1), 0, cfg.vocab_size),
             "cache": cache, "cache_len": jnp.int32(7)}
    logits, new_cache = jax.jit(model.decode_fn)(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    # decoding twice advances the cache consistently
    batch2 = {"tokens": batch["tokens"], "cache": new_cache,
              "cache_len": jnp.int32(8)}
    logits2, _ = jax.jit(model.decode_fn)(params, batch2)
    assert jnp.isfinite(logits2).all()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(3)
    params = model.init(key)
    batch = _batch_for(cfg, key, B=2, S=16)
    del batch["labels"]
    logits = jax.jit(model.prefill_fn)(params, batch)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert jnp.isfinite(logits).all()


def test_param_count_matches_analytic():
    """Analytic param_count tracks the real builders (within embed ties)."""
    import numpy as np
    for arch in ALL_ARCHS:
        cfg = get_arch(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        real = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        est = cfg.param_count()
        assert abs(real - est) / real < 0.25, (arch, real, est)
