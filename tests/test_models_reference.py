"""Model-layer reference tests: flash attention vs naive, decode-vs-prefill
consistency, Mamba2 prefill-vs-decode state equivalence, MoE dispatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import build_model
from repro.models.attention import (apply_rope, decode_attention,
                                    flash_attention)
from repro.models.mamba2 import mamba_block, mamba_decode_block, ssd_chunked
from repro.models.moe import capacity, moe_block, init_moe


def _naive_attention(q, k, v, causal, window=0):
    B, Sq, H, hd = q.shape
    Kv = k.shape[2]
    R = H // Kv
    qg = q.reshape(B, Sq, Kv, R, hd)
    s = jnp.einsum("bqkrh,bskh->bkrqs", qg, k) * hd ** -0.5
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= ki <= qi
    if window:
        mask &= ki > qi - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkrqs,bskh->bkrqh", p, v)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 16),
                                           (False, 0)])
@pytest.mark.parametrize("Sq,Skv", [(64, 64), (33, 65)])
def test_flash_vs_naive(causal, window, Sq, Skv):
    if causal and Sq != Skv:
        pytest.skip("causal assumes aligned self-attention here")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, H, Kv, hd = 2, 4, 2, 32
    q = jax.random.normal(ks[0], (B, Sq, H, hd))
    k = jax.random.normal(ks[1], (B, Skv, Kv, hd))
    v = jax.random.normal(ks[2], (B, Skv, Kv, hd))
    got = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=16, kv_block=16)
    want = _naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_rope_rotation_invariant():
    """RoPE preserves norms and relative-position structure."""
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 64))
    pos = jnp.arange(8)
    r = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(jnp.linalg.norm(r, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # dot products depend only on relative offset
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 64))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 64))
    def dot_at(pq, pk):
        qr = apply_rope(q, jnp.array([pq]), 10000.0)
        kr = apply_rope(k, jnp.array([pk]), 10000.0)
        return float((qr * kr).sum())
    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4


def test_decode_matches_prefill_dense():
    """Greedy decode logits == teacher-forced forward logits (tinyllama)."""
    cfg = get_arch("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    # full forward logits at last position
    from repro.models.transformer import chunked_cross_entropy  # noqa: F401
    x, enc, off = model._embed_inputs(params, {"tokens": tokens})
    h, _ = model._backbone(params, x)
    from repro.models.layers import rms_norm
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    full_logits = h[:, -1] @ model._lm_head(params)
    # decode token-by-token
    cache = model.init_cache(B, 16)
    logits = None
    for t in range(S):
        batch = {"tokens": tokens[:, t:t + 1], "cache": cache,
                 "cache_len": jnp.int32(t)}
        logits, cache = model.decode_fn(params, batch)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits), rtol=5e-3, atol=5e-3)


def test_mamba_decode_matches_prefill():
    """Sequential decode through the Mamba block == chunked prefill."""
    cfg = get_arch("mamba2-130m").reduced()
    d = cfg.d_model
    import repro.models.mamba2 as m2
    params = m2.init_mamba(jax.random.PRNGKey(0), d, cfg.ssm_state,
                           cfg.ssm_head_dim, cfg.ssm_expand,
                           cfg.ssm_conv_width, jnp.float32)
    B, S = 1, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d)) * 0.3
    kw = dict(d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
              expand=cfg.ssm_expand, conv_width=cfg.ssm_conv_width)
    y_full = mamba_block(params, x, chunk=8, **kw)
    d_inner, nheads, conv_dim = m2.mamba_dims(d, cfg.ssm_expand,
                                              cfg.ssm_head_dim, cfg.ssm_state)
    conv_state = jnp.zeros((B, cfg.ssm_conv_width - 1, conv_dim))
    ssm_state = jnp.zeros((B, nheads, cfg.ssm_head_dim, cfg.ssm_state))
    ys = []
    for t in range(S):
        y, conv_state, ssm_state = mamba_decode_block(
            params, x[:, t:t + 1], conv_state, ssm_state, **kw)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_full),
                               rtol=2e-3, atol=2e-3)


def test_ssd_chunk_invariance():
    """SSD output must not depend on the chunk size."""
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    B, S, H, P, N = 2, 64, 4, 32, 16
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.abs(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
    y8, s8 = ssd_chunked(x, dt, A, Bm, Cm, 8)
    y32, s32 = ssd_chunked(x, dt, A, Bm, Cm, 32)
    np.testing.assert_allclose(y8, y32, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s8, s32, rtol=2e-4, atol=2e-4)


def test_moe_top1_routes_all_tokens():
    """With ample capacity every token gets exactly its expert's output."""
    d, f, E = 16, 32, 4
    params = init_moe(jax.random.PRNGKey(0), d, f, E, False, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d))
    y, aux = moe_block(params, x, experts_per_token=1, capacity_factor=4.0)
    # manual: every token through its argmax expert
    logits = x.reshape(-1, d) @ params["router"]
    idx = jnp.argmax(logits, -1)
    def expert_out(e, t):
        h = jax.nn.silu(t @ params["w_gate"][e]) * (t @ params["w_up"][e])
        return h @ params["w_down"][e]
    xf = x.reshape(-1, d)
    want = jnp.stack([expert_out(int(idx[i]), xf[i]) for i in range(16)])
    np.testing.assert_allclose(y.reshape(-1, d), want, rtol=1e-4, atol=1e-4)
    assert aux >= 1.0 - 1e-5  # load-balance loss >= 1 (=1 when uniform)


def test_moe_capacity_drops_overflow():
    """Tokens beyond capacity contribute zero (dropped, not garbage)."""
    d, f, E = 8, 16, 2
    params = init_moe(jax.random.PRNGKey(3), d, f, E, False, jnp.float32)
    # force all tokens to expert 0 (positive inputs x positive column)
    params["router"] = jnp.zeros((d, E)).at[:, 0].set(10.0)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(4), (1, 16, d)))
    y, _ = moe_block(params, x, experts_per_token=1, capacity_factor=0.5)
    C = capacity(16, 1, E, 0.5)
    # at most C tokens nonzero
    nonzero = (jnp.abs(y[0]).sum(-1) > 1e-6).sum()
    assert int(nonzero) <= C


def test_sliding_window_blocks_long_range():
    """With window w, token t must not see tokens < t - w + 1."""
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    B, S, H, Kv, hd, w = 1, 32, 2, 2, 16, 8
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, Kv, hd))
    v = jax.random.normal(ks[2], (B, S, Kv, hd))
    out1 = flash_attention(q, k, v, causal=True, window=w, q_block=8,
                           kv_block=8)
    # perturb k/v far outside the window of the last token
    k2 = k.at[:, :S - w - 4].set(jax.random.normal(ks[0], (B, S - w - 4, Kv, hd)))
    v2 = v.at[:, :S - w - 4].set(0.0)
    out2 = flash_attention(q, k2, v2, causal=True, window=w, q_block=8,
                           kv_block=8)
    np.testing.assert_allclose(out1[:, -1], out2[:, -1], rtol=1e-5, atol=1e-5)
