"""§Perf levers must be numerically equivalent to the baseline paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import flash_attention
from repro.models.moe import init_moe, moe_block
from repro.runtime.flags import feature_scope


@pytest.fixture(scope="module")
def qkv():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, Kv, hd = 2, 64, 8, 2, 32
    return (jax.random.normal(ks[0], (B, S, H, hd)),
            jax.random.normal(ks[1], (B, S, Kv, hd)),
            jax.random.normal(ks[2], (B, S, Kv, hd)))


@pytest.mark.parametrize("flags", [dict(gqa_flat=True), dict(banded=True),
                                   dict(gqa_flat=True, banded=True)])
def test_attention_levers_equivalent(qkv, flags):
    q, k, v = qkv
    base = flash_attention(q, k, v, causal=True, window=16, q_block=16,
                           kv_block=16)
    with feature_scope(**flags):
        opt = flash_attention(q, k, v, causal=True, window=16, q_block=16,
                              kv_block=16)
    np.testing.assert_allclose(base, opt, rtol=2e-4, atol=2e-4)


def test_gqa_flat_full_causal(qkv):
    q, k, v = qkv
    base = flash_attention(q, k, v, causal=True, q_block=16, kv_block=16)
    with feature_scope(gqa_flat=True):
        opt = flash_attention(q, k, v, causal=True, q_block=16, kv_block=16)
    np.testing.assert_allclose(base, opt, rtol=2e-4, atol=2e-4)


def test_moe2d_equivalent():
    p = init_moe(jax.random.PRNGKey(1), 16, 32, 4, False, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 16))
    y0, a0 = moe_block(p, x, experts_per_token=2)
    with feature_scope(moe2d=True):
        y1, a1 = moe_block(p, x, experts_per_token=2)
    np.testing.assert_allclose(y0, y1, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(a0, a1, rtol=1e-6)


def test_banded_matches_probe_path(qkv):
    """banded + probe unrolling (the §Perf measurement path) is exact."""
    from repro.runtime.flags import probe_scope
    q, k, v = qkv
    base = flash_attention(q, k, v, causal=True, window=16, q_block=16,
                           kv_block=16)
    with feature_scope(banded=True), probe_scope(True):
        opt = flash_attention(q, k, v, causal=True, window=16, q_block=16)
    np.testing.assert_allclose(base, opt, rtol=2e-4, atol=2e-4)


def test_ringkv_equivalent_across_wraparound():
    import dataclasses
    from repro.configs import get_arch
    from repro.models import build_model
    cfg = dataclasses.replace(get_arch("mixtral-8x22b").reduced(),
                              sliding_window=16)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 1, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                cfg.vocab_size)

    def run(ring):
        with feature_scope(ringkv=ring):
            cache = model.init_cache(B, 64)
            outs = []
            for t in range(T):
                logits, cache = model.decode_fn(params, {
                    "tokens": tokens[:, t:t + 1], "cache": cache,
                    "cache_len": jnp.int32(t)})
                outs.append(np.asarray(logits))
            return np.concatenate(outs, axis=1)

    np.testing.assert_allclose(run(False), run(True), rtol=2e-3, atol=2e-3)


def test_moelocal_equivalent_groups1():
    p = init_moe(jax.random.PRNGKey(1), 16, 32, 4, False, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 16))
    y0, a0 = moe_block(p, x, experts_per_token=2)
    with feature_scope(moelocal=True):  # no mesh -> single group, identical
        y1, a1 = moe_block(p, x, experts_per_token=2)
    np.testing.assert_allclose(y0, y1, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(a0, a1, rtol=1e-6)


def test_seqpar_equivalent(qkv):
    q, k, v = qkv
    base = flash_attention(q, k, v, causal=True, q_block=16, kv_block=16)
    with feature_scope(seqpar=True):
        opt = flash_attention(q, k, v, causal=True, q_block=16, kv_block=16)
    np.testing.assert_allclose(base, opt, rtol=2e-4, atol=2e-4)
