"""2-D (clients x model) mesh execution of the round engine (PR 10).

Runs in SUBPROCESSES with forced host devices (the test_mesh_engine.py
pattern) so the topology never leaks into the rest of the suite. The
2-D route is GSPMD-only: the GLOBAL block bodies compile against the
mesh with phi committed to the run's ModelPartitioner NamedShardings
(weight matrices split on the model axis, norms/biases replicated) and
the schedule/batch rows sharded over "clients" — no manual shard_map.
Covers the tentpole contracts:

- seeded parity of a small-transformer federated run across mesh=None
  vs a 1-D client mesh vs a 2x2 (clients, model) mesh — training
  trajectory, eval history, and the exact integer transport bills —
  at ONE jit trace per config across uneven eval blocks;
- the memory win the 2-D mesh exists for: analytic per-device
  parameter bytes of model-sharded phi <= 0.6x the replicated 1-D
  layout (the BENCHMARKS.md floor);
- composition with the sine workload, pooled identity state, partial
  participation, and FedBuff buffered aggregation (flat pool-state
  layout under GSPMD);
- the mamba2 ssd_scan Pallas kernel on the client-update hot path
  INSIDE a federated 2-D round (REPRO_OPT_SSD_PALLAS routes the
  prefetcher-thread trace; interpret mode on CPU), with parity
  against the oracle einsum route;
- validation: int8 strategies rejected on model-sharded meshes,
  partitioner= rejected without a 2-D mesh, and partitioner identity
  as part of the runner-cache key.
"""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_PRELUDE = """
import dataclasses, functools
import jax, numpy as np
from repro.configs import get_arch
from repro.configs.paper_models import SINE_MLP
from repro.core import (BufferedAggregation, ClientPool, CommChannel,
                        PartialParticipation, clear_runner_cache,
                        client_mesh, run_federated, runner_cache_stats)
from repro.core.engine import _block_runner
from repro.core.strategies import (ReptileStrategy, TifedStrategy,
                                   TinyReptileStrategy)
from repro.data import LmTaskDistribution, SineTasks, lm_loss
from repro.models import build_model
from repro.models.paper_nets import (init_paper_model, paper_model_loss,
                                     relu_mlp_loss)
from repro.runtime.sharding import (DEFAULT_PARTITIONER, client_model_mesh,
                                    partitioner_for, per_device_param_bytes)

LOSS = functools.partial(paper_model_loss, SINE_MLP)
EVAL = dict(num_tasks=2, support=4, k_steps=2, lr=0.02, query=8)
params = init_paper_model(SINE_MLP, jax.random.PRNGKey(0))
dist = SineTasks()

def assert_close(a, b, tol=3e-4):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=tol, atol=tol)

def tiny_lm(family):
    base = {"transformer": "tinyllama-1.1b",
            "mamba2": "mamba2-130m"}[family]
    cfg = get_arch(base).reduced()
    small = dict(name="tiny-" + family, vocab_size=128, d_model=64)
    if family == "transformer":
        small.update(d_ff=128, num_heads=2, num_kv_heads=2, head_dim=32)
    else:
        small.update(ssm_state=16, ssm_chunk=8)
    return dataclasses.replace(cfg, **small)
"""


def _run(code: str, devices: int = 8, extra_env=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    if extra_env:
        env.update(extra_env)
    r = subprocess.run([sys.executable, "-c", _PRELUDE + code],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=500)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_mesh2d_transformer_parity_and_memory():
    """The headline run: a small transformer meta-trained over
    heterogeneous LM clients agrees seeded across mesh=None, a 1-D
    client mesh, and a 2x2 (clients, model) mesh — params, eval
    history, exact bills — traces ONCE per config, and the 2-D layout
    carries <= 0.6x the per-device parameter bytes of the replicated
    1-D run."""
    out = _run("""
cfg = tiny_lm("transformer")
model = build_model(cfg)
lm = LmTaskDistribution(cfg.vocab_size, 16)
phi = model.init(jax.random.PRNGKey(1))
S = ReptileStrategy(lm_loss(model), epochs=2, use_pallas=None)
LM_EVAL = dict(num_tasks=2, support=4, k_steps=2, lr=0.01, query=4)
kw = dict(rounds=5, beta=0.02, support=3, seed=3, eval_every=2,
          eval_kwargs=LM_EVAL, clients_per_round=3)   # uneven: pads to 4
mesh2d = client_model_mesh(2, 2)
clear_runner_cache()
flat = run_federated(phi, lm, S, **kw)
one_d = run_federated(phi, lm, S, mesh=client_mesh(4), **kw)
two_d = run_federated(phi, lm, S, mesh=mesh2d, **kw)
for other in (one_d, two_d):
    assert_close(flat["params"], other["params"], tol=1e-3)
    assert len(flat["history"]) == len(other["history"])
    for fe, se in zip(flat["history"], other["history"]):
        np.testing.assert_allclose(fe["query_loss"], se["query_loss"],
                                   rtol=1e-3, atol=1e-4)
    assert flat["comm_bytes"] == other["comm_bytes"]
    assert flat["per_client_bytes"] == other["per_client_bytes"]
runner = _block_runner(S, 0.02, CommChannel(), scheduled=True,
                       mesh=mesh2d, masked=False,
                       partitioner=DEFAULT_PARTITIONER)
assert runner.trace_count == 1, runner.trace_count

# the memory contract the 2-D mesh exists for (the BENCHMARKS floor):
# phi's weight matrices split over the model axis, so each device
# holds well under the replicated footprint
two_bytes = per_device_param_bytes(jax.device_put(
    phi, DEFAULT_PARTITIONER.shardings(phi, mesh2d)))
one_bytes = per_device_param_bytes(jax.device_put(phi, jax.devices()[0]))
assert two_bytes <= 0.6 * one_bytes, (two_bytes, one_bytes)
print("transformer 2d parity ok", two_bytes / one_bytes)
""", devices=4)
    assert "transformer 2d parity ok" in out


def test_mesh2d_sine_pooled_composition():
    """The 2-D route composes with the engine's fleet plugins exactly
    like a flat run: pooled identity state, partial participation, and
    FedBuff buffered aggregation all agree with mesh=None — including
    integer pool counters and per-client bills."""
    out = _run("""
S = TinyReptileStrategy(LOSS, use_pallas=None)
mesh2d = client_model_mesh(2, 2)
kw = dict(rounds=11, beta=0.02, support=4, seed=6, eval_every=4,
          eval_kwargs=EVAL, clients_per_round=3)
for case_kw in (dict(),
                dict(sampling=PartialParticipation(0.5)),
                dict(buffered=BufferedAggregation(4))):
    pooled = bool(case_kw)
    pool = lambda: ClientPool(dist, 7) if pooled else None
    flat = run_federated(params, dist, S, pool=pool(), **case_kw, **kw)
    shrd = run_federated(params, dist, S, pool=pool(), mesh=mesh2d,
                         **case_kw, **kw)
    assert_close(flat["params"], shrd["params"])
    assert flat["per_client_bytes"] == shrd["per_client_bytes"]
    assert flat["comm_bytes"] == shrd["comm_bytes"]
    if pooled:
        for k in ("last_seen", "staleness", "checkins"):
            np.testing.assert_array_equal(flat["pool_state"][k],
                                          shrd["pool_state"][k])
    if "buffered" in case_kw:
        assert (flat["pool_state"]["flushes"]
                == shrd["pool_state"]["flushes"])
        assert (flat["pool_state"]["buffered_pending"]
                == shrd["pool_state"]["buffered_pending"])
print("2d pooled composition ok")
""", devices=4)
    assert "2d pooled composition ok" in out


def test_mesh2d_mamba2_ssd_pallas_route():
    """The Pallas hot path inside a federated 2-D round: with
    REPRO_OPT_SSD_PALLAS set (env, not feature_scope — the block traces
    on the prefetcher thread) a mamba2 client update routes through
    kernels.ssd_scan, and the run agrees with the oracle einsum route
    traced before the flag flipped."""
    out = _run("""
import os
import repro.kernels.ssd_scan as ssd_mod
calls = {"n": 0}
orig = ssd_mod.ssd_scan
def counting(*a, **k):
    calls["n"] += 1
    return orig(*a, **k)
ssd_mod.ssd_scan = counting

cfg = tiny_lm("mamba2")
model = build_model(cfg)
lm = LmTaskDistribution(cfg.vocab_size, 16)
phi = model.init(jax.random.PRNGKey(2))
S = ReptileStrategy(lm_loss(model), epochs=2, use_pallas=None)
kw = dict(rounds=3, beta=0.02, support=2, seed=4, clients_per_round=2)
oracle = run_federated(phi, lm, S, **kw)
assert calls["n"] == 0                       # flag off: einsum oracle
os.environ["REPRO_OPT_SSD_PALLAS"] = "1"
# the inner finetune jit caches its jaxpr by shape — drop it so the
# 2-D trace re-reads the feature flag and takes the kernel route
jax.clear_caches()
clear_runner_cache()
shrd = run_federated(phi, lm, S, mesh=client_model_mesh(2, 2), **kw)
assert calls["n"] > 0, calls                 # kernel traced on hot path
assert_close(oracle["params"], shrd["params"], tol=2e-3)
print("mamba2 pallas 2d route ok", calls["n"])
""", devices=4)
    assert "mamba2 pallas 2d route ok" in out


def test_mesh2d_validation_and_cache_identity():
    """Guard rails: int8 uplink strategies cannot run with model-sharded
    phi (per-tensor quantization grids need whole tensors), a
    partitioner without a 2-D mesh is rejected, client_model_mesh
    validates its device budget, and the partitioner is part of the
    runner-cache identity (renamed rules can never be served a stale
    trace)."""
    out = _run("""
import dataclasses as dc
mesh2d = client_model_mesh(2, 2)
kw = dict(rounds=2, beta=0.02, support=4, seed=1, clients_per_round=2)
try:
    run_federated(params, dist, TifedStrategy(relu_mlp_loss, epochs=2),
                  channel=CommChannel("int8", quantize=False),
                  mesh=mesh2d, **dict(kw, beta=0.0))
    raise SystemExit("int8 on model-sharded mesh accepted")
except ValueError as e:
    assert "int8" in str(e)
try:
    run_federated(params, dist, TinyReptileStrategy(LOSS, use_pallas=None),
                  partitioner=DEFAULT_PARTITIONER, **kw)
    raise SystemExit("partitioner without 2-D mesh accepted")
except ValueError as e:
    assert "partitioner" in str(e)
try:
    client_model_mesh(64, 64)
    raise SystemExit("oversized mesh accepted")
except ValueError:
    pass

S = TinyReptileStrategy(LOSS, use_pallas=None)
clear_runner_cache()
r_default = _block_runner(S, 0.05, CommChannel(), scheduled=True,
                          mesh=mesh2d, masked=False,
                          partitioner=DEFAULT_PARTITIONER)
r_renamed = _block_runner(S, 0.05, CommChannel(), scheduled=True,
                          mesh=mesh2d, masked=False,
                          partitioner=dc.replace(DEFAULT_PARTITIONER,
                                                 name="other"))
assert r_default is not r_renamed          # identity keyed by name
assert _block_runner(S, 0.05, CommChannel(), scheduled=True,
                     mesh=mesh2d, masked=False,
                     partitioner=DEFAULT_PARTITIONER) is r_default
assert runner_cache_stats()["mesh_entries"] == 2
print("2d validation ok")
""", devices=4)
    assert "2d validation ok" in out
