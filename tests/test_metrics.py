"""The pluggable MetricsTracker (metering.tracker).

Contracts:

- INERT: attaching a tracker to ``run_federated`` changes NOTHING the
  run computes — params and history are bit-for-bit identical with and
  without it (the per-block loss fetch only happens when a tracker is
  present, so tracker=None also stays fetch-free).
- FAITHFUL: everything the tracker reports is cross-checkable against
  the run's own outputs — per-round inner-loss series vs history rows,
  transport counters vs comm_bytes, staleness observations vs
  pool_state, eval series vs history.
- The summary math (percentiles / histogram) matches NumPy.
- ``profile_dir=`` really arms the JAX profiler (trace files appear).
- The serving hooks agree with the AdaptationServer's own ledger.
"""
import functools
import glob

import jax
import numpy as np
import pytest

from repro.configs.paper_models import SINE_MLP
from repro.core import ClientPool, CommChannel, run_federated
from repro.core.strategies import TinyReptileStrategy
from repro.data import SineTasks
from repro.metering import MetricsTracker
from repro.models.paper_nets import init_paper_model, paper_model_loss

LOSS = functools.partial(paper_model_loss, SINE_MLP)
ROUNDS, EVERY = 12, 4
KW = dict(rounds=ROUNDS, clients_per_round=2, support=6, seed=3,
          eval_every=EVERY,
          eval_kwargs=dict(num_tasks=2, support=4, k_steps=2, lr=0.02,
                           query=8))


def _run(tracker=None):
    phi = init_paper_model(SINE_MLP, jax.random.PRNGKey(0))
    return run_federated(phi, SineTasks(), TinyReptileStrategy(LOSS),
                         channel=CommChannel("float32"),
                         pool=ClientPool(SineTasks(), 5),
                         tracker=tracker, **KW)


@pytest.fixture(scope="module")
def tracked():
    tracker = MetricsTracker()
    return _run(tracker), tracker


def test_tracker_is_bitwise_inert(tracked):
    """tracker=None and tracker=MetricsTracker() produce identical runs:
    params bit-for-bit, history row-for-row."""
    out_t, _ = tracked
    out = _run(tracker=None)
    for a, b in zip(jax.tree.leaves(out["params"]),
                    jax.tree.leaves(out_t["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert len(out["history"]) == len(out_t["history"])
    for ra, rb in zip(out["history"], out_t["history"]):
        assert ra.keys() == rb.keys()
        for k in ra:
            assert ra[k] == rb[k], k
    assert out["comm_bytes"] == out_t["comm_bytes"]


def test_round_loss_series_matches_history(tracked):
    """"round.inner_loss" covers every round exactly once, and at each
    eval round equals the history row's inner_loss."""
    out, tr = tracked
    series = tr.series["round.inner_loss"]
    assert [s for s, _ in series] == list(range(ROUNDS))
    by_round = dict(series)
    for row in out["history"]:
        assert by_round[row["round"] - 1] == row["inner_loss"]
    assert tr.counters["engine.rounds"] == ROUNDS
    assert tr.counters["engine.blocks"] >= 1


def test_eval_series_matches_history(tracked):
    out, tr = tracked
    assert tr.series["eval.query_loss"] == [
        (row["round"], float(row["query_loss"])) for row in out["history"]]
    assert tr.counters["engine.evals"] == len(out["history"])
    assert len(out["history"]) == ROUNDS // EVERY


def test_transport_counters_match_comm_bytes(tracked):
    out, tr = tracked
    assert tr.counters["transport.bytes"] == out["comm_bytes"]
    cum = tr.series_values("transport.cum_bytes")
    assert cum[-1] == out["comm_bytes"]
    assert cum == sorted(cum)                       # monotone bill


def test_staleness_observations_match_pool_state(tracked):
    out, tr = tracked
    np.testing.assert_array_equal(
        np.sort(tr.observations["pool.staleness"]),
        np.sort(np.asarray(out["pool_state"]["staleness"], np.float64)))


def test_run_end_gauges(tracked):
    _, tr = tracked
    assert tr.gauges["engine.wall_s"] > 0
    assert any(k.startswith("runner_cache.") for k in tr.gauges)


def test_percentiles_match_numpy():
    tr = MetricsTracker()
    vals = np.random.default_rng(0).normal(size=257)
    for v in vals:
        tr.observe("x", v)
    got = tr.percentiles("x", qs=(50.0, 95.0, 99.0))
    want = np.percentile(vals, [50.0, 95.0, 99.0])
    assert got == {"p50": want[0], "p95": want[1], "p99": want[2]}
    assert tr.percentiles("missing") == {}
    hist = tr.histogram("x", bins=7)
    counts, edges = np.histogram(vals, bins=7)
    assert hist == {"counts": counts.tolist(), "edges": edges.tolist()}
    summ = tr.summary()
    assert summ["distributions"]["x"]["count"] == 257


def test_profile_dir_writes_trace(tmp_path):
    """profile_dir= brackets the region in the JAX profiler and leaves
    trace artifacts on disk."""
    import jax.numpy as jnp
    tr = MetricsTracker(profile_dir=str(tmp_path))
    tr.on_run_start()
    jnp.dot(jnp.ones((32, 32)), jnp.ones((32, 32))).block_until_ready()
    tr.on_run_end()
    files = glob.glob(str(tmp_path / "**" / "*"), recursive=True)
    assert any(f.endswith(".xplane.pb") for f in files), files
    tr.stop_profile()                               # idempotent no-op


def test_serving_hooks_match_server_ledger():
    from repro.serving import AdaptationServer, Fp32Adapter
    phi = init_paper_model(SINE_MLP, jax.random.PRNGKey(0))
    tr = MetricsTracker()
    server = AdaptationServer(phi, Fp32Adapter(loss_fn=LOSS),
                              slots=4, k_max=5, steps_per_tick=2,
                              metrics=tr)
    rng = np.random.default_rng(0)
    n = 9
    for i in range(n):
        sx = rng.uniform(-5, 5, (6, 1)).astype(np.float32)
        qx = rng.uniform(-5, 5, (4, 1)).astype(np.float32)
        server.submit(sx, np.sin(sx, dtype=np.float32),
                      qx, np.sin(qx, dtype=np.float32), 1 + i % 5)
    results = server.drain()
    assert tr.counters["serve.admitted"] == n
    assert tr.counters["serve.retired"] == len(results) == n
    assert tr.counters["serve.ticks"] == server.ticks
    assert sorted(tr.observations["serve.steps"]) == sorted(
        float(r.steps) for r in results)
    pcts = tr.percentiles("serve.latency_ms")
    assert set(pcts) == {"p50", "p95", "p99"}
    assert all(v > 0 for v in pcts.values())
