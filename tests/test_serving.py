"""Serving parity: the continuous-batching AdaptationServer must serve
exactly what the offline adaptation math computes.

Contracts pinned here:

- served request == `serving.offline_adapt` (the independently-jitted
  one-shot vmapped reference at the same slot width) BIT-FOR-BIT —
  params, query loss, and step counts — for the fp32 online-SGD route
  and the int8 TIFeD route, including across slot retire/refill waves
  with adversarial ragged k.
- int8 served params are additionally EXACTLY equal to the engine's
  scalar `TifedStrategy._run_epochs` (integer-valued fp32 arithmetic is
  vmap-width invariant); the fp32 route matches the scalar
  `finetune_online` API to ~1e-6 (vmap changes fp reduction lowering —
  the same contract as the engine's 1-vs-N-device parity).
- the whole serve loop is ONE jit trace per (adapter, slots, shapes)
  config, across refills, resets, and phi swaps.
- a `checkpoint.load_params` phi (from a training checkpoint) serves
  bit-for-bit identically to the in-memory phi.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_params, save_checkpoint
from repro.configs.paper_models import SINE_MLP
from repro.core import run_federated
from repro.core.meta import finetune_online
from repro.core.strategies import (TifedStrategy, tifed_dequantize,
                                   tifed_requantize, TinyReptileStrategy)
from repro.data import SineTasks
from repro.models.paper_nets import (init_paper_model, paper_model_loss,
                                     relu_mlp_loss)
from repro.serving import (AdaptationServer, Fp32Adapter, TifedAdapter,
                           offline_adapt)

LOSS = functools.partial(paper_model_loss, SINE_MLP)


@pytest.fixture(scope="module")
def phi():
    return init_paper_model(SINE_MLP, jax.random.PRNGKey(0))


def make_requests(n, support, query, ks, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        a, b = rng.uniform(0.1, 5.0), rng.uniform(0.0, np.pi)
        sx = rng.uniform(-5, 5, (support, 1)).astype(np.float32)
        qx = rng.uniform(-5, 5, (query, 1)).astype(np.float32)
        reqs.append({"sx": sx, "sy": np.float32(a * np.sin(sx + b)),
                     "qx": qx, "qy": np.float32(a * np.sin(qx + b)),
                     "k": ks[i % len(ks)]})
    return reqs


def serve_all(server, reqs):
    for r in reqs:
        server.submit(r["sx"], r["sy"], r["qx"], r["qy"], r["k"])
    return {res.rid: res for res in server.drain()}


def assert_results_equal(results, offline):
    for i, off in enumerate(offline):
        res = results[i]
        assert res.steps == off["steps"]
        np.testing.assert_array_equal(
            np.float32(res.query_loss), np.float32(off["query_loss"]),
            err_msg=f"request {i}: query loss diverged")
        for leaf in off["params"]:
            np.testing.assert_array_equal(
                res.params[leaf], off["params"][leaf],
                err_msg=f"request {i}: params[{leaf}] diverged")


# -- fp32 route -------------------------------------------------------------

def test_fp32_served_matches_offline_bitwise(phi):
    """Ragged k, 3 refill waves over 4 slots: every request bit-equal
    to the one-shot offline reference at the same width."""
    adapter = Fp32Adapter(loss_fn=LOSS, lr=0.01)
    reqs = make_requests(12, support=10, query=16,
                         ks=(3, 10, 7, 1, 5, 9, 2, 10, 4, 6, 8, 10))
    server = AdaptationServer(phi, adapter, slots=4, k_max=10,
                              steps_per_tick=3, return_params=True)
    results = serve_all(server, reqs)
    offline = offline_adapt(phi, adapter, reqs, slots=4, k_max=10)
    assert len(results) == len(reqs)
    assert_results_equal(results, offline)


def test_fp32_served_matches_scalar_finetune_online(phi):
    """Served adaptation == the paper's scalar finetune_online on the
    request's first k samples, to vmap-lowering tolerance (1e-6)."""
    adapter = Fp32Adapter(loss_fn=LOSS, lr=0.01)
    reqs = make_requests(6, support=10, query=16, ks=(10, 4, 7, 1, 9, 10))
    server = AdaptationServer(phi, adapter, slots=3, k_max=10,
                              steps_per_tick=4, return_params=True)
    results = serve_all(server, reqs)
    for i, r in enumerate(reqs):
        ref, _ = finetune_online(LOSS, phi,
                                 jnp.asarray(r["sx"][:r["k"]]),
                                 jnp.asarray(r["sy"][:r["k"]]),
                                 jnp.float32(0.01))
        for leaf in ref:
            np.testing.assert_allclose(
                results[i].params[leaf], np.asarray(ref[leaf]),
                rtol=1e-6, atol=1e-6,
                err_msg=f"request {i}: params[{leaf}]")


def test_single_trace_across_refills(phi):
    """One jit trace covers admission, ragged advancing, retirement,
    refills, a reset, AND a second full stream."""
    adapter = Fp32Adapter(loss_fn=LOSS, lr=0.01)
    server = AdaptationServer(phi, adapter, slots=4, k_max=8,
                              steps_per_tick=2)
    reqs = make_requests(24, support=8, query=8,
                         ks=(8, 1, 5, 3, 7, 2, 8, 4))
    out1 = serve_all(server, reqs)
    assert len(out1) == 24
    assert server.trace_count == 1
    server.reset()
    out2 = serve_all(server, reqs)
    assert len(out2) == 24
    assert server.trace_count == 1


def test_ckpt_loaded_phi_serves_identically(phi, tmp_path):
    """phi restored via checkpoint.load_params (both a bare params
    snapshot and a run_federated round-state checkpoint) serves
    bit-for-bit like the in-memory tree — and the phi swap reuses the
    jit trace."""
    adapter = Fp32Adapter(loss_fn=LOSS, lr=0.01)
    reqs = make_requests(6, support=8, query=8, ks=(8, 3, 5, 1, 7, 8))

    # bare params snapshot
    save_checkpoint(str(tmp_path / "bare"), phi, step=0)
    loaded = load_params(str(tmp_path / "bare"), phi)
    # round-state checkpoint from a real (tiny) training run
    out = run_federated(
        phi, SineTasks(), TinyReptileStrategy(LOSS, use_pallas=False),
        rounds=4, clients_per_round=2, support=8, seed=0,
        ckpt_dir=str(tmp_path / "round"), ckpt_every=2, ckpt_async=False)
    trained = load_params(str(tmp_path / "round"), phi)
    for leaf in phi:
        np.testing.assert_array_equal(loaded[leaf], np.asarray(phi[leaf]))
        np.testing.assert_array_equal(trained[leaf],
                                      np.asarray(out["params"][leaf]))

    server = AdaptationServer(phi, adapter, slots=3, k_max=8,
                              steps_per_tick=3, return_params=True)
    mem = sorted(serve_all(server, reqs).values(), key=lambda r: r.rid)
    server.set_params(loaded)
    via_ckpt = sorted(serve_all(server, reqs).values(),
                      key=lambda r: r.rid)
    assert server.trace_count == 1          # phi swap reuses the trace
    for res, ck in zip(mem, via_ckpt):
        assert ck.query_loss == res.query_loss
        for leaf in res.params:
            np.testing.assert_array_equal(ck.params[leaf],
                                          res.params[leaf])


# -- int8 (TIFeD) route -----------------------------------------------------

def test_tifed_served_matches_offline_bitwise(phi):
    phi_q = tifed_requantize(phi)
    adapter = TifedAdapter(support=8, k_max=6, use_pallas=False)
    reqs = make_requests(10, support=8, query=16,
                         ks=(2, 6, 4, 1, 3, 6, 5, 2, 6, 1), seed=1)
    server = AdaptationServer(phi_q, adapter, slots=4, k_max=6,
                              steps_per_tick=2, return_params=True)
    results = serve_all(server, reqs)
    offline = offline_adapt(phi_q, adapter, reqs, slots=4, k_max=6)
    assert server.trace_count == 1
    assert_results_equal(results, offline)


def test_tifed_served_matches_scalar_engine_epochs(phi):
    """Served int8 params == the engine's scalar TifedStrategy epochs
    EXACTLY (integer arithmetic is batching-invariant); the fp32 query
    eval on those identical params matches to vmap tolerance."""
    phi_q = tifed_requantize(phi)
    adapter = TifedAdapter(support=8, k_max=6, use_pallas=False)
    strat = TifedStrategy(loss_fn=relu_mlp_loss, epochs=6,
                          use_pallas=False)
    reqs = make_requests(6, support=8, query=16, ks=(6, 2, 4, 1, 5, 3),
                         seed=2)
    server = AdaptationServer(phi_q, adapter, slots=3, k_max=6,
                              steps_per_tick=2, return_params=True)
    results = serve_all(server, reqs)
    for i, r in enumerate(reqs):
        out, _ = strat._run_epochs(
            phi_q, {"x": jnp.asarray(r["sx"]), "y": jnp.asarray(r["sy"])},
            jnp.int32(r["k"]))
        ref = tifed_dequantize(jax.tree.map(np.asarray, out))
        for leaf in ref:
            np.testing.assert_array_equal(
                results[i].params[leaf], np.asarray(ref[leaf]),
                err_msg=f"request {i}: params[{leaf}]")
        ql = float(relu_mlp_loss(jax.tree.map(jnp.asarray, ref),
                                 {"x": jnp.asarray(r["qx"]),
                                  "y": jnp.asarray(r["qy"])}))
        np.testing.assert_allclose(results[i].query_loss, ql,
                                   rtol=1e-6, atol=1e-6)


def test_tifed_no_cross_slot_leakage(phi):
    """A request served alone in a width-B server equals the same
    request served inside a full ragged batch, EXACTLY — padded-slot
    masks cannot leak across requests on the integer route."""
    phi_q = tifed_requantize(phi)
    adapter = TifedAdapter(support=8, k_max=6, use_pallas=False)
    reqs = make_requests(8, support=8, query=16,
                         ks=(4, 6, 1, 3, 6, 2, 5, 4), seed=3)
    probe = reqs[0]
    together = AdaptationServer(phi_q, adapter, slots=4, k_max=6,
                                steps_per_tick=2, return_params=True)
    got = serve_all(together, reqs)[0]
    alone = AdaptationServer(phi_q, adapter, slots=4, k_max=6,
                             steps_per_tick=2, return_params=True)
    solo = serve_all(alone, [probe])[0]
    assert solo.query_loss == got.query_loss
    for leaf in solo.params:
        np.testing.assert_array_equal(solo.params[leaf], got.params[leaf])


# -- request validation -----------------------------------------------------

def test_submit_validation(phi):
    adapter = Fp32Adapter(loss_fn=LOSS, lr=0.01)
    server = AdaptationServer(phi, adapter, slots=2, k_max=5,
                              steps_per_tick=2)
    r = make_requests(1, support=5, query=4, ks=(5,))[0]
    with pytest.raises(ValueError, match="outside"):
        server.submit(r["sx"], r["sy"], r["qx"], r["qy"], k=6)
    with pytest.raises(ValueError, match="outside"):
        server.submit(r["sx"], r["sy"], r["qx"], r["qy"], k=0)
    server.submit(r["sx"], r["sy"], r["qx"], r["qy"], k=5)
    server.drain()
    bad = make_requests(1, support=7, query=4, ks=(5,))[0]
    with pytest.raises(ValueError, match="shape"):
        server.submit(bad["sx"], bad["sy"], bad["qx"], bad["qy"], k=5)
    with pytest.raises(RuntimeError, match="in flight"):
        server.submit(r["sx"], r["sy"], r["qx"], r["qy"], k=5)
        server.set_params(phi)


def test_constructor_validation(phi):
    adapter = Fp32Adapter(loss_fn=LOSS, lr=0.01)
    with pytest.raises(ValueError, match="slots"):
        AdaptationServer(phi, adapter, slots=0, k_max=5)
    with pytest.raises(ValueError, match="k_max"):
        AdaptationServer(phi, adapter, slots=2, k_max=0)
    with pytest.raises(ValueError, match="steps_per_tick"):
        AdaptationServer(phi, adapter, slots=2, k_max=5, steps_per_tick=0)
