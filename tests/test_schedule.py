"""The ClientSchedule heterogeneity layer (PR 3).

Covers the tentpole contracts:
- SamplingPolicy as a schedule producer: UniformSampling's trivial plan
  (no rng consumed), PartialParticipation cohorts, StragglerSampling
  step draws + arrival weights;
- schedule-driven block sampling (reference loop skips scheduled-out
  rng draws; vectorized overrides zero scheduled-out slots);
- the scheduled scan body: trivial schedules match the uniform fast
  path, one jit trace per schedule-shape config (no per-round host
  dispatches), masked inner loops degenerate op-for-op at k == budget;
- per-participant transport accounting (comm_bytes + per_client_bytes);
- rotating PartialCommChannel masks: disjoint per-round chunks, full
  coverage within ceil(1/fraction) rounds, full-coverage byte
  accounting over one rotation period;
- the 64-entry runner cache: LRU eviction, miss/unhashable counters,
  clear_runner_cache idempotence.
"""
import copy
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import SINE_MLP
from repro.core import (CommChannel, PartialCommChannel,
                        PartialParticipation, StragglerSampling,
                        UniformSampling, clear_runner_cache, fedavg_train,
                        fedsgd_train, reptile_train, run_federated,
                        runner_cache_stats, tinyreptile_train,
                        transfer_train)
from repro.core import engine as engine_mod
from repro.core.engine import _block_runner
from repro.core.meta import (finetune_batch, finetune_batch_masked,
                             finetune_online, finetune_online_masked,
                             tree_bytes)
from repro.core.strategies import (FedAvgStrategy, FedSGDStrategy,
                                   ReptileStrategy, TinyReptileStrategy,
                                   TransferStrategy)
from repro.data import SineTasks
from repro.models.paper_nets import init_paper_model, paper_model_loss

LOSS = functools.partial(paper_model_loss, SINE_MLP)
EVAL = dict(num_tasks=2, support=4, k_steps=2, lr=0.02, query=8)


@pytest.fixture(scope="module")
def setup():
    return init_paper_model(SINE_MLP, jax.random.PRNGKey(0)), SineTasks()


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _assert_trees_close(a, b, tol=1e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=tol, atol=tol)


@dataclasses.dataclass(frozen=True)
class TrivialScheduled(UniformSampling):
    """UniformSampling's data order, but routed through the scheduled
    scan body (weighted aggregation with uniform weights, step-masked
    client loops at full budget) — the degeneracy check."""
    schedule_kind = "scheduled"


# ---------------------------------------------------------------------------
# schedule planning
# ---------------------------------------------------------------------------

def test_uniform_plan_is_trivial_and_consumes_no_rng():
    rng = np.random.default_rng(0)
    state_before = copy.deepcopy(rng.bit_generator.state)
    plan = UniformSampling().plan_schedule(rng, 3, 10, clients=4, budget=6)
    assert rng.bit_generator.state == state_before      # no draws
    assert plan["participation"].shape == (7, 4)
    assert plan["participation"].all()
    assert (plan["local_steps"] == 6).all()
    np.testing.assert_allclose(plan["weights"], 0.25)
    assert UniformSampling.schedule_kind == "uniform"


def test_partial_participation_plan():
    policy = PartialParticipation(0.5)
    assert policy.cohort(8) == 4 and policy.cohort(1) == 1
    plan = policy.plan_schedule(np.random.default_rng(1), 0, 20,
                                clients=8, budget=5)
    part = plan["participation"]
    assert part.shape == (20, 8)
    assert (part.sum(axis=1) == 4).all()                # exactly m per round
    # weights: 1/m on participants, 0 elsewhere, normalized per round
    np.testing.assert_allclose(plan["weights"].sum(axis=1), 1.0, rtol=1e-6)
    assert (plan["weights"][part] == 0.25).all()
    assert (plan["weights"][~part] == 0.0).all()
    # scheduled-out slots get zero local steps
    assert (plan["local_steps"][part] == 5).all()
    assert (plan["local_steps"][~part] == 0).all()
    # the rotation varies across rounds (not the same cohort every time)
    assert len({tuple(r) for r in part}) > 1
    for bad in (0.0, 1.5):
        with pytest.raises(ValueError):
            PartialParticipation(bad)
    with pytest.raises(ValueError):
        PartialParticipation(0.5, sampler="bogus")


def test_straggler_plan():
    policy = StragglerSampling(min_steps_frac=0.25)
    plan = policy.plan_schedule(np.random.default_rng(2), 0, 30,
                                clients=6, budget=8)
    steps = plan["local_steps"]
    assert steps.shape == (30, 6)
    assert steps.min() >= 2 and steps.max() <= 8        # ceil(.25*8)=2
    assert len(np.unique(steps)) > 1                    # heterogeneous
    assert plan["participation"].all()                  # everyone shows up
    # arrival-weighted: w_i = k_i / sum k_j
    np.testing.assert_allclose(
        plan["weights"], steps / steps.sum(axis=1, keepdims=True),
        rtol=1e-6)
    with pytest.raises(ValueError):
        StragglerSampling(min_steps_frac=0.0)


# ---------------------------------------------------------------------------
# schedule-driven block sampling
# ---------------------------------------------------------------------------

def test_reference_sampling_skips_scheduled_out_rng_draws():
    """Scheduled-out slots draw NOTHING: sampling rounds r with a mask
    equals sampling only the participating slots in the same rng order."""
    dist = SineTasks()
    part = np.array([[True, False, True],
                     [False, True, True]])
    got = dist.sample_support_block_reference(
        np.random.default_rng(7), 2, 3, 4, participation=part)
    # replay: same seed, only the participating (round, client) slots
    rng = np.random.default_rng(7)
    want_live = dist.sample_support_block_reference(rng, 1, 1, 4)
    assert got["x"][0, 0].shape == want_live["x"][0, 0].shape
    np.testing.assert_array_equal(got["x"][0, 0], want_live["x"][0, 0])
    # scheduled-out slots are zero
    assert (got["x"][0, 1] == 0).all() and (got["y"][0, 1] == 0).all()
    assert (got["x"][1, 0] == 0).all()
    # an all-True mask consumes the rng identically to no mask
    a = dist.sample_support_block_reference(np.random.default_rng(3), 2, 2, 4)
    b = dist.sample_support_block_reference(
        np.random.default_rng(3), 2, 2, 4,
        participation=np.ones((2, 2), bool))
    np.testing.assert_array_equal(a["x"], b["x"])
    with pytest.raises(ValueError):
        dist.sample_support_block_reference(
            np.random.default_rng(0), 2, 2, 4,
            participation=np.zeros((2, 2), bool))


def test_vectorized_sampling_zeroes_scheduled_out_slots():
    dist = SineTasks()
    part = np.zeros((3, 2), bool)
    part[:, 0] = True
    blk = dist.sample_support_block(np.random.default_rng(5), 3, 2, 4,
                                    participation=part)
    assert (blk["x"][:, 1] == 0).all() and (blk["y"][:, 1] == 0).all()
    assert np.abs(blk["x"][:, 0]).sum() > 0


# ---------------------------------------------------------------------------
# scheduled scan body: trivial-schedule degeneracy + masked inner loops
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("train_fn,kw", [
    (tinyreptile_train, dict(alpha=1.0, support=6)),
    (reptile_train, dict(alpha=1.0, support=6, epochs=3,
                         clients_per_round=3)),
    (fedavg_train, dict(support=6, epochs=3, clients_per_round=3)),
    (fedsgd_train, dict(support=6, clients_per_round=3)),
    (transfer_train, dict(batch_per_round=12, tasks_per_round=3)),
])
def test_trivial_schedule_matches_uniform_path(setup, train_fn, kw):
    """The scheduled body with the trivial schedule (full participation,
    full budget, uniform weights) reproduces the uniform fast path for
    all five strategies — the tentpole's degeneracy criterion."""
    params, dist = setup
    base = dict(rounds=9, beta=0.02, seed=4, eval_every=9, eval_kwargs=EVAL)
    uni = train_fn(LOSS, params, dist, sampling=UniformSampling(), **base,
                   **kw)
    sch = train_fn(LOSS, params, dist, sampling=TrivialScheduled(), **base,
                   **kw)
    _assert_trees_close(uni["params"], sch["params"])
    assert len(uni["history"]) == len(sch["history"])
    for ue, se in zip(uni["history"], sch["history"]):
        assert set(ue) == set(se)
        np.testing.assert_allclose(ue["query_loss"], se["query_loss"],
                                   rtol=1e-4, atol=1e-5)
    if "comm_bytes" in uni:
        assert uni["comm_bytes"] == sch["comm_bytes"]
        assert uni["per_client_bytes"] == sch["per_client_bytes"]


def test_masked_finetune_degenerates_at_full_budget(setup):
    params, dist = setup
    rng = np.random.default_rng(0)
    task = dist.sample_task(rng)
    sup = task.support_batch(rng, 6)
    xs, ys = jnp.asarray(sup["x"]), jnp.asarray(sup["y"])
    lr = jnp.float32(0.02)

    full, full_l = finetune_online(LOSS, params, xs, ys, lr)
    masked, masked_l = finetune_online_masked(LOSS, params, xs, ys, lr,
                                              jnp.int32(6))
    _assert_trees_equal(full, masked)
    np.testing.assert_array_equal(np.asarray(full_l), np.asarray(masked_l))

    fullb, fullb_l = finetune_batch(LOSS, params, sup, 4, lr)
    maskb, maskb_l = finetune_batch_masked(LOSS, params, sup, 4, lr,
                                           jnp.int32(4))
    _assert_trees_equal(fullb, maskb)
    np.testing.assert_array_equal(np.asarray(fullb_l), np.asarray(maskb_l))


def test_masked_finetune_truncates():
    """k < S: params equal the k-step run; dead steps contribute 0 loss.
    k = 0: params pass through untouched."""
    params = init_paper_model(SINE_MLP, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    task = SineTasks().sample_task(rng)
    sup = task.support_batch(rng, 6)
    xs, ys = jnp.asarray(sup["x"]), jnp.asarray(sup["y"])
    lr = jnp.float32(0.02)

    short, short_l = finetune_online(LOSS, params, xs[:2], ys[:2], lr)
    masked, masked_l = finetune_online_masked(LOSS, params, xs, ys, lr,
                                              jnp.int32(2))
    _assert_trees_equal(short, masked)
    np.testing.assert_array_equal(np.asarray(short_l),
                                  np.asarray(masked_l)[:2])
    assert (np.asarray(masked_l)[2:] == 0).all()

    frozen, frozen_l = finetune_online_masked(LOSS, params, xs, ys, lr,
                                              jnp.int32(0))
    _assert_trees_equal(params, frozen)
    assert (np.asarray(frozen_l) == 0).all()


def test_zero_weight_clients_are_inert_even_when_nonfinite():
    """A scheduled-out client whose hook still ran (one-shot strategies
    ignore local_steps) must not poison the round: 0-weight results are
    zeroed before the weighted sum, so even a NaN/inf gradient from a
    zeroed batch leaves phi finite."""
    from repro.core.strategies import weighted_client_mean
    trees = {"w": jnp.asarray([[1.0, 2.0], [jnp.nan, jnp.inf]])}
    got = weighted_client_mean(trees, jnp.asarray([1.0, 0.0]))
    np.testing.assert_array_equal(np.asarray(got["w"]), [1.0, 2.0])


def test_weighted_aggregates_respect_weights(setup):
    params, _ = setup
    C = 3
    models = jax.tree.map(
        lambda p: jnp.stack([p + i for i in range(C)]), params)
    one_hot = jnp.asarray([0.0, 1.0, 0.0])
    picked = FedAvgStrategy(LOSS).server_aggregate_weighted(
        params, models, jnp.float32(1.0), jnp.float32(0.01), one_hot)
    _assert_trees_close(picked, jax.tree.map(lambda p: p + 1, params))
    # Reptile with a one-hot weight interpolates toward that client only
    rep = TinyReptileStrategy(LOSS, use_pallas=False)
    agg = rep.server_aggregate_weighted(
        params, models, jnp.float32(0.5), jnp.float32(0.01), one_hot)
    _assert_trees_close(agg, jax.tree.map(lambda p: p + 0.5, params))
    # FedSGD applies the weighted mean gradient
    g = FedSGDStrategy(LOSS).server_aggregate_weighted(
        params, models, jnp.float32(1.0), jnp.float32(1.0), one_hot)
    _assert_trees_close(g, jax.tree.map(lambda p: p - (p + 1), params),
                        tol=1e-4)


# ---------------------------------------------------------------------------
# per-participant transport accounting
# ---------------------------------------------------------------------------

def test_partial_participation_accounting(setup):
    params, dist = setup
    policy = PartialParticipation(0.5)
    out = reptile_train(LOSS, params, dist, rounds=12, beta=0.02, support=4,
                        epochs=2, clients_per_round=4, seed=0,
                        sampling=policy)
    payload = CommChannel().payload_bytes(params)
    m = policy.cohort(4)
    assert out["comm_bytes"] == 12 * 2 * m * payload    # participants only
    assert sum(out["per_client_bytes"]) == out["comm_bytes"]
    # every slot's bill is a whole number of participated rounds
    for b in out["per_client_bytes"]:
        assert b % (2 * payload) == 0
        assert 0 <= b <= 12 * 2 * payload
    for l in jax.tree.leaves(out["params"]):
        assert np.isfinite(np.asarray(l)).all()


def test_straggler_full_transport_and_training(setup):
    """Stragglers do less local work but still ship full payloads."""
    params, dist = setup
    out = tinyreptile_train(LOSS, params, dist, rounds=10, beta=0.02,
                            support=8, seed=1, clients_per_round=3,
                            sampling=StragglerSampling(0.25),
                            eval_every=10, eval_kwargs=EVAL)
    payload = CommChannel().payload_bytes(params)
    assert out["comm_bytes"] == 10 * 2 * 3 * payload
    assert out["per_client_bytes"] == [10 * 2 * payload] * 3
    assert np.isfinite(out["history"][-1]["query_loss"])


def test_scheduled_runs_trace_once(setup):
    """Straggler/partial runs across uneven eval blocks still compile
    exactly once per (strategy, beta, channel, schedule-shape) config —
    heterogeneity must not reintroduce per-round host dispatches."""
    params, dist = setup
    clear_runner_cache()
    beta = 0.0704                        # unique config -> fresh runner
    kw = dict(rounds=17, beta=beta, support=4, seed=3, eval_every=7,
              eval_kwargs=EVAL, clients_per_round=3)
    tinyreptile_train(LOSS, params, dist,
                      sampling=StragglerSampling(0.25), **kw)
    runner = _block_runner(TinyReptileStrategy(LOSS, use_pallas=None),
                           beta, CommChannel(), scheduled=True)
    assert runner.trace_count == 1
    tinyreptile_train(LOSS, params, dist,
                      sampling=PartialParticipation(0.5), **kw)
    assert runner.trace_count == 1       # same schedule shape: reused
    # the uniform fast path is a DIFFERENT cached runner
    uniform = _block_runner(TinyReptileStrategy(LOSS, use_pallas=None),
                            beta, CommChannel(), scheduled=False)
    assert uniform is not runner


# ---------------------------------------------------------------------------
# rotating partial-communication masks
# ---------------------------------------------------------------------------

def test_sampler_string_conflicts_with_policy_object(setup):
    """run_federated must not silently ignore a non-default sampler=
    string when an explicit sampling= policy (with its own sampler)
    is passed."""
    params, dist = setup
    with pytest.raises(ValueError, match="sampling policy"):
        reptile_train(LOSS, params, dist, rounds=4, beta=0.02, support=4,
                      sampler="vectorized",
                      sampling=PartialParticipation(0.5))
    # default sampler string + policy: fine (the policy's choice wins)
    out = reptile_train(LOSS, params, dist, rounds=4, beta=0.02, support=4,
                        sampling=PartialParticipation(
                            0.5, sampler="vectorized"),
                        clients_per_round=2, seed=0)
    assert np.isfinite(np.asarray(
        jax.tree.leaves(out["params"])[0])).all()


def test_rotating_payload_bytes_reports_chunk_not_fraction():
    """For non-reciprocal fractions the rotating wire carries
    1/ceil(1/fraction) of the entries per round, and payload_bytes must
    agree with the mask (round 0's chunk), not the nominal fraction."""
    ch = PartialCommChannel(fraction=0.4, rotate=True)
    tree = {"w": jnp.zeros((100,), jnp.float32)}
    assert ch.rotation_period == 3
    assert ch.kept_entries(100) == 34                   # ceil(100/3), not 40
    assert ch.payload_bytes(tree) == ch.payload_bytes_at(tree, 0) == 34 * 4
    assert int(np.asarray(
        ch.mask_tree(tree, round_index=0)["w"]).sum()) == 34
    # the fixed-mask accounting is unchanged
    assert PartialCommChannel(fraction=0.4).kept_entries(100) == 40


def test_rotation_period_ceil():
    assert PartialCommChannel(fraction=0.5, rotate=True).rotation_period == 2
    assert PartialCommChannel(fraction=0.25, rotate=True).rotation_period == 4
    # float-noise guard: 1/(1/3) is slightly above 3.0
    assert PartialCommChannel(fraction=1 / 3,
                              rotate=True).rotation_period == 3
    assert PartialCommChannel(fraction=1.0, rotate=True).rotation_period == 1


@pytest.mark.parametrize("fraction,n", [(0.5, 128), (0.25, 10), (0.3, 7)])
def test_rotating_masks_cover_everything_once_per_period(fraction, n):
    """Per-round masks are disjoint chunks that tile every entry exactly
    once per rotation period, and the per-round byte accounting matches
    the mask sizes (full coverage = one whole tree per period)."""
    ch = PartialCommChannel(fraction=fraction, rotate=True)
    tree = {"w": jnp.zeros((n,), jnp.float32)}
    period = ch.rotation_period
    assert period == int(np.ceil(1.0 / fraction - 1e-9))
    seen = np.zeros(n, np.int64)
    total_bytes = 0
    for r in range(period):
        m = np.asarray(ch.mask_tree(tree, round_index=r)["w"])
        assert m.sum() == ch.kept_entries_at(n, r)      # mask == accounting
        seen += m
        total_bytes += ch.payload_bytes_at(tree, r)
    assert (seen == 1).all()                            # exact tiling
    assert total_bytes == tree_bytes(tree)              # one full tree
    # mask sequence repeats with the period
    np.testing.assert_array_equal(
        np.asarray(ch.mask_tree(tree, round_index=0)["w"]),
        np.asarray(ch.mask_tree(tree, round_index=period)["w"]))
    # deterministic in mask_seed, different across rounds
    assert not np.array_equal(
        np.asarray(ch.mask_tree(tree, round_index=0)["w"]),
        np.asarray(ch.mask_tree(tree, round_index=1)["w"]))


def test_rotating_uplink_rotates_the_kept_set():
    r = np.random.default_rng(0)
    ref = {"w": jnp.asarray(r.normal(size=(64,)), jnp.float32)}
    sent = {"w": jnp.asarray(r.normal(size=(64,)), jnp.float32)}
    ch = PartialCommChannel(fraction=0.5, rotate=True)
    got0 = np.asarray(ch.transmit(sent, ref=ref, round_index=0)["w"])
    got1 = np.asarray(ch.transmit(sent, ref=ref, round_index=1)["w"])
    from0 = got0 == np.asarray(sent["w"])
    from1 = got1 == np.asarray(sent["w"])
    assert from0.sum() == ch.kept_entries_at(64, 0)
    assert from1.sum() == ch.kept_entries_at(64, 1)
    assert not (from0 & from1).any()                    # disjoint chunks
    assert (from0 | from1).all()                        # full coverage


def test_rotating_channel_trains_and_meters(setup):
    """End-to-end: the in-scan round index drives the mask; accounting
    bills the round-exact fraction-scaled payload per participant."""
    params, dist = setup
    ch = PartialCommChannel(fraction=0.25, rotate=True)
    rounds = 10
    out = tinyreptile_train(LOSS, params, dist, rounds=rounds, beta=0.02,
                            support=4, seed=1, channel=ch, eval_every=5,
                            eval_kwargs=EVAL)
    want = sum(2 * ch.payload_bytes_at(params, r) for r in range(rounds))
    assert out["comm_bytes"] == want
    assert out["per_client_bytes"] == [want]
    # a full-period slice of the per-round payloads meters a whole tree
    per_period = sum(ch.payload_bytes_at(params, r)
                     for r in range(ch.rotation_period))
    assert per_period == tree_bytes(params)
    for l in jax.tree.leaves(out["params"]):
        assert np.isfinite(np.asarray(l)).all()


def test_rotating_channel_composes_with_schedules(setup):
    """Rotating masks + partial participation: bytes are fraction-scaled
    AND billed only to the round's participants."""
    params, dist = setup
    ch = PartialCommChannel(fraction=0.5, rotate=True)
    policy = PartialParticipation(0.5)
    out = reptile_train(LOSS, params, dist, rounds=8, beta=0.02, support=4,
                        epochs=2, clients_per_round=4, seed=2, channel=ch,
                        sampling=policy)
    m = policy.cohort(4)
    want = sum(2 * m * ch.payload_bytes_at(params, r) for r in range(8))
    assert out["comm_bytes"] == want
    assert sum(out["per_client_bytes"]) == want
    for l in jax.tree.leaves(out["params"]):
        assert np.isfinite(np.asarray(l)).all()


# ---------------------------------------------------------------------------
# the 64-entry runner cache (LRU eviction + counters + clear idempotence)
# ---------------------------------------------------------------------------

def test_runner_cache_lru_eviction():
    """Building runners is cheap (the jit trace happens on first CALL),
    so we can walk straight through the real 64-entry cache."""
    clear_runner_cache()
    strategy = TinyReptileStrategy(LOSS, use_pallas=None)
    channel = CommChannel()
    maxsize = runner_cache_stats()["maxsize"]
    assert maxsize == 64
    betas = [0.001 + 1e-5 * i for i in range(maxsize + 1)]
    runners = [_block_runner(strategy, b, channel) for b in betas]
    stats = runner_cache_stats()
    assert stats["misses"] == maxsize + 1
    assert stats["currsize"] == maxsize                 # one got evicted
    # beta[0] was the least recently used -> evicted: a fresh object
    again0 = _block_runner(strategy, betas[0], channel)
    assert again0 is not runners[0]
    assert runner_cache_stats()["misses"] == maxsize + 2
    # the most recent entry is still cached: identity hit
    hits_before = runner_cache_stats()["hits"]
    assert _block_runner(strategy, betas[-1], channel) is runners[-1]
    assert runner_cache_stats()["hits"] == hits_before + 1
    clear_runner_cache()


def test_runner_cache_unhashable_counter_and_clear_idempotence(caplog):
    clear_runner_cache()

    @dataclasses.dataclass(frozen=True)
    class Unhashable(TinyReptileStrategy):
        junk: list = dataclasses.field(default_factory=list)

    with caplog.at_level("WARNING", logger="repro.core.engine"):
        a = _block_runner(Unhashable(LOSS), 0.02, CommChannel())
        b = _block_runner(Unhashable(LOSS), 0.02, CommChannel())
    assert a is not b                                   # never cached
    stats = runner_cache_stats()
    assert stats["unhashable_misses"] == 2
    assert stats["currsize"] == 0                       # lru untouched
    assert sum("unhashable" in r.message for r in caplog.records) == 2
    # clear is idempotent: calling twice lands in the same zero state
    clear_runner_cache()
    clear_runner_cache()
    stats = runner_cache_stats()
    assert stats == {"hits": 0, "misses": 0, "currsize": 0,
                     "maxsize": 64, "unhashable_misses": 0,
                     "mesh_entries": 0}


def test_runner_cache_accounts_mesh_entries():
    """Mesh-keyed runners are their own cache entries (a sharded trace
    must never serve a flat run or vice versa), are counted by
    runner_cache_stats, and are dropped by clear_runner_cache. The
    cross-topology half (a 4-device and an 8-device mesh never share a
    trace) lives in tests/test_mesh_engine.py, which has the forced
    multi-device process."""
    from repro.core import client_mesh
    clear_runner_cache()
    s = TinyReptileStrategy(LOSS, use_pallas=None)
    mesh = client_mesh(1)
    flat = _block_runner(s, 0.06, CommChannel(), scheduled=True)
    sharded = _block_runner(s, 0.06, CommChannel(), scheduled=True,
                            mesh=mesh)
    assert sharded is not flat
    stats = runner_cache_stats()
    assert stats["currsize"] == 2 and stats["mesh_entries"] == 1
    # an equal mesh (same devices, same axis) hits the same entry:
    # Mesh hashes by topology, not object identity
    again = _block_runner(s, 0.06, CommChannel(), scheduled=True,
                          mesh=client_mesh(1))
    assert again is sharded
    assert runner_cache_stats()["hits"] >= 1
    clear_runner_cache()
    assert runner_cache_stats()["mesh_entries"] == 0


def test_mesh_runner_requires_collective_hook():
    """A custom strategy whose server_aggregate_weighted lacks the
    axis_name parameter gets a plugin-author-facing error at runner
    construction, not a TypeError from inside the trace."""
    from repro.core import client_mesh

    @dataclasses.dataclass(frozen=True)
    class OldHook(TinyReptileStrategy):
        def server_aggregate_weighted(self, phi, client_results, alpha_t,
                                      beta, weights):
            return phi

    with pytest.raises(ValueError, match="axis_name"):
        _block_runner(OldHook(LOSS), 0.05, CommChannel(), scheduled=True,
                      mesh=client_mesh(1))


def test_scheduled_and_uniform_runners_cached_separately():
    clear_runner_cache()
    s = TinyReptileStrategy(LOSS, use_pallas=None)
    u = _block_runner(s, 0.05, CommChannel(), scheduled=False)
    sc = _block_runner(s, 0.05, CommChannel(), scheduled=True)
    assert u is not sc
    assert runner_cache_stats()["misses"] == 2
    assert _block_runner(s, 0.05, CommChannel(), scheduled=True) is sc
    clear_runner_cache()


# ---------------------------------------------------------------------------
# prefetch parity for scheduled runs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", [
    PartialParticipation(0.5),
    StragglerSampling(0.25),
    PartialParticipation(0.5, sampler="vectorized"),
])
def test_scheduled_prefetch_parity(setup, policy):
    """Pipelined and synchronous scheduled runs are bit-for-bit
    identical: plan_schedule + sample_block consume the host rng
    strictly in block order either way."""
    params, dist = setup
    kw = dict(rounds=13, beta=0.02, support=4, seed=6, eval_every=5,
              eval_kwargs=EVAL, clients_per_round=3, epochs=2,
              sampling=policy)
    sync = reptile_train(LOSS, params, dist, prefetch=0, **kw)
    piped = reptile_train(LOSS, params, dist, prefetch=2, **kw)
    _assert_trees_equal(sync["params"], piped["params"])
    assert sync["history"] == piped["history"]
    assert sync["comm_bytes"] == piped["comm_bytes"]
    assert sync["per_client_bytes"] == piped["per_client_bytes"]
