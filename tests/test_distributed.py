"""Two-process jax.distributed execution of the mesh round engine (PR 8).

Launches a REAL two-process run (gloo CPU collectives, one forced host
device per process) against the coordinator on localhost, and pins it
seeded bit-for-bit against the same-topology single-process mesh run:

- reptile on a pooled (vectorized sampler, host-resident slabs) FedBuff
  config — params, eval history, identity state, and the exact integer
  transport bills;
- tifed int8 — params and the exact int8 bill;
- checkpoints in the two-process run are written by process 0 ONLY
  (every process materializes the snapshot collectively, the
  non-coordinators drop it);
- launcher wiring: --coordinator/--num-processes/--process-id flag
  validation at parse time, and a two-process `repro.launch.train`
  run whose summary row matches the single-process one.

Subprocess-isolated like tests/test_mesh_engine.py so the forced device
topology and the distributed runtime never leak into the suite.
"""
import json
import os
import socket
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_WORKER = """
import sys
mode, port, outdir = sys.argv[1], sys.argv[2], sys.argv[3]

import jax
if mode != "solo":
    from repro.runtime.sharding import init_distributed
    init_distributed(f"127.0.0.1:{port}", 2, int(mode))
    assert jax.process_count() == 2
    assert jax.local_device_count() == 1
assert jax.device_count() == 2

import functools, os
import numpy as np
from repro.configs.paper_models import SINE_MLP
from repro.core import (BufferedAggregation, ClientPool, CommChannel,
                        client_mesh, run_federated)
from repro.core.strategies import ReptileStrategy, TifedStrategy
from repro.data import SineTasks
from repro.models.paper_nets import (init_paper_model, paper_model_loss,
                                     relu_mlp_loss)

dist = SineTasks()
params = init_paper_model(SINE_MLP, jax.random.PRNGKey(0))
mesh = client_mesh(2)
EVAL = dict(num_tasks=2, support=4, k_steps=2, lr=0.02, query=8)
rank = 0 if mode == "solo" else int(mode)
ckpt = os.path.join(outdir, f"ckpt_{mode}")

rep = run_federated(
    params, dist, ReptileStrategy(
        functools.partial(paper_model_loss, SINE_MLP), epochs=2),
    rounds=6, clients_per_round=3, beta=0.02, support=4, seed=3,
    eval_every=3, eval_kwargs=EVAL,
    pool=ClientPool(dist, 7, seed=3, sampler="vectorized",
                    residency="host"),
    buffered=BufferedAggregation(4), mesh=mesh,
    ckpt_dir=ckpt, ckpt_every=3)
tif = run_federated(
    params, dist, TifedStrategy(relu_mlp_loss, epochs=2),
    rounds=5, clients_per_round=2, beta=0.0, support=8, seed=3,
    channel=CommChannel("int8", quantize=False), mesh=mesh)

wrote = sorted(os.listdir(ckpt)) if os.path.isdir(ckpt) else []
if rank == 0:
    assert wrote, "process 0 must write round-state snapshots"
    blob = {}
    for name, out in (("rep", rep), ("tif", tif)):
        for j, leaf in enumerate(jax.tree.leaves(out["params"])):
            blob[f"{name}_p{j}"] = np.asarray(leaf)
        blob[f"{name}_bill"] = np.asarray(out["per_client_bytes"])
        blob[f"{name}_comm"] = np.asarray(out["comm_bytes"])
    blob["rep_loss"] = np.asarray(
        [h["query_loss"] for h in rep["history"]])
    for k, v in rep["pool_state"].items():
        blob[f"rep_pool_{k}"] = np.asarray(v)
    np.savez(os.path.join(outdir, f"out_{mode}.npz"), **blob)
else:
    assert not wrote, f"non-coordinator wrote snapshots: {wrote}"
print("DIST_WORKER_OK", mode, flush=True)
"""


def _free_port():
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _env(devices: int):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    return env


def _launch_pair(argv0, argv1, env, timeout=500):
    """Run rank 1 in the background and rank 0 in the foreground; both
    must exit 0 and print their marker."""
    p1 = subprocess.Popen(argv1, stdout=subprocess.PIPE,
                          stderr=subprocess.PIPE, text=True, env=env,
                          cwd=REPO)
    try:
        r0 = subprocess.run(argv0, capture_output=True, text=True,
                            env=env, cwd=REPO, timeout=timeout)
        out1, err1 = p1.communicate(timeout=60)
    finally:
        p1.kill()
    assert r0.returncode == 0, r0.stderr[-3000:]
    assert p1.returncode == 0, err1[-3000:]
    return r0.stdout, out1


@pytest.fixture(scope="module")
def dist_outputs(tmp_path_factory):
    """One two-process run + one single-process mesh run, shared by the
    parity assertions below (cross-process startup dominates runtime)."""
    outdir = str(tmp_path_factory.mktemp("dist"))
    worker = os.path.join(outdir, "worker.py")
    with open(worker, "w") as f:
        f.write(_WORKER)
    port = str(_free_port())
    env = _env(devices=1)
    out0, out1 = _launch_pair(
        [sys.executable, worker, "0", port, outdir],
        [sys.executable, worker, "1", port, outdir], env)
    assert "DIST_WORKER_OK 0" in out0
    assert "DIST_WORKER_OK 1" in out1
    r = subprocess.run([sys.executable, worker, "solo", "0", outdir],
                       capture_output=True, text=True, env=_env(devices=2),
                       cwd=REPO, timeout=500)
    assert r.returncode == 0, r.stderr[-3000:]
    dist_blob = np.load(os.path.join(outdir, "out_0.npz"))
    solo_blob = np.load(os.path.join(outdir, "out_solo.npz"))
    return dist_blob, solo_blob


def test_two_process_parity_reptile_and_tifed(dist_outputs):
    """The two-process run is seeded BIT-FOR-BIT with the same-mesh
    single-process run: params, eval losses, pooled identity state, and
    the exact integer bills, for reptile (pooled fleet-scale config) and
    tifed (int8)."""
    dist_blob, solo_blob = dist_outputs
    assert set(dist_blob.files) == set(solo_blob.files)
    for k in sorted(solo_blob.files):
        np.testing.assert_array_equal(dist_blob[k], solo_blob[k], err_msg=k)


def test_two_process_checkpoint_gating(dist_outputs):
    """Snapshots exist for the coordinator's run only — asserted inside
    the workers (process 1 sees an empty/absent ckpt dir); here we just
    pin that the fixture's assertions ran."""
    dist_blob, _ = dist_outputs
    assert dist_blob["rep_comm"] > 0


def test_launcher_distributed_flag_validation():
    """--coordinator/--num-processes/--process-id combos are rejected at
    parse time (no distributed runtime is started for bad argv)."""
    code = """
from repro.launch.train import parse_args
for argv in (["--strategy", "reptile", "--num-processes", "2"],
             ["--strategy", "reptile", "--coordinator", "h:1"],
             ["--strategy", "reptile", "--coordinator", "h:1",
              "--num-processes", "2", "--process-id", "2"],
             ["--strategy", "tinyreptile", "--arch", "gpt2-125m",
              "--coordinator", "h:1", "--num-processes", "2"],
             ["--strategy", "reptile", "--pool-sampler", "vectorized"],
             ["--strategy", "reptile", "--pool-residency", "host"]):
    try:
        parse_args(argv)
        raise AssertionError(f"accepted {argv}")
    except SystemExit:
        pass
print("validation ok")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=_env(devices=1), cwd=REPO,
                       timeout=120)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "validation ok" in r.stdout


def test_launcher_two_process_run(tmp_path):
    """End-to-end launcher wiring: a two-process `repro.launch.train`
    engine run completes and its summary row (loss, transport) matches
    the single-process --devices 2 run on the same seed."""
    port = str(_free_port())
    base = [sys.executable, "-m", "repro.launch.train", "--strategy",
            "reptile", "--rounds", "4", "--clients", "2", "--pool-size",
            "5", "--pool-sampler", "vectorized", "--pool-residency",
            "host", "--devices", "2", "--seed", "3"]
    dflags = ["--coordinator", f"127.0.0.1:{port}", "--num-processes", "2"]
    out0, out1 = _launch_pair(
        base + dflags + ["--process-id", "0"],
        base + dflags + ["--process-id", "1"], _env(devices=1))
    r = subprocess.run(base, capture_output=True, text=True,
                       env=_env(devices=2), cwd=REPO, timeout=500)
    assert r.returncode == 0, r.stderr[-3000:]
    row_dist = json.loads(out0.strip().splitlines()[-1])
    row_solo = json.loads(r.stdout.strip().splitlines()[-1])
    for k in ("strategy", "rounds", "clients", "query_loss", "comm_mb"):
        assert row_dist[k] == row_solo[k], (k, row_dist, row_solo)
