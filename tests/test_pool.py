"""The ClientPool persistent-identity layer (PR 4).

Covers the tentpole contracts:
- stable identities: materialize_client determinism, per-client data
  streams that depend only on the client's own check-in count;
- pool state round-trip through the scan: the device-side gather/scatter
  of last_seen/staleness/checkins by cohort indices reproduces a host
  replay of the planned schedule exactly, and the billing cross-checks
  (per_client_bytes == 2 * payload * checkins);
- staleness counters under PartialParticipation over a pool;
- BufferedAggregation (FedBuff) flush semantics: flush cadence, phi
  frozen between flushes, flush-every-round degenerating to the
  unbuffered pooled run, staleness discounts favoring fresh updates;
- Markov / diurnal availability statistics + the no-show-round no-op;
- the legacy fast path with pool=None stays bit-for-bit (pinned), and
  pooled runs trace exactly once per config.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import SINE_MLP
from repro.core import (BufferedAggregation, ClientPool, CommChannel,
                        DiurnalAvailability, MarkovAvailability,
                        PartialParticipation, UniformSampling,
                        clear_runner_cache, plan_blocks, reptile_train,
                        run_federated, tinyreptile_train)
from repro.core.engine import _block_runner
from repro.core.pool import PoolState, default_staleness_weight
from repro.core.strategies import TinyReptileStrategy
from repro.data import SineTasks
from repro.models.paper_nets import init_paper_model, paper_model_loss

LOSS = functools.partial(paper_model_loss, SINE_MLP)
EVAL = dict(num_tasks=2, support=4, k_steps=2, lr=0.02, query=8)


@pytest.fixture(scope="module")
def setup():
    return init_paper_model(SINE_MLP, jax.random.PRNGKey(0)), SineTasks()


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _assert_trees_close(a, b, tol=1e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# stable identities
# ---------------------------------------------------------------------------

def test_materialize_client_is_stable():
    dist = SineTasks()
    a = dist.materialize_client(3, seed=7)
    b = dist.materialize_client(3, seed=7)
    r1, r2 = np.random.default_rng(0), np.random.default_rng(0)
    xa, ya = a.make_sample(r1)
    xb, yb = b.make_sample(r2)
    np.testing.assert_array_equal(xa, xb)
    np.testing.assert_array_equal(ya, yb)          # same task both times
    c = dist.materialize_client(4, seed=7)
    xc, yc = c.make_sample(np.random.default_rng(0))
    assert not np.array_equal(ya, yc)              # different client


def test_pool_data_depends_only_on_own_checkins():
    """Client 2's k-th check-in draws the same data whether or not other
    clients were scheduled around it."""
    part_a = np.array([[True, True], [True, True]])
    cohort_a = np.array([[2, 5], [2, 1]], np.int32)
    got_a = ClientPool(SineTasks(), 8, seed=0).sample_cohort_block(
        cohort_a, part_a, support=4)
    part_b = np.array([[True, False], [True, False]])
    cohort_b = np.array([[2, 0], [2, 0]], np.int32)
    got_b = ClientPool(SineTasks(), 8, seed=0).sample_cohort_block(
        cohort_b, part_b, support=4)
    np.testing.assert_array_equal(got_a["x"][0, 0], got_b["x"][0, 0])
    np.testing.assert_array_equal(got_a["x"][1, 0], got_b["x"][1, 0])
    # consecutive check-ins advance the client's private stream
    assert not np.array_equal(got_a["x"][0, 0], got_a["x"][1, 0])
    # scheduled-out slots stay zero
    assert (got_b["x"][:, 1] == 0).all() and (got_b["y"][:, 1] == 0).all()


def test_pool_validation():
    with pytest.raises(ValueError):
        ClientPool(SineTasks(), 0)
    with pytest.raises(IndexError):
        ClientPool(SineTasks(), 4).client_task(4)
    with pytest.raises(ValueError, match="buffer_size"):
        BufferedAggregation(0)
    with pytest.raises(ValueError, match="pool_size"):
        UniformSampling().plan_pool_schedule(
            np.random.default_rng(0), 0, 4, clients=8, budget=2,
            pool_size=4)


# ---------------------------------------------------------------------------
# pool state round-trip through the scan (gather/scatter parity)
# ---------------------------------------------------------------------------

def _replay_pool_state(policy, seed, rounds, eval_every, max_block,
                       clients, budget, pool_size):
    """Host-side replay of the engine's schedule planning: the expected
    last_seen/staleness/checkins the device scan must reproduce."""
    rng = np.random.default_rng(seed)
    last_seen = np.full(pool_size, -1, np.int64)
    staleness = np.zeros(pool_size, np.int64)
    checkins = np.zeros(pool_size, np.int64)
    for start, end in plan_blocks(rounds, eval_every, max_block)[0]:
        plan = policy.plan_pool_schedule(rng, start, end, clients, budget,
                                         pool_size)
        for j, r in enumerate(range(start, end)):
            for c in range(clients):
                if plan["participation"][j, c]:
                    m = plan["cohort"][j, c]
                    staleness[m] = r - last_seen[m]
                    last_seen[m] = r
                    checkins[m] += 1
    return last_seen, staleness, checkins


@pytest.mark.parametrize("policy", [
    UniformSampling(),
    PartialParticipation(0.5),
    DiurnalAvailability(period=5),
])
def test_pool_state_scan_matches_host_replay(setup, policy):
    """The in-scan gather/scatter of per-client state by cohort indices
    is exact: a pure-host replay of the same planned schedule produces
    identical last_seen/staleness/checkins — across uneven eval blocks
    and both prefetch modes."""
    params, dist = setup
    kw = dict(rounds=13, beta=0.02, support=4, seed=6, eval_every=5,
              eval_kwargs=EVAL, clients_per_round=3)
    out = tinyreptile_train(LOSS, params, dist, pool=ClientPool(dist, 7),
                            sampling=policy, **kw)
    want = _replay_pool_state(policy, seed=6, rounds=13, eval_every=5,
                              max_block=512, clients=3,
                              budget=4, pool_size=7)
    got = out["pool_state"]
    np.testing.assert_array_equal(got["last_seen"], want[0])
    np.testing.assert_array_equal(got["staleness"], want[1])
    np.testing.assert_array_equal(got["checkins"], want[2])
    # billing cross-check: every client pays exactly per check-in
    payload = CommChannel().payload_bytes(params)
    np.testing.assert_array_equal(out["per_client_bytes"],
                                  2 * payload * want[2])
    assert out["comm_bytes"] == sum(out["per_client_bytes"])


def test_pooled_prefetch_parity(setup):
    params, dist = setup
    kw = dict(rounds=11, beta=0.02, support=4, seed=2, eval_every=4,
              eval_kwargs=EVAL, clients_per_round=3, epochs=2,
              sampling=PartialParticipation(0.5))
    sync = reptile_train(LOSS, params, dist, prefetch=0,
                         pool=ClientPool(dist, 6), **kw)
    piped = reptile_train(LOSS, params, dist, prefetch=2,
                          pool=ClientPool(dist, 6), **kw)
    _assert_trees_equal(sync["params"], piped["params"])
    assert sync["history"] == piped["history"]
    for k in ("last_seen", "staleness", "checkins"):
        np.testing.assert_array_equal(sync["pool_state"][k],
                                      piped["pool_state"][k])
    assert sync["per_client_bytes"] == piped["per_client_bytes"]


def test_staleness_under_partial_participation(setup):
    """With a 50% check-in fraction over a pool twice the cohort size,
    clients skip rounds: staleness counters exceed 1 and check-ins sum
    to exactly participants-per-round x rounds."""
    params, dist = setup
    policy = PartialParticipation(0.5)
    out = tinyreptile_train(LOSS, params, dist, rounds=16, beta=0.02,
                            support=4, seed=3, clients_per_round=4,
                            sampling=policy, pool=ClientPool(dist, 8))
    ps = out["pool_state"]
    assert ps["checkins"].sum() == 16 * policy.cohort(4)
    assert (ps["last_seen"] < 16).all()
    seen = ps["checkins"] > 0
    assert (ps["staleness"][seen] >= 1).all()
    assert ps["staleness"].max() > 1               # somebody skipped rounds


# ---------------------------------------------------------------------------
# BufferedAggregation (FedBuff) flush semantics
# ---------------------------------------------------------------------------

def test_fedbuff_flush_cadence(setup):
    """Full participation, cohort C, threshold K: arrivals accumulate C
    per round and the buffer flushes every ceil(K/C) rounds."""
    params, dist = setup
    out = tinyreptile_train(LOSS, params, dist, rounds=10, beta=0.02,
                            support=4, seed=0, clients_per_round=3,
                            pool=ClientPool(dist, 6),
                            buffered=BufferedAggregation(4))
    # counts: 3, 6 -> flush, 3, 6 -> flush ... = one flush per 2 rounds
    assert out["pool_state"]["flushes"] == 5
    assert out["pool_state"]["buffered_pending"] == 0
    for l in jax.tree.leaves(out["params"]):
        assert np.isfinite(np.asarray(l)).all()


def test_fedbuff_phi_frozen_until_first_flush(setup):
    """A threshold larger than the run's total arrivals never flushes:
    phi must come back bit-identical to the init (async aggregation
    really is the only write path)."""
    params, dist = setup
    out = tinyreptile_train(LOSS, params, dist, rounds=4, beta=0.02,
                            support=4, seed=0, clients_per_round=2,
                            pool=ClientPool(dist, 4),
                            buffered=BufferedAggregation(100))
    assert out["pool_state"]["flushes"] == 0
    assert out["pool_state"]["buffered_pending"] == 8     # 4 rounds x 2
    _assert_trees_equal(out["params"], params)
    # ... but identity state still advanced (check-ins happened)
    assert out["pool_state"]["checkins"].sum() == 8


def test_fedbuff_flush_every_round_matches_unbuffered(setup):
    """buffer_size == cohort makes every round flush its own arrivals
    with zero staleness -> uniform weights: identical to the unbuffered
    pooled run (the degeneracy criterion for the async path)."""
    params, dist = setup
    kw = dict(rounds=8, beta=0.02, support=4, seed=5, clients_per_round=3,
              eval_every=8, eval_kwargs=EVAL)
    plain = tinyreptile_train(LOSS, params, dist,
                              pool=ClientPool(dist, 6), **kw)
    buff = tinyreptile_train(LOSS, params, dist,
                             pool=ClientPool(dist, 6),
                             buffered=BufferedAggregation(3), **kw)
    assert buff["pool_state"]["flushes"] == 8
    _assert_trees_close(plain["params"], buff["params"])
    np.testing.assert_allclose(plain["history"][-1]["query_loss"],
                               buff["history"][-1]["query_loss"],
                               rtol=1e-4, atol=1e-5)


def test_fedbuff_staleness_discount_weights():
    """The flush's staleness weighting: updates buffered longer ago get
    discounted by staleness_fn and the weights renormalize."""
    w = np.asarray(default_staleness_weight(jnp.asarray([0.0, 3.0])))
    np.testing.assert_allclose(w, [1.0, 0.5])
    # direct scan-level check: two buffered updates, one fresh, one
    # 3 rounds stale -> flush folds them 2/3 : 1/3
    phi = {"w": jnp.zeros((2,), jnp.float32)}
    strat = TinyReptileStrategy(LOSS, use_pallas=False)
    buf = {"w": jnp.asarray([[3.0, 3.0], [6.0, 6.0], [0.0, 0.0]])}
    buf_round = jnp.asarray([4, 1, 0], jnp.int32)   # taus at r=4: 0, 3
    tau = (4 - buf_round).astype(jnp.float32)
    w = default_staleness_weight(tau) * (jnp.arange(3) < 2)
    w = w / w.sum()
    got = strat.server_aggregate_weighted(
        phi, buf, jnp.float32(1.0), jnp.float32(0.01), w)
    np.testing.assert_allclose(np.asarray(got["w"]),
                               [4.0, 4.0], rtol=1e-6)  # 2/3*3 + 1/3*6


def test_fedbuff_flush_staleness_deadline_of_one_degenerates(setup):
    """Availability-aware FedBuff: flush_staleness=1 means no buffered
    update may ever reach staleness 1, i.e. the buffer flushes every
    round that has arrivals (tau = 0 at every flush) — identical to the
    count-based flush-every-round run even with a huge count
    threshold."""
    params, dist = setup
    kw = dict(rounds=8, beta=0.02, support=4, seed=5, clients_per_round=3,
              eval_every=8, eval_kwargs=EVAL)
    by_count = tinyreptile_train(LOSS, params, dist,
                                 pool=ClientPool(dist, 6),
                                 buffered=BufferedAggregation(3), **kw)
    by_deadline = tinyreptile_train(
        LOSS, params, dist, pool=ClientPool(dist, 6),
        buffered=BufferedAggregation(100, flush_staleness=1), **kw)
    assert by_deadline["pool_state"]["flushes"] == 8
    assert by_deadline["pool_state"]["buffered_pending"] == 0
    _assert_trees_close(by_count["params"], by_deadline["params"])
    np.testing.assert_allclose(
        by_count["history"][-1]["query_loss"],
        by_deadline["history"][-1]["query_loss"], rtol=1e-4, atol=1e-5)


def test_fedbuff_flush_staleness_bounds_buffer_age(setup):
    """A count threshold the sparse fleet never reaches still flushes
    under the staleness deadline: with a cohort of 1 and deadline 3,
    the single arrival of round r is held through rounds r+1, r+2 and
    applied before it would turn 3 rounds stale — one flush per 3
    rounds, nothing pending at a multiple-of-3 horizon."""
    params, dist = setup
    out = tinyreptile_train(LOSS, params, dist, rounds=9, beta=0.02,
                            support=4, seed=1, clients_per_round=1,
                            pool=ClientPool(dist, 4),
                            buffered=BufferedAggregation(
                                100, flush_staleness=3))
    assert out["pool_state"]["flushes"] == 3
    assert out["pool_state"]["buffered_pending"] == 0
    # the count-only control never flushes at all
    held = tinyreptile_train(LOSS, params, dist, rounds=9, beta=0.02,
                             support=4, seed=1, clients_per_round=1,
                             pool=ClientPool(dist, 4),
                             buffered=BufferedAggregation(100))
    assert held["pool_state"]["flushes"] == 0
    assert held["pool_state"]["buffered_pending"] == 9
    _assert_trees_equal(held["params"], params)   # phi frozen, no flush


def test_fedbuff_flush_staleness_validation():
    with pytest.raises(ValueError, match="flush_staleness"):
        BufferedAggregation(4, flush_staleness=0)
    with pytest.raises(ValueError, match="flush_staleness"):
        BufferedAggregation(4, flush_staleness=1.5)
    assert BufferedAggregation(4, flush_staleness=2).flush_staleness == 2


def test_fedbuff_validation(setup):
    params, dist = setup
    with pytest.raises(ValueError, match="pool="):
        tinyreptile_train(LOSS, params, dist, rounds=2,
                          buffered=BufferedAggregation(2))
    with pytest.raises(ValueError, match="uplink"):
        from repro.core.strategies import TransferStrategy
        run_federated(params, dist, TransferStrategy(LOSS), rounds=2,
                      clients_per_round=2, pool=ClientPool(dist, 4),
                      buffered=BufferedAggregation(2))
    with pytest.raises(ValueError, match="cohort"):
        tinyreptile_train(LOSS, params, dist, rounds=2,
                          clients_per_round=8, pool=ClientPool(dist, 4))


# ---------------------------------------------------------------------------
# availability processes
# ---------------------------------------------------------------------------

def test_diurnal_availability_statistics():
    proc = DiurnalAvailability(period=10, base=0.5, amplitude=0.45)
    avail = proc.availability(np.random.default_rng(0), 0, 400,
                              pool_size=32)
    rate = avail.mean(axis=1)                       # per-round rate
    peaks = rate[np.arange(400) % 10 == 2]          # sin ~ +0.95 here
    troughs = rate[np.arange(400) % 10 == 7]        # sin ~ -0.95 here
    assert peaks.mean() > 0.8
    assert troughs.mean() < 0.15
    # fleet-wide phase (spread=0): all clients share the same sine
    spread = DiurnalAvailability(period=10, phase_spread=1.0)
    rate_s = spread.availability(np.random.default_rng(0), 0, 400,
                                 pool_size=32).mean(axis=1)
    assert rate_s.std() < rate.std()                # staggered -> flat
    with pytest.raises(ValueError):
        DiurnalAvailability(period=0)


def test_markov_availability_statistics():
    proc = MarkovAvailability(p_on=0.3, p_off=0.15)
    rng = np.random.default_rng(1)
    # called in contiguous blocks, like the engine's producer
    rows = np.concatenate([proc.availability(rng, 0, 300, 16),
                           proc.availability(rng, 300, 600, 16)])
    stationary = 0.3 / 0.45
    np.testing.assert_allclose(rows.mean(), stationary, atol=0.05)
    # sticky chains: consecutive rounds agree far more often than i.i.d.
    agree = (rows[1:] == rows[:-1]).mean()
    iid_agree = stationary ** 2 + (1 - stationary) ** 2
    assert agree > iid_agree + 0.2
    # out-of-order blocks are rejected, fresh runs reset at round 0
    with pytest.raises(RuntimeError, match="contiguous"):
        proc.availability(rng, 900, 920, 16)
    assert proc.availability(np.random.default_rng(9), 0, 5, 16).shape \
        == (5, 16)
    with pytest.raises(ValueError):
        MarkovAvailability(p_on=0.0)


def test_availability_requires_pool(setup):
    params, dist = setup
    with pytest.raises(ValueError, match="PERSISTENT"):
        tinyreptile_train(LOSS, params, dist, rounds=2,
                          sampling=DiurnalAvailability())


def test_no_show_rounds_are_noops(setup):
    """A trough round where nobody checks in: phi and the pool state
    pass through and no transport is billed — without retracing."""
    params, dist = setup

    class NightOnly(DiurnalAvailability):
        def availability(self, rng, start, end, pool_size):
            rows = np.zeros((end - start, pool_size), bool)
            for r, rnd in enumerate(range(start, end)):
                if rnd % 2 == 0:                 # every other round: empty
                    rows[r] = rng.uniform(size=pool_size) < 0.9
            return rows

    out = tinyreptile_train(LOSS, params, dist, rounds=6, beta=0.02,
                            support=4, seed=0, clients_per_round=2,
                            sampling=NightOnly(period=2),
                            pool=ClientPool(dist, 4))
    ps = out["pool_state"]
    assert set(ps["last_seen"]) <= {-1, 0, 2, 4}    # odd rounds idle
    payload = CommChannel().payload_bytes(params)
    assert out["comm_bytes"] == 2 * payload * ps["checkins"].sum()
    for l in jax.tree.leaves(out["params"]):
        assert np.isfinite(np.asarray(l)).all()


# ---------------------------------------------------------------------------
# legacy fast path + single-trace contract
# ---------------------------------------------------------------------------

def test_pool_none_keeps_legacy_fast_path(setup):
    """pool=None runs are byte-identical to the pre-pool engine: the
    uniform policy still routes through the UNSCHEDULED runner (cohort
    threading is dead code XLA drops), and prefetch parity holds."""
    params, dist = setup
    clear_runner_cache()
    beta = 0.0807                       # unique config -> fresh runner
    kw = dict(rounds=9, beta=beta, support=4, seed=4, eval_every=9,
              eval_kwargs=EVAL)
    a = tinyreptile_train(LOSS, params, dist, prefetch=0, **kw)
    b = tinyreptile_train(LOSS, params, dist, prefetch=2, **kw)
    _assert_trees_equal(a["params"], b["params"])
    assert a["history"] == b["history"]
    assert "pool_state" not in a
    runner = _block_runner(TinyReptileStrategy(LOSS, use_pallas=None),
                           beta, CommChannel(), scheduled=False)
    assert runner.trace_count == 1
    clear_runner_cache()


def test_pooled_runs_trace_once(setup):
    """Pooled runs across uneven eval blocks compile exactly once per
    (strategy, beta, channel, schedule-shape, pool-shape) config, and
    the pooled runner is cached separately from the flat scheduled
    runner."""
    params, dist = setup
    clear_runner_cache()
    beta = 0.0909                       # unique config -> fresh runner
    kw = dict(rounds=13, beta=beta, support=4, seed=3, eval_every=5,
              eval_kwargs=EVAL, clients_per_round=3)
    tinyreptile_train(LOSS, params, dist, pool=ClientPool(dist, 6), **kw)
    strat = TinyReptileStrategy(LOSS, use_pallas=None)
    pooled = _block_runner(strat, beta, CommChannel(), scheduled=True,
                           pooled=True, masked=False)
    assert pooled.trace_count == 1
    # buffered configs are their own cached runner, also single-trace
    tinyreptile_train(LOSS, params, dist, pool=ClientPool(dist, 6),
                      buffered=BufferedAggregation(4), **kw)
    buffed = _block_runner(strat, beta, CommChannel(), scheduled=True,
                           pooled=True, buffered=BufferedAggregation(4),
                           masked=False)
    assert buffed is not pooled
    assert buffed.trace_count == 1
    assert pooled.trace_count == 1       # untouched by the buffered run
    flat = _block_runner(strat, beta, CommChannel(), scheduled=True)
    assert flat is not pooled
    clear_runner_cache()


def test_pool_state_is_a_pytree():
    ps = PoolState(last_seen=np.full(4, -1, np.int32),
                   staleness=np.zeros(4, np.int32),
                   checkins=np.zeros(4, np.int32))
    staged = jax.device_put(ps)
    assert isinstance(staged, PoolState)
    leaves = jax.tree.leaves(staged)
    assert len(leaves) == 3              # buffer fields are empty (None)
    rt = jax.tree.unflatten(jax.tree.structure(staged), leaves)
    assert rt.buf_updates is None and rt.flushes is None
