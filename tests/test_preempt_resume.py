"""Preemption-safe federated runs (PR 7): kill-and-resume BIT-FOR-BIT
parity against an uninterrupted seeded run.

The contract under test: run_federated(ckpt_dir=...) snapshots the full
scan carry (phi, PoolState incl. int8 FedBuff slabs, host RNG / sampling
chains, per-client transport bills, eval history) at block boundaries,
and resume=True restores it so an interrupted run finishes with EXACTLY
the params, history rows, pool identity state, and integer byte bills of
a run that was never killed.

Heavy cases run in SUBPROCESSES (the test_mesh_engine.py pattern) so
forced host-device topologies never leak into the rest of the suite;
the real-SIGKILL case additionally exercises the async writer dying at
an arbitrary execution point and falling back to the newest durable
snapshot.
"""
import functools
import os
import subprocess
import sys
import tempfile

import jax
import numpy as np
import pytest

from repro.configs.paper_models import SINE_MLP
from repro.core import run_federated
from repro.core.strategies import TinyReptileStrategy
from repro.data import SineTasks
from repro.models.paper_nets import init_paper_model, paper_model_loss
from repro.testing import faults

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_PRELUDE = """
import functools, tempfile
import jax, numpy as np
from repro.configs.paper_models import SINE_MLP
from repro.core import (BufferedAggregation, ClientPool, CommChannel,
                        DiurnalAvailability, MarkovAvailability,
                        run_federated, client_mesh)
from repro.core.strategies import (FedAvgStrategy, FedSGDStrategy,
                                   ReptileStrategy, TifedStrategy,
                                   TinyReptileStrategy, TransferStrategy)
from repro.data import SineTasks
from repro.models.paper_nets import (init_paper_model, paper_model_loss,
                                     relu_mlp_loss)
from repro.testing import faults

LOSS = functools.partial(paper_model_loss, SINE_MLP)
EVAL = dict(num_tasks=2, support=4, k_steps=2, lr=0.02, query=8)
params = init_paper_model(SINE_MLP, jax.random.PRNGKey(0))
dist = SineTasks()

def assert_same(ref, res, tag):
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(res["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=tag)
    assert len(ref["history"]) == len(res["history"]), tag
    for ra, rb in zip(ref["history"], res["history"]):
        assert set(ra) == set(rb), tag
        for k in ra:
            assert float(ra[k]) == float(rb[k]), (tag, k, ra[k], rb[k])
    for k in ("comm_bytes", "per_client_bytes"):
        if k in ref:
            assert ref[k] == res[k], (tag, k)
    if "pool_state" in ref:
        for k in ref["pool_state"]:
            a = np.asarray(ref["pool_state"][k])
            b = np.asarray(res["pool_state"][k])
            assert a.dtype == b.dtype, (tag, k)
            np.testing.assert_array_equal(a, b, err_msg=f"{tag}:{k}")

def crash_resume(make_run, crash_round, tag):
    ref = make_run()
    d = tempfile.mkdtemp()
    ck = dict(ckpt_dir=d, ckpt_every=4)
    try:
        with faults.crash_at_round(crash_round):
            make_run(ckpt_async=False, **ck)
        raise SystemExit(f"{tag}: crash hook never fired")
    except faults.SimulatedPreemption:
        pass
    res = make_run(resume=True, **ck)
    assert_same(ref, res, tag)
"""


def _run(code: str, devices: int = 1, timeout: int = 560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", _PRELUDE + code],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=timeout)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_crash_resume_parity_all_six_strategies():
    """Kill after the round-4 snapshot, resume, and land bit-for-bit on
    the uninterrupted run for every strategy — including tifed, whose
    carry holds int8 payloads and an int8 transport bill."""
    out = _run("""
cases = [
    ("tinyreptile", TinyReptileStrategy(LOSS, use_pallas=None), {}),
    ("reptile", ReptileStrategy(LOSS, epochs=2, use_pallas=None), {}),
    ("fedavg", FedAvgStrategy(LOSS, epochs=2), {}),
    ("fedsgd", FedSGDStrategy(LOSS), {}),
    ("transfer", TransferStrategy(LOSS), {}),
    ("tifed", TifedStrategy(relu_mlp_loss, epochs=8),
     dict(beta=0.0, support=16,
          eval_kwargs=dict(num_tasks=2, support=4, k_steps=2, lr=0.01,
                           query=8),
          channel=CommChannel("int8", quantize=False))),
]
for name, strategy, over in cases:
    kw = dict(rounds=8, beta=0.02, support=6, seed=5, clients_per_round=3,
              eval_every=4, eval_kwargs=EVAL)
    kw.update(over)
    def make_run(**extra):
        return run_federated(params, dist, strategy, **kw, **extra)
    crash_resume(make_run, 4, name)
    print("OK", name)
print("six-strategy crash/resume parity ok")
""")
    assert "six-strategy crash/resume parity ok" in out
    for name in ("tinyreptile", "reptile", "fedavg", "fedsgd", "transfer",
                 "tifed"):
        assert f"OK {name}" in out


def test_crash_resume_pool_buffered_availability():
    """Pooled scenarios: the snapshot must carry PoolState (identity
    arrays + FedBuff buffer slab + flush counters), the per-client data
    RNG streams, and the availability chain — Markov's sticky on/off
    state is host-side and would silently diverge if dropped."""
    out = _run("""
strategy = TinyReptileStrategy(LOSS, use_pallas=None)
scenarios = [
    ("pool-buffered-markov", lambda: dict(
        pool=ClientPool(dist, 16, seed=7),
        buffered=BufferedAggregation(buffer_size=3),
        sampling=MarkovAvailability())),
    ("pool-diurnal", lambda: dict(
        pool=ClientPool(dist, 12, seed=11),
        sampling=DiurnalAvailability(period=6))),
    ("pool-plain", lambda: dict(pool=ClientPool(dist, 10, seed=2))),
]
for name, mk in scenarios:
    kw = dict(rounds=12, beta=0.02, support=6, seed=5, clients_per_round=4,
              eval_every=4, eval_kwargs=EVAL)
    def make_run(**extra):
        # fresh pool/policy objects per run: host state must come from
        # the snapshot, never from leftover in-process objects
        return run_federated(params, dist, strategy, **kw, **mk(), **extra)
    crash_resume(make_run, 4, name)
    print("OK", name)
print("pool crash/resume parity ok")
""")
    assert "pool crash/resume parity ok" in out


def test_crash_resume_mesh4():
    """Resume on a 4-device client mesh: the sharded carry (phi
    replicated, pool arrays client-sharded) snapshots and restores to
    the same bits as the uninterrupted mesh run."""
    out = _run("""
strategy = TinyReptileStrategy(LOSS, use_pallas=None)
mesh = client_mesh(4)
kw = dict(rounds=8, beta=0.02, support=6, seed=3, clients_per_round=4,
          eval_every=4, eval_kwargs=EVAL, mesh=mesh,
          pool=None)
def make_run(**extra):
    return run_federated(params, dist, strategy,
                         pool=ClientPool(dist, 8, seed=9),
                         buffered=BufferedAggregation(buffer_size=2),
                         **{k: v for k, v in kw.items() if k != "pool"},
                         **extra)
crash_resume(make_run, 4, "mesh4-pool")
def make_flat(**extra):
    return run_federated(params, dist, strategy,
                         **{k: v for k, v in kw.items() if k != "pool"},
                         **extra)
crash_resume(make_flat, 4, "mesh4-flat")
print("mesh4 crash/resume parity ok")
""", devices=4)
    assert "mesh4 crash/resume parity ok" in out


def test_real_sigkill_resume():
    """A REAL preemption: the child announces each durable snapshot on
    stdout and is SIGKILLed right after the first one — mid-run, async
    writer live, no cleanup. A second process resumes from whatever
    survived on disk and must still land bit-for-bit on the
    uninterrupted run."""
    child = _PRELUDE + """
import sys
d = sys.argv[1]
strategy = TinyReptileStrategy(LOSS, use_pallas=None)
with faults.announce_snapshots():
    run_federated(params, dist, strategy, rounds=16, beta=0.02, support=6,
                  seed=5, clients_per_round=3, eval_every=4, eval_kwargs=EVAL,
                  ckpt_dir=d, ckpt_every=4)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=1"
    env["JAX_PLATFORMS"] = "cpu"
    with tempfile.TemporaryDirectory() as d:
        rc, out = faults.kill_after_snapshot(
            [sys.executable, "-c", child, d], n=1, env=env, cwd=REPO,
            timeout=400)
        assert rc != 0, "child survived the kill"
        assert faults.SNAPSHOT_TAG in out
        finisher = _PRELUDE + """
import sys
d = sys.argv[1]
strategy = TinyReptileStrategy(LOSS, use_pallas=None)
kw = dict(rounds=16, beta=0.02, support=6, seed=5, clients_per_round=3,
          eval_every=4, eval_kwargs=EVAL)
ref = run_federated(params, dist, strategy, **kw)
res = run_federated(params, dist, strategy, ckpt_dir=d, ckpt_every=4,
                    resume=True, **kw)
assert_same(ref, res, "sigkill-resume")
print("sigkill resume parity ok")
"""
        r = subprocess.run([sys.executable, "-c", finisher, d],
                           capture_output=True, text=True, env=env,
                           cwd=REPO, timeout=560)
        assert r.returncode == 0, r.stderr[-3000:]
        assert "sigkill resume parity ok" in r.stdout


# ---------------------------------------------------------------------------
# in-process cases (default backend, no forced topology)

LOSS = functools.partial(paper_model_loss, SINE_MLP)
EVAL = dict(num_tasks=2, support=4, k_steps=2, lr=0.02, query=8)


@pytest.fixture(scope="module")
def sine_setup():
    params = init_paper_model(SINE_MLP, jax.random.PRNGKey(0))
    return params, SineTasks(), TinyReptileStrategy(LOSS, use_pallas=None)


def _exact(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_resume_past_original_horizon(sine_setup):
    """--resume with a LARGER --rounds keeps going past the original
    horizon: ckpt at rounds=6, resume to rounds=10, bitwise equal to a
    fresh rounds=10 run (anneal=False — annealed alpha schedules depend
    on the total horizon by design)."""
    params, dist, strategy = sine_setup
    kw = dict(beta=0.02, support=6, seed=5, eval_every=2, eval_kwargs=EVAL,
              anneal=False)
    with tempfile.TemporaryDirectory() as d:
        run_federated(params, dist, strategy, rounds=6, ckpt_dir=d,
                      ckpt_every=2, ckpt_async=False, **kw)
        res = run_federated(params, dist, strategy, rounds=10, ckpt_dir=d,
                            ckpt_every=2, resume=True, **kw)
        fresh = run_federated(params, dist, strategy, rounds=10, **kw)
        _exact(fresh["params"], res["params"])
        assert len(fresh["history"]) == len(res["history"])
        for a, b in zip(fresh["history"], res["history"]):
            assert all(float(a[k]) == float(b[k]) for k in a)


def test_resume_at_horizon_is_noop(sine_setup):
    """Resuming a run that already finished returns the saved terminal
    state without executing any blocks."""
    params, dist, strategy = sine_setup
    kw = dict(rounds=4, beta=0.02, support=6, seed=5, eval_every=2,
              eval_kwargs=EVAL)
    with tempfile.TemporaryDirectory() as d:
        out1 = run_federated(params, dist, strategy, ckpt_dir=d,
                             ckpt_every=2, ckpt_async=False, **kw)
        out2 = run_federated(params, dist, strategy, ckpt_dir=d,
                             ckpt_every=2, resume=True, **kw)
        _exact(out1["params"], out2["params"])
        assert len(out1["history"]) == len(out2["history"])


def test_resume_fingerprint_mismatch_rejected(sine_setup):
    params, dist, strategy = sine_setup
    kw = dict(rounds=4, beta=0.02, support=6, eval_every=2,
              eval_kwargs=EVAL)
    with tempfile.TemporaryDirectory() as d:
        run_federated(params, dist, strategy, seed=5, ckpt_dir=d,
                      ckpt_every=2, ckpt_async=False, **kw)
        with pytest.raises(ValueError, match="different run config"):
            run_federated(params, dist, strategy, seed=99, ckpt_dir=d,
                          ckpt_every=2, resume=True, **kw)


def test_resume_mesh_layout_mismatch_rejected(sine_setup):
    """A snapshot taken under one mesh layout never silently resumes
    into another: the fingerprint pins the full mesh topology (axis
    names + extents) and the ModelPartitioner identity, so a flat (or
    1-D) checkpoint cannot feed a 2-D model-sharded run. A 1x1
    ("clients", "model") mesh makes this checkable on one device."""
    from repro.runtime.sharding import client_model_mesh
    params, dist, strategy = sine_setup
    kw = dict(rounds=4, beta=0.02, support=6, seed=5, eval_every=2,
              eval_kwargs=EVAL)
    with tempfile.TemporaryDirectory() as d:
        run_federated(params, dist, strategy, ckpt_dir=d, ckpt_every=2,
                      ckpt_async=False, **kw)
        with pytest.raises(ValueError, match="different run config"):
            run_federated(params, dist, strategy, ckpt_dir=d,
                          ckpt_every=2, resume=True,
                          mesh=client_model_mesh(1, 1), **kw)


def test_resume_shrunk_horizon_rejected(sine_setup):
    params, dist, strategy = sine_setup
    kw = dict(beta=0.02, support=6, seed=5, eval_every=2, eval_kwargs=EVAL)
    with tempfile.TemporaryDirectory() as d:
        run_federated(params, dist, strategy, rounds=8, ckpt_dir=d,
                      ckpt_every=2, ckpt_async=False, **kw)
        with pytest.raises(ValueError):
            run_federated(params, dist, strategy, rounds=4, ckpt_dir=d,
                          ckpt_every=2, resume=True, **kw)


def test_resume_empty_dir_starts_fresh(sine_setup, caplog):
    """resume=True against a directory with no snapshots is a fresh
    start (logged), not an error — first launch of a preemptible job."""
    import logging
    params, dist, strategy = sine_setup
    kw = dict(rounds=4, beta=0.02, support=6, seed=5, eval_every=2,
              eval_kwargs=EVAL)
    ref = run_federated(params, dist, strategy, **kw)
    with tempfile.TemporaryDirectory() as d:
        with caplog.at_level(logging.INFO, "repro.core.engine"):
            res = run_federated(params, dist, strategy, ckpt_dir=d,
                                ckpt_every=2, ckpt_async=False,
                                resume=True, **kw)
        assert any("fresh" in r.message for r in caplog.records)
    _exact(ref["params"], res["params"])


def test_ckpt_argument_validation(sine_setup):
    params, dist, strategy = sine_setup
    kw = dict(rounds=2, beta=0.02, support=6, seed=5)
    with pytest.raises(ValueError):
        run_federated(params, dist, strategy, ckpt_dir="/tmp/x",
                      ckpt_every=0, **kw)
    with pytest.raises(ValueError):
        run_federated(params, dist, strategy, resume=True, **kw)


def test_corrupt_newest_snapshot_resumes_from_older(sine_setup, caplog):
    """Graceful degradation end-to-end: corrupt the newest snapshot,
    resume falls back to the previous one (warning logged) and still
    reproduces the uninterrupted run bit-for-bit."""
    import logging
    params, dist, strategy = sine_setup
    from repro.checkpoint import list_checkpoints
    kw = dict(rounds=12, beta=0.02, support=6, seed=5, eval_every=4,
              eval_kwargs=EVAL)
    ref = run_federated(params, dist, strategy, **kw)
    with tempfile.TemporaryDirectory() as d:
        try:
            with faults.crash_at_round(8):
                run_federated(params, dist, strategy, ckpt_dir=d,
                              ckpt_every=4, ckpt_async=False, **kw)
        except faults.SimulatedPreemption:
            pass
        faults.flip_bytes(list_checkpoints(d)[-1])
        with caplog.at_level(logging.WARNING, "repro.checkpoint.ckpt"):
            res = run_federated(params, dist, strategy, ckpt_dir=d,
                                ckpt_every=4, resume=True, **kw)
        assert any("falling back" in r.message for r in caplog.records)
    _exact(ref["params"], res["params"])
    assert len(ref["history"]) == len(res["history"])
