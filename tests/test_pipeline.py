"""The overlapped host/device round pipeline (PR 2).

Covers the four tentpole contracts:
- block planning + fixed padded shapes (retrace-free: ONE jit trace per
  strategy/channel config across uneven eval/tail blocks);
- bit-for-bit seeded parity of pipelined (background prefetch) vs
  synchronous runs across eval cadences and uneven max_block tails;
- vectorized block sampling == the scalar block-order reference loop for
  the sine distribution, and shape/dtype contracts for all distributions;
- TinyMetaFed-style partial-communication channel (fraction accounting +
  masked uplink) and the block-runner cache counters.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import SINE_MLP
from repro.core import (CommChannel, PartialCommChannel, UniformSampling,
                        clear_runner_cache, fedsgd_train, reptile_train,
                        runner_cache_stats, tinyreptile_train)
from repro.core.engine import _block_runner
from repro.core.meta import tree_bytes
from repro.core.pipeline import BlockPrefetcher, plan_blocks
from repro.core.strategies import TinyReptileStrategy
from repro.data import SineTasks
from repro.data.tasks import KWSTasks, OmniglotTasks
from repro.models.paper_nets import init_paper_model, paper_model_loss

LOSS = functools.partial(paper_model_loss, SINE_MLP)
EVAL = dict(num_tasks=2, support=4, k_steps=2, lr=0.02, query=8)


@pytest.fixture(scope="module")
def setup():
    return init_paper_model(SINE_MLP, jax.random.PRNGKey(0)), SineTasks()


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# block planning
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rounds,eval_every,max_block", [
    (120, 0, 512), (120, 20, 512), (50, 20, 512), (21, 7, 512),
    (21, 0, 8), (17, 7, 5), (1, 0, 512), (20, 30, 512),
])
def test_plan_blocks_covers_run_with_one_pad(rounds, eval_every, max_block):
    blocks, pad = plan_blocks(rounds, eval_every, max_block)
    # contiguous cover of [0, rounds)
    assert blocks[0][0] == 0 and blocks[-1][1] == rounds
    for (_, e0), (s1, _) in zip(blocks, blocks[1:]):
        assert e0 == s1
    # every block fits the single padded shape
    assert all(0 < e - s <= pad for s, e in blocks)
    # blocks never straddle an eval boundary
    if eval_every:
        for s, e in blocks:
            assert s // eval_every == (e - 1) // eval_every
    assert pad <= max_block and pad <= rounds


def test_plan_blocks_empty_run():
    assert plan_blocks(0, 0, 512) == ([], 0)


def test_plan_blocks_rejects_nonpositive_max_block():
    for bad in (0, -3):
        with pytest.raises(ValueError):
            plan_blocks(10, 0, bad)


# ---------------------------------------------------------------------------
# pipelined vs synchronous: bit-for-bit seeded parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("eval_every", [0, 7, 21])
@pytest.mark.parametrize("sampler", ["reference", "vectorized"])
def test_prefetch_parity_across_eval_cadence(setup, eval_every, sampler):
    params, dist = setup
    kw = dict(rounds=21, alpha=1.0, beta=0.02, support=4, seed=3,
              eval_every=eval_every, eval_kwargs=EVAL, sampler=sampler)
    sync = tinyreptile_train(LOSS, params, dist, prefetch=0, **kw)
    piped = tinyreptile_train(LOSS, params, dist, prefetch=2, **kw)
    _assert_trees_equal(sync["params"], piped["params"])
    assert sync["history"] == piped["history"]
    assert sync["comm_bytes"] == piped["comm_bytes"]


def test_prefetch_parity_uneven_max_block_tail(setup):
    """rounds % max_block != 0: the short tail block is padded + masked,
    not re-traced — and numerics stay bitwise identical."""
    params, dist = setup
    kw = dict(rounds=21, alpha=1.0, beta=0.02, support=4, seed=5,
              max_block=8, clients_per_round=3, epochs=2)
    sync = reptile_train(LOSS, params, dist, prefetch=0, **kw)
    piped = reptile_train(LOSS, params, dist, prefetch=2, **kw)
    _assert_trees_equal(sync["params"], piped["params"])


def test_sampling_policy_object_param(setup):
    """An explicit SamplingPolicy instance overrides the sampler string."""
    params, dist = setup
    from repro.core import run_federated
    from repro.core.strategies import TinyReptileStrategy as S
    kw = dict(rounds=9, alpha=1.0, beta=0.02, support=4, seed=2)
    a = run_federated(params, dist, S(LOSS), sampler="vectorized", **kw)
    b = run_federated(params, dist, S(LOSS),
                      sampling=UniformSampling("vectorized"), **kw)
    _assert_trees_equal(a["params"], b["params"])
    with pytest.raises(ValueError):
        UniformSampling("bogus")


# ---------------------------------------------------------------------------
# retrace-free fixed shapes: exactly one compile per config
# ---------------------------------------------------------------------------

def test_single_trace_across_uneven_eval_blocks(setup):
    """17 rounds at eval_every=7 -> blocks 7, 7, 3 all padded to 7: the
    runner traces exactly once (the tentpole's acceptance criterion)."""
    params, dist = setup
    clear_runner_cache()
    beta = 0.0701                        # unique config -> fresh runner
    tinyreptile_train(LOSS, params, dist, rounds=17, alpha=1.0, beta=beta,
                      support=4, seed=3, eval_every=7, eval_kwargs=EVAL)
    runner = _block_runner(TinyReptileStrategy(LOSS, use_pallas=None),
                           beta, CommChannel())
    assert runner.trace_count == 1
    # a second identical run reuses the cached executable: still 1 trace
    tinyreptile_train(LOSS, params, dist, rounds=17, alpha=1.0, beta=beta,
                      support=4, seed=4, eval_every=7, eval_kwargs=EVAL)
    assert runner.trace_count == 1


def test_single_trace_uneven_max_block_tail(setup):
    params, dist = setup
    clear_runner_cache()
    beta = 0.0702
    tinyreptile_train(LOSS, params, dist, rounds=21, alpha=1.0, beta=beta,
                      support=4, seed=3, max_block=8)   # blocks 8, 8, 5
    runner = _block_runner(TinyReptileStrategy(LOSS, use_pallas=None),
                           beta, CommChannel())
    assert runner.trace_count == 1


# ---------------------------------------------------------------------------
# vectorized block sampling
# ---------------------------------------------------------------------------

def test_sine_vectorized_block_matches_scalar_block_order_loop():
    """The vectorized sine sampler is bit-for-bit a scalar loop in the
    documented block RNG order: all (a, b, c) task triples row-by-row,
    then every support input, then the same per-sample math."""
    dist = SineTasks()
    rounds, clients, support = 4, 3, 5
    vec = dist.sample_support_block(np.random.default_rng(9), rounds,
                                    clients, support)
    rng = np.random.default_rng(9)
    n, (lo, hi) = rounds * clients, dist.x_range
    abc = np.array([[rng.uniform(0.1, 5.0), rng.uniform(0.8, 1.2),
                     rng.uniform(0.0, np.pi)] for _ in range(n)])
    x = np.array([[rng.uniform(lo, hi) for _ in range(support)]
                  for _ in range(n)], np.float32)[..., None]
    a, b, c = (abc[:, j, None, None] for j in range(3))
    y = (a * np.sin(b * x + c)).astype(np.float32)
    np.testing.assert_array_equal(vec["x"],
                                  x.reshape(rounds, clients, support, 1))
    np.testing.assert_array_equal(vec["y"],
                                  y.reshape(rounds, clients, support, 1))


@pytest.mark.parametrize("dist,ways", [
    (OmniglotTasks(num_classes=30, ways=5), 5),
    (KWSTasks(num_words=12, ways=4), 4),
])
def test_vectorized_block_matches_reference_contract(dist, ways):
    """Vectorized Omniglot/KWS blocks match the reference loop's shapes,
    dtypes, and label/value ranges (the RNG block order is documented to
    differ, so values are distribution-equal, not bitwise-equal)."""
    rounds, clients, support = 3, 2, 4
    ref = dist.sample_support_block_reference(np.random.default_rng(1),
                                              rounds, clients, support)
    vec = dist.sample_support_block(np.random.default_rng(1), rounds,
                                    clients, support)
    assert vec["x"].shape == ref["x"].shape
    assert vec["y"].shape == ref["y"].shape
    assert vec["x"].dtype == ref["x"].dtype == np.float32
    assert vec["y"].dtype == ref["y"].dtype == np.int32
    assert np.isfinite(vec["x"]).all()
    assert vec["y"].min() >= 0 and vec["y"].max() < ways


def test_omniglot_vectorized_block_matches_scalar_block_order_loop():
    """The fully-vectorized Omniglot sampler (no per-task Python loop
    left) is bit-for-bit a scalar loop in the documented block RNG
    order: one (n, num_classes) uniform draw argsorted per row for the
    class subsets, then labels, roll offsets, and noise as one array
    draw each, then the per-sample np.roll + noise math."""
    from repro.data.tasks import _glyph_prototype
    dist = OmniglotTasks(num_classes=12, ways=4, noise=0.1)
    rounds, clients, support, side = 2, 3, 4, 28
    n = rounds * clients
    vec = dist.sample_support_block(np.random.default_rng(11), rounds,
                                    clients, support)
    rng = np.random.default_rng(11)
    classes = np.argsort(rng.random((n, 12)), axis=1)[:, :4]
    labels = rng.integers(4, size=(n, support))
    shifts = rng.integers(-2, 3, size=(n, support, 2))
    noise = rng.normal(0, 0.1, size=(n, support, side, side)).astype(
        np.float32)
    x = np.zeros((n, support, side, side, 1), np.float32)
    for i in range(n):
        for s in range(support):
            proto = _glyph_prototype(int(classes[i, labels[i, s]]))
            img = np.roll(proto, tuple(shifts[i, s]), axis=(0, 1))
            x[i, s] = (img + noise[i, s])[..., None].astype(np.float32)
    np.testing.assert_array_equal(
        vec["x"], x.reshape(rounds, clients, support, side, side, 1))
    np.testing.assert_array_equal(
        vec["y"], labels.astype(np.int32).reshape(rounds, clients, support))


def test_kws_vectorized_block_matches_scalar_block_order_loop():
    """Same contract for the KWS sampler: one (n, num_words) uniform
    draw for the keyword subsets, then labels / shifts / amplitudes /
    noise as array draws, per-sample roll-scale-noise math bitwise."""
    from repro.data.tasks import _kws_prototype
    dist = KWSTasks(num_words=9, ways=3, noise=0.15)
    rounds, clients, support, t, f = 2, 2, 5, 49, 10
    n = rounds * clients
    vec = dist.sample_support_block(np.random.default_rng(13), rounds,
                                    clients, support)
    rng = np.random.default_rng(13)
    words = np.argsort(rng.random((n, 9)), axis=1)[:, :3]
    labels = rng.integers(3, size=(n, support))
    shifts = rng.integers(-3, 4, size=(n, support))
    amps = rng.uniform(0.8, 1.2, size=(n, support))
    noise = rng.normal(0, 0.15, size=(n, support, t, f)).astype(np.float32)
    x = np.zeros((n, support, t, f, 1), np.float32)
    for i in range(n):
        for s in range(support):
            proto = _kws_prototype(int(words[i, labels[i, s]]))
            m = np.roll(proto, int(shifts[i, s]), axis=0)
            x[i, s] = (m * amps[i, s] + noise[i, s])[..., None].astype(
                np.float32)
    np.testing.assert_array_equal(
        vec["x"], x.reshape(rounds, clients, support, t, f, 1))
    np.testing.assert_array_equal(
        vec["y"], labels.astype(np.int32).reshape(rounds, clients, support))


def test_choice_block_is_without_replacement_and_uniform():
    """The vectorized subset draw: rows are distinct-entry subsets, and
    with k == m every row is a full permutation (the argsort-of-uniform
    construction); k > m is rejected."""
    from repro.data.tasks import TaskDistribution
    got = TaskDistribution._choice_block(np.random.default_rng(0), 64, 10, 4)
    assert got.shape == (64, 4)
    assert all(len(set(row)) == 4 for row in got)
    perms = TaskDistribution._choice_block(np.random.default_rng(1), 32, 5, 5)
    assert (np.sort(perms, axis=1) == np.arange(5)).all()
    with pytest.raises(ValueError):
        TaskDistribution._choice_block(np.random.default_rng(2), 4, 3, 5)


@pytest.mark.parametrize("dist", [
    OmniglotTasks(num_classes=20, ways=5),
    KWSTasks(num_words=10, ways=4),
])
def test_vectorized_block_distribution_matches_reference(dist):
    """Seeded distributional parity with sample_support_block_reference:
    the vectorized block order draws different values for a given seed
    (documented since PR 2) but must sample the SAME distribution —
    pixel moments and label histograms agree over a large block."""
    rounds, clients, support = 16, 4, 8
    ref = dist.sample_support_block_reference(np.random.default_rng(3),
                                              rounds, clients, support)
    vec = dist.sample_support_block(np.random.default_rng(3), rounds,
                                    clients, support)
    np.testing.assert_allclose(vec["x"].mean(), ref["x"].mean(), atol=0.05)
    np.testing.assert_allclose(vec["x"].std(), ref["x"].std(), atol=0.05)
    ways = dist.ways
    href = np.bincount(ref["y"].ravel(), minlength=ways) / ref["y"].size
    hvec = np.bincount(vec["y"].ravel(), minlength=ways) / vec["y"].size
    np.testing.assert_allclose(hvec, href, atol=0.1)


def test_base_distribution_block_falls_back_to_reference():
    dist = SineTasks()
    ref = dist.sample_support_block_reference(np.random.default_rng(4),
                                              2, 2, 3)
    base = super(SineTasks, dist).sample_support_block  # unbound fallback
    got = base(np.random.default_rng(4), 2, 2, 3)
    np.testing.assert_array_equal(ref["x"], got["x"])
    np.testing.assert_array_equal(ref["y"], got["y"])


def test_vectorized_sampler_trains(setup):
    """End-to-end: the vectorized host path learns an adaptable init."""
    params, dist = setup
    out = tinyreptile_train(LOSS, params, dist, rounds=60, alpha=1.0,
                            beta=0.02, support=8, seed=1, eval_every=60,
                            eval_kwargs=EVAL, sampler="vectorized")
    assert np.isfinite(out["history"][-1]["query_loss"])
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree.leaves(out["params"]))


def test_client_schedule_is_a_pytree():
    """ClientSchedule device-stages through the prefetcher and slices
    through lax.scan like any other block input."""
    from repro.core import ClientSchedule
    sched = ClientSchedule(
        valid=np.array([True, False]),
        alpha=np.array([1.0, 0.5], np.float32),
        round_index=np.array([0, 1], np.int32),
        participation=np.ones((2, 3), bool),
        local_steps=np.full((2, 3), 4, np.int32),
        weights=np.full((2, 3), 1 / 3, np.float32))
    staged = jax.device_put(sched)
    assert isinstance(staged, ClientSchedule)
    rows = []

    def body(carry, s):
        rows.append(s)
        return carry, s.round_index

    _, idx = jax.lax.scan(body, 0, staged)
    np.testing.assert_array_equal(np.asarray(idx), [0, 1])
    assert rows[0].participation.shape == (3,)     # per-round row slices
    assert rows[0].valid.shape == ()


# ---------------------------------------------------------------------------
# the prefetcher itself
# ---------------------------------------------------------------------------

def test_prefetcher_yields_in_order_and_closes():
    pf = BlockPrefetcher(lambda i: i * i, 7, depth=2)
    assert [pf.get() for _ in range(7)] == [i * i for i in range(7)]
    # over-consumption raises instead of deadlocking on the empty queue
    with pytest.raises(StopIteration):
        pf.get()
    pf.close()
    pf.close()                                   # idempotent
    with pytest.raises(StopIteration):
        pf.get()                                 # closed -> exhausted


def test_prefetcher_propagates_producer_errors():
    def produce(i):
        if i == 1:
            raise RuntimeError("boom")
        return i
    pf = BlockPrefetcher(produce, 5, depth=2)
    assert pf.get() == 0
    with pytest.raises(RuntimeError, match="boom"):
        pf.get()
    pf.close()


def test_prefetcher_early_close_does_not_deadlock():
    pf = BlockPrefetcher(lambda i: i, 100, depth=1)
    assert pf.get() == 0
    pf.close()                                   # 99 items never consumed


# ---------------------------------------------------------------------------
# TinyMetaFed-style partial communication
# ---------------------------------------------------------------------------

def test_partial_channel_accounting(setup):
    params, _ = setup
    ch = PartialCommChannel(fraction=0.25)
    want = sum(max(1, int(round(0.25 * x.size))) * 4
               for x in jax.tree.leaves(params))
    assert ch.payload_bytes(params) == want
    assert ch.round_bytes(params, 3) == 2 * 3 * want
    assert want < tree_bytes(params) // 3        # genuinely partial
    # fraction=1.0 degenerates to the base fp32 accounting
    assert PartialCommChannel(fraction=1.0).payload_bytes(params) == \
        tree_bytes(params)
    for bad in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError):
            PartialCommChannel(fraction=bad)


def test_partial_channel_masks_uplink_delta():
    r = np.random.default_rng(0)
    ref = {"w": jnp.asarray(r.normal(size=(128,)), jnp.float32)}
    sent = {"w": jnp.asarray(r.normal(size=(128,)), jnp.float32)}
    ch = PartialCommChannel(fraction=0.5)
    got = np.asarray(ch.transmit(sent, ref=ref)["w"])
    from_sent = got == np.asarray(sent["w"])
    from_ref = got == np.asarray(ref["w"])
    assert (from_sent | from_ref).all()
    assert from_sent.sum() == ch.kept_entries(128)
    # deterministic: the mask is fixed by mask_seed
    again = np.asarray(ch.transmit(sent, ref=ref)["w"])
    np.testing.assert_array_equal(got, again)
    # no ref (downlink): value-exact broadcast
    np.testing.assert_array_equal(np.asarray(ch.transmit(sent)["w"]),
                                  np.asarray(sent["w"]))


def test_partial_channel_int8_keeps_server_values_exact():
    """On a quantizing wire, untransmitted entries fall back to the
    server's OWN values bit-exactly — only transmitted entries carry
    quantization noise."""
    r = np.random.default_rng(2)
    ref = {"w": jnp.asarray(r.normal(size=(128,)), jnp.float32)}
    sent = {"w": jnp.asarray(r.normal(size=(128,)), jnp.float32)}
    ch = PartialCommChannel(dtype="int8", fraction=0.5)
    got = np.asarray(ch.transmit(sent, ref=ref)["w"])
    wired = np.asarray(CommChannel("int8").transmit(sent)["w"])
    from_ref = got == np.asarray(ref["w"])
    from_wire = got == wired
    assert (from_ref | from_wire).all()
    assert from_ref.sum() >= 128 - ch.kept_entries(128)


def test_quantize_true_on_fp32_wire_rejected():
    with pytest.raises(ValueError):
        CommChannel("float32", quantize=True)


def test_partial_channel_wire_gating():
    """quantize=False keeps the accounting-only contract (no dtype cast
    anywhere), and quantizing partial downlinks stay value-exact."""
    r = np.random.default_rng(3)
    ref = {"w": jnp.asarray(r.normal(size=(64,)), jnp.float32)}
    sent = {"w": jnp.asarray(r.normal(size=(64,)), jnp.float32)}
    acct = PartialCommChannel(dtype="float16", quantize=False, fraction=0.5)
    np.testing.assert_array_equal(np.asarray(acct.transmit(sent)["w"]),
                                  np.asarray(sent["w"]))
    up = np.asarray(acct.transmit(sent, ref=ref)["w"])
    assert ((up == np.asarray(sent["w"])) | (up == np.asarray(ref["w"]))).all()
    # metering still sees the compressed link: fp16 itemsize, half entries
    assert acct.payload_bytes(ref) == acct.kept_entries(64) * 2
    # quantizing partial downlink: kept entries ride the int8 wire,
    # dropped entries stay exact — converging to the base channel at 1.0
    ch = PartialCommChannel(dtype="int8", fraction=0.5)
    down = np.asarray(ch.transmit(sent)["w"])
    wired = np.asarray(CommChannel("int8").transmit(sent)["w"])
    exact = down == np.asarray(sent["w"])
    assert (exact | (down == wired)).all()
    assert exact.sum() >= 64 - ch.kept_entries(64)
    full = PartialCommChannel(dtype="int8", fraction=1.0)
    np.testing.assert_array_equal(np.asarray(full.transmit(sent)["w"]),
                                  wired)


def test_partial_channel_trains_and_meters(setup):
    params, dist = setup
    ch = PartialCommChannel(fraction=0.5)
    out = tinyreptile_train(LOSS, params, dist, rounds=30, alpha=1.0,
                            beta=0.02, support=8, seed=1, channel=ch)
    assert out["comm_bytes"] == 30 * 2 * ch.payload_bytes(params)
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree.leaves(out["params"]))


def test_partial_channel_gradient_uplink(setup):
    """FedSGD's uplink reference is zeros: untransmitted gradient entries
    vanish rather than falling back to phi."""
    params, dist = setup
    out = fedsgd_train(LOSS, params, dist, rounds=10, beta=0.02, support=4,
                       clients_per_round=2, seed=0,
                       channel=PartialCommChannel(fraction=0.5))
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree.leaves(out["params"]))


# ---------------------------------------------------------------------------
# runner cache counters
# ---------------------------------------------------------------------------

def test_runner_cache_stats_and_clear(setup, caplog):
    params, dist = setup
    clear_runner_cache()
    stats = runner_cache_stats()
    assert stats["currsize"] == 0 and stats["unhashable_misses"] == 0

    kw = dict(rounds=5, alpha=1.0, beta=0.0703, support=4, seed=0)
    tinyreptile_train(LOSS, params, dist, **kw)
    tinyreptile_train(LOSS, params, dist, **kw)
    stats = runner_cache_stats()
    assert stats["misses"] == 1 and stats["hits"] == 1

    @dataclasses.dataclass(frozen=True)
    class UnhashableStrategy(TinyReptileStrategy):
        junk: list = dataclasses.field(default_factory=list)

    from repro.core import run_federated
    with caplog.at_level("WARNING", logger="repro.core.engine"):
        run_federated(params, dist, UnhashableStrategy(LOSS), rounds=5,
                      beta=0.0703, support=4, seed=0)
    assert runner_cache_stats()["unhashable_misses"] == 1
    assert any("unhashable" in r.message for r in caplog.records)

    clear_runner_cache()
    stats = runner_cache_stats()
    assert stats["currsize"] == 0 and stats["unhashable_misses"] == 0
