"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("shape", [(7,), (1153,), (64, 64), (3, 5, 257),
                                   (8192,)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("alpha", [0.0, 0.37, 1.0])
def test_meta_update(shape, dtype, alpha):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    w = jax.random.normal(k1, shape, jnp.float32).astype(dtype)
    wh = jax.random.normal(k2, shape, jnp.float32).astype(dtype)
    got = ops.meta_update(w, wh, alpha)
    want = ref.meta_update(w, wh, alpha)
    assert got.dtype == w.dtype and got.shape == w.shape
    tol = 1e-5 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", [(129,), (1024, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_online_sgd(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    p = jax.random.normal(k1, shape, jnp.float32).astype(dtype)
    g = jax.random.normal(k2, shape, jnp.float32).astype(dtype)
    got = ops.online_sgd(p, g, 0.01)
    want = ref.online_sgd(p, g, 0.01)
    tol = 1e-6 if dtype == jnp.float32 else 1e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_online_sgd_momentum():
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    p = jax.random.normal(k1, (513,))
    g = jax.random.normal(k2, (513,))
    m = jnp.ones((513,), jnp.float32) * 0.3
    pn, mn = ops.online_sgd_momentum(p, g, m, 0.05, 0.9)
    pr, mr = ref.online_sgd(p, g, 0.05, m, 0.9)
    np.testing.assert_allclose(pn, pr, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(mn, mr, rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("B,H,Kv,hd,S", [
    (1, 4, 4, 64, 512),      # MHA
    (2, 8, 2, 64, 1024),     # GQA
    (1, 8, 1, 128, 2048),    # MQA, paligemma-like head_dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(B, H, Kv, hd, S, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32).astype(dtype)
    kc = jax.random.normal(ks[1], (B, S, Kv, hd), jnp.float32).astype(dtype)
    vc = jax.random.normal(ks[2], (B, S, Kv, hd), jnp.float32).astype(dtype)
    for cache_len, window in [(S // 2, 0), (S, 0), (1, 0), (S // 2, 128)]:
        got = ops.flash_decode(q, kc, vc, cache_len, window=window,
                               block_s=256)
        want = ref.flash_decode(q, kc, vc, cache_len, window=window)
        tol = 3e-4 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(got, np.float32), want,
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("B,H,nc,Q,P,N", [
    (1, 2, 2, 16, 64, 16),
    (2, 3, 4, 32, 64, 32),
    (1, 24, 2, 64, 64, 128),  # mamba2-130m geometry
])
def test_ssd_scan(B, H, nc, Q, P, N):
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    xd = jax.random.normal(ks[0], (B, H, nc, Q, P))
    dA = -jnp.abs(jax.random.normal(ks[1], (B, H, nc, Q))) * 0.1
    Bm = jax.random.normal(ks[2], (B, nc, Q, N)) * 0.3
    Cm = jax.random.normal(ks[3], (B, nc, Q, N)) * 0.3
    got = ops.ssd_scan(xd, dA, Bm, Cm)
    want = ref.ssd_scan(xd, dA, Bm, Cm)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def _tifed_case(dims, S, seed, extreme=False):
    """Random (or all-extreme) int-valued fp32 inputs for the TIFeD
    epoch kernel, plus a power-of-two scales dict. ``extreme`` drives
    every tensor to its dtype rails (the int32-accumulation edge: the
    documented < 2^24 envelope for exact fp32 parity)."""
    rng = np.random.default_rng(seed)
    din, h1, h2, dout = dims

    def ints(lo, hi, shape):
        if extreme:
            return jnp.asarray(rng.choice([float(lo), float(hi)], shape),
                               jnp.float32)
        return jnp.asarray(rng.integers(lo, hi + 1, shape), jnp.float32)

    ws = tuple(ints(-127, 127, s)
               for s in ((din, h1), (h1, h2), (h2, dout)))
    bs = tuple(ints(-2 ** 22, 2 ** 22, (b,)) if extreme
               else ints(-2 ** 15, 2 ** 15, (b,)) for b in (h1, h2, dout))
    xq = ints(-127, 127, (S, din))
    yal = ints(-2 ** 21, 2 ** 21, (S, dout)) if extreme \
        else ints(-2 ** 15, 2 ** 15, (S, dout))
    fb = tuple(ints(-127, 127, (dout, h)) for h in (h1, h2))
    dither = tuple(jnp.asarray(rng.random(s), jnp.float32)
                   for s in ((din, h1), (h1, h2), (h2, dout)))
    f32 = jnp.float32
    scales = {"f0": f32(2.0 ** -7), "f1": f32(2.0 ** -7),
              "fe": f32(2.0 ** -9), "floss": f32(2.0 ** -4 / S),
              "ftw": (f32(2.0 ** -8), f32(2.0 ** -9), f32(2.0 ** -10)),
              "ftb": (f32(2.0 ** -6), f32(2.0 ** -7), f32(2.0 ** -8))}
    return ws, bs, xq, yal, fb, dither, scales


@pytest.mark.parametrize("dims", [(1, 16, 16, 1),   # sine-MLP shape class
                                  (5, 16, 12, 3)])  # din>1, dout>1 paths
@pytest.mark.parametrize("layer", [0, 1, 2])
def test_dfa_epoch_int8_matches_ref(dims, layer):
    """Kernel vs fp32-exact oracle: EXACT equality, not allclose — both
    sides compute the same integers (ref in fp32 carrying exact ints,
    kernel in native int8/int32)."""
    ws, bs, xq, yal, fb, dither, scales = _tifed_case(dims, 32, layer + 10)
    gw, gb, gl = ops.dfa_epoch_int8(ws, bs, xq, yal, layer, fb, dither,
                                    scales)
    ww, wb, wl = ref.dfa_int8_epoch(ws, bs, xq, yal, layer, fb, dither,
                                    scales)
    for i in range(3):
        assert gw[i].dtype == jnp.int8 and gb[i].dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(gw[i], np.float32), ww[i])
        np.testing.assert_array_equal(np.asarray(gb[i], np.float32), wb[i])
    np.testing.assert_array_equal(np.float32(gl), np.float32(wl))
    # the untrained layers pass through unchanged
    for i in range(3):
        if i != layer:
            np.testing.assert_array_equal(np.asarray(gw[i], np.float32),
                                          np.asarray(ws[i]))


@pytest.mark.parametrize("layer", [0, 1, 2])
def test_dfa_epoch_int8_accumulation_edge(layer):
    """All-rails inputs at the documented envelope: S=512 samples of
    +/-127 against +/-127 weights and +/-2^22 biases keep every int32
    accumulator below 2^24, so kernel and oracle must still agree
    exactly and land inside the int8 / bias clip rails."""
    ws, bs, xq, yal, fb, dither, scales = _tifed_case(
        (1, 8, 8, 1), 512, 99, extreme=True)
    gw, gb, _ = ops.dfa_epoch_int8(ws, bs, xq, yal, layer, fb, dither,
                                   scales)
    ww, wb, _ = ref.dfa_int8_epoch(ws, bs, xq, yal, layer, fb, dither,
                                   scales)
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(gw[i], np.float32), ww[i])
        np.testing.assert_array_equal(np.asarray(gb[i], np.float32), wb[i])
        assert np.abs(np.asarray(gw[i], np.float32)).max() <= ref.INT8_MAX
        assert np.abs(np.asarray(gb[i], np.float64)).max() <= ref.BIAS_MAX


def test_stochastic_round_statistics():
    """floor(v + u), u ~ U[0,1): values land on the neighbouring
    integers only, and the mean over many dithers is unbiased."""
    rng = np.random.default_rng(7)
    v = jnp.asarray(rng.uniform(-5.0, 5.0, (64,)), jnp.float32)
    dithers = jnp.asarray(rng.random((4096, 64)), jnp.float32)
    r = np.asarray(ref.stochastic_round(v[None, :], dithers))
    lo, hi = np.floor(np.asarray(v)), np.ceil(np.asarray(v))
    assert np.all((r == lo[None, :]) | (r == hi[None, :]))
    np.testing.assert_allclose(r.mean(0), np.asarray(v), atol=0.05)


def test_ssd_kernel_matches_model_path():
    """Kernel agrees with the model's ssd_chunked (different layout)."""
    from repro.models.mamba2 import ssd_chunked
    B, S, H, P, N, chunk = 2, 128, 4, 32, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.abs(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[0], (B, S, N)) * 0.3
    y_model, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    # kernel layout
    nc = S // chunk
    xd = (x * dt[..., None]).reshape(B, nc, chunk, H, P).transpose(0, 3, 1, 2, 4)
    dA = (dt * A).reshape(B, nc, chunk, H).transpose(0, 3, 1, 2)
    Bk = Bm.reshape(B, nc, chunk, N)
    Ck = Cm.reshape(B, nc, chunk, N)
    y_kernel = ops.ssd_scan(xd, dA, Bk, Ck)
    y_kernel = y_kernel.transpose(0, 2, 3, 1, 4).reshape(B, S, H, P)
    np.testing.assert_allclose(y_kernel, np.asarray(y_model, np.float32),
                               rtol=2e-4, atol=2e-4)
