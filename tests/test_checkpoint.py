"""Checkpoint-layer contracts (PR 7): mixed-dtype roundtrips, manifest
checksums, corruption fallback, retention, the async writer, and
mesh-sharded trees gathered before save.

The fault-injection knobs live in repro.testing.faults; the engine-level
kill-and-resume parity tests live in tests/test_preempt_resume.py.
"""
import logging
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointWriter, RoundState,
                              latest_checkpoint, list_checkpoints,
                              restore_checkpoint, restore_round_state,
                              save_checkpoint, save_round_state,
                              verify_checkpoint)
from repro.testing import faults

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mixed_tree(rng):
    """A pytree spanning the dtypes the engine actually snapshots:
    fp32 phi leaves, int8 FedBuff buffer slabs, int32/int64 counters."""
    return {
        "phi": {"w": jnp.asarray(rng.normal(size=(4, 5)), jnp.float32),
                "b": jnp.asarray(rng.normal(size=5), jnp.float32)},
        "buf": jnp.asarray(rng.integers(-128, 128, size=(3, 7)), jnp.int8),
        "count": jnp.asarray(rng.integers(0, 9, size=3), jnp.int32),
        "bills": np.asarray(rng.integers(0, 2 ** 40, size=4), np.int64),
    }


def _assert_tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype, (x.dtype, y.dtype)
        np.testing.assert_array_equal(x, y)


def test_mixed_dtype_roundtrip_property():
    """Bit-exact save/restore across fp32/int8/int32/int64 leaves for a
    sweep of seeded random trees (dtype AND value preservation)."""
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.given(st.integers(0, 40))
    @hypothesis.settings(deadline=None, max_examples=20, derandomize=True)
    def inner(seed):
        tree = _mixed_tree(np.random.default_rng(seed))
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, tree, step=seed, extra={"seed": seed})
            got, step, extra = restore_checkpoint(d, tree)
            assert step == seed and extra == {"seed": seed}
            _assert_tree_equal(got, tree)

    inner()


def test_mixed_dtype_roundtrip_seeds():
    """Deterministic fallback for the property test above: same
    invariant, fixed seed sweep, runs even without hypothesis."""
    for seed in range(8):
        tree = _mixed_tree(np.random.default_rng(seed))
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, tree, step=seed, extra={"seed": seed})
            got, step, extra = restore_checkpoint(d, tree)
            assert step == seed and extra == {"seed": seed}
            _assert_tree_equal(got, tree)


def test_dtype_mismatch_raises_unless_cast():
    tree = {"w": jnp.ones((2, 3), jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, tree, step=1)
        bad_template = {"w": jnp.ones((2, 3), jnp.int8)}
        with pytest.raises(TypeError):
            restore_checkpoint(d, bad_template)
        got, _, _ = restore_checkpoint(d, bad_template, cast=True)
        assert np.asarray(got["w"]).dtype == np.int8
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.ones((2, 3), np.int8))


def test_structural_mismatches_are_not_swallowed():
    """Template/shape/leaf-count mismatches raise immediately — only
    CORRUPTION triggers the fallback scan, never a wrong template."""
    tree = {"w": jnp.ones((2, 3), jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, tree, step=1)
        with pytest.raises(ValueError):
            restore_checkpoint(d, {"w": jnp.ones((3, 2), jnp.float32)})
        with pytest.raises(KeyError):
            restore_checkpoint(d, {"w": tree["w"], "extra": tree["w"]})


def test_verify_checkpoint_catches_bit_flips():
    tree = {"w": jnp.arange(64, dtype=jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, tree, step=3)
        path = list_checkpoints(d)[-1]
        assert verify_checkpoint(path)
        faults.flip_bytes(path, offset=40, count=4)
        assert os.path.getsize(path) > 0          # size unchanged
        assert not verify_checkpoint(path)


def test_stale_latest_falls_back_to_scan(caplog):
    tree = {"w": jnp.ones(3, jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, tree, step=2)
        save_checkpoint(d, jax.tree.map(lambda x: x * 5, tree), step=4)
        faults.make_stale_latest(d)
        with caplog.at_level(logging.WARNING, "repro.checkpoint.ckpt"):
            path = latest_checkpoint(d)
        assert path is not None and path.endswith("ckpt_00000004.npz")
        assert any("LATEST" in r.message for r in caplog.records)
        got, step, _ = restore_checkpoint(d, tree)
        assert step == 4
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.full(3, 5.0, np.float32))


def test_torn_write_falls_back_to_older_snapshot(caplog):
    """A truncated newest payload is detected and skipped; restore
    degrades to the previous snapshot with a warning."""
    tree = {"w": jnp.arange(256, dtype=jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, tree, step=1)
        save_checkpoint(d, jax.tree.map(lambda x: x + 1, tree), step=2)
        faults.truncate_file(list_checkpoints(d)[-1])
        with caplog.at_level(logging.WARNING, "repro.checkpoint.ckpt"):
            got, step, _ = restore_checkpoint(d, tree)
        assert step == 1
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.arange(256, dtype=np.float32))
        assert any("falling back" in r.message for r in caplog.records)


def test_corrupted_leaves_fall_back(caplog):
    tree = {"w": jnp.arange(256, dtype=jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, tree, step=1)
        save_checkpoint(d, jax.tree.map(lambda x: x + 1, tree), step=2)
        faults.flip_bytes(list_checkpoints(d)[-1], offset=200, count=16)
        with caplog.at_level(logging.WARNING, "repro.checkpoint.ckpt"):
            got, step, _ = restore_checkpoint(d, tree)
        assert step == 1
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.arange(256, dtype=np.float32))


def test_all_corrupt_raises_empty_dir_distinct():
    tree = {"w": jnp.arange(16, dtype=jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(FileNotFoundError):
            restore_checkpoint(d, tree)
        save_checkpoint(d, tree, step=1)
        faults.truncate_file(list_checkpoints(d)[0], keep_bytes=4)
        with pytest.raises(ValueError):
            restore_checkpoint(d, tree)


def test_retention_keeps_last_k():
    tree = {"w": jnp.ones(8, jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        for step in (1, 2, 3, 4, 5):
            save_checkpoint(d, jax.tree.map(lambda x: x * step, tree),
                            step=step, keep=2)
        paths = list_checkpoints(d)
        assert [os.path.basename(p) for p in paths] == [
            "ckpt_00000004.npz", "ckpt_00000005.npz"]
        # manifests pruned alongside payloads; LATEST still valid
        assert all(os.path.exists(p[:-4] + ".json") for p in paths)
        got, step, _ = restore_checkpoint(d, tree)
        assert step == 5


def test_async_writer_durable_and_ordered():
    tree = {"w": jnp.ones(8, jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        w = AsyncCheckpointWriter(d, keep=10)
        for step in (1, 2, 3):
            w.submit(jax.tree.map(lambda x: x * step, tree), step,
                     extra={"step": step})
        w.close()
        assert [os.path.basename(p) for p in list_checkpoints(d)] == [
            "ckpt_00000001.npz", "ckpt_00000002.npz", "ckpt_00000003.npz"]
        got, step, extra = restore_checkpoint(d, tree)
        assert step == 3 and extra == {"step": 3}
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.full(8, 3.0, np.float32))


def test_async_writer_propagates_worker_errors():
    tree = {"w": jnp.ones(4, jnp.float32)}
    with tempfile.TemporaryDirectory() as d:
        w = AsyncCheckpointWriter(d)
        with faults.crash_at_round(1):
            w.submit(tree, 1)
            with pytest.raises(faults.SimulatedPreemption):
                w.close()
        # the snapshot itself was durable before the hook fired
        got, step, _ = restore_checkpoint(d, tree)
        assert step == 1


def test_round_state_roundtrip():
    """save_round_state/restore_round_state carry the full engine carry:
    phi, pool arrays (int8 buffer included), bills, history, host RNG."""
    rng = np.random.default_rng(0)
    host_rng = np.random.default_rng(123)
    host_rng.integers(0, 10, size=5)               # advance it
    state = RoundState(
        round=12,
        phi={"w": jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)},
        pool_state={"buffer": jnp.asarray(
                        rng.integers(-128, 128, (2, 9)), jnp.int8),
                    "staleness": jnp.asarray([0, 3], jnp.int32)},
        per_client_bytes=[10, 20, 30],
        comm_bytes=60,
        history=[{"round": 4, "query_loss": 1.25}],
        host={"rng": host_rng.bit_generator.state},
        fingerprint={"seed": 5, "strategy": "TinyReptileStrategy"},
    )
    with tempfile.TemporaryDirectory() as d:
        save_round_state(d, state)
        got = restore_round_state(
            d, phi=state.phi,
            pool_state=state.pool_state,
            per_client_bytes=np.zeros(3, np.int64))
        assert got.round == 12 and got.comm_bytes == 60
        assert got.history == state.history
        assert got.fingerprint == state.fingerprint
        _assert_tree_equal(got.phi, state.phi)
        _assert_tree_equal(got.pool_state, state.pool_state)
        assert list(np.asarray(got.per_client_bytes)) == [10, 20, 30]
        restored = np.random.default_rng()
        restored.bit_generator.state = got.host["rng"]
        np.testing.assert_array_equal(restored.integers(0, 1000, 8),
                                      host_rng.integers(0, 1000, 8))


def test_mesh_sharded_tree_gathers_before_save():
    """A NamedSharding-sharded tree saves from a 4-device mesh and
    restores bit-exact in a fresh single-process template — snapshots
    must be topology-independent."""
    code = """
import tempfile
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.checkpoint import restore_checkpoint, save_checkpoint
assert jax.device_count() == 4
mesh = Mesh(np.array(jax.devices()), ("clients",))
rng = np.random.default_rng(7)
host = {"w": np.asarray(rng.normal(size=(8, 5)), np.float32),
        "buf": np.asarray(rng.integers(-128, 128, (4, 6)), np.int8)}
tree = {
    "w": jax.device_put(host["w"], NamedSharding(mesh, P("clients", None))),
    "buf": jax.device_put(host["buf"], NamedSharding(mesh, P("clients",))),
}
with tempfile.TemporaryDirectory() as d:
    save_checkpoint(d, tree, step=1)
    got, _, _ = restore_checkpoint(d, host)
    for k in host:
        assert np.asarray(got[k]).dtype == host[k].dtype
        np.testing.assert_array_equal(np.asarray(got[k]), host[k])
print("sharded save ok")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=REPO, timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "sharded save ok" in r.stdout
