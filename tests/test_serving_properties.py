"""Hypothesis property tests for the AdaptationServer's
continuous-batching queue (skip when hypothesis is absent, mirroring
tests/test_properties.py).

Invariants:

- CONSERVATION: every submitted request retires exactly once, having
  run exactly its k adaptation steps — across arbitrary slot counts,
  tick widths, and ragged k streams.
- NO STARVATION: the drain terminates within the analytic worst-case
  tick bound for ANY adversarial k distribution, and with one slot the
  FIFO admission order is the retirement order (nobody is overtaken
  while waiting).
- NO MASK LEAKAGE: a request's result does not depend on which
  companions share the batch (padded/retired slots never bleed into
  live ones).

The servers are cached per (slots, steps_per_tick) config and reset
between examples — which doubles as a re-assertion of the single-trace
contract under hundreds of adversarial streams.
"""
import math

import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st  # noqa: E402
import jax  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.models.paper_nets import relu_mlp_loss
from repro.serving import AdaptationServer, Fp32Adapter

SET = dict(deadline=None, max_examples=15, derandomize=True)
K_MAX, SUPPORT, QUERY = 6, 6, 4

# tiny 1-4-4-1 relu MLP: the queue invariants are model-independent,
# so the device work per example stays microscopic
_r = np.random.default_rng(0)
PHI = {"w0": np.float32(_r.normal(size=(1, 4)) * 0.5),
       "b0": np.zeros(4, np.float32),
       "w1": np.float32(_r.normal(size=(4, 4)) * 0.5),
       "b1": np.zeros(4, np.float32),
       "w2": np.float32(_r.normal(size=(4, 1)) * 0.5),
       "b2": np.zeros(1, np.float32)}
ADAPTER = Fp32Adapter(loss_fn=relu_mlp_loss, lr=0.01, use_pallas=False)

_SERVERS = {}


def server_for(slots, spt):
    """One cached server per config: hypothesis examples reuse the jit
    trace (and keep re-checking it stays at 1)."""
    key = (slots, spt)
    if key not in _SERVERS:
        _SERVERS[key] = AdaptationServer(
            jax.tree.map(np.asarray, PHI), ADAPTER, slots=slots,
            k_max=K_MAX, steps_per_tick=spt)
    srv = _SERVERS[key]
    srv.reset()
    return srv


def submit_stream(server, ks, seed=0):
    rng = np.random.default_rng(seed)
    rids = []
    for k in ks:
        sx = rng.uniform(-1, 1, (SUPPORT, 1)).astype(np.float32)
        sy = rng.uniform(-1, 1, (SUPPORT, 1)).astype(np.float32)
        qx = rng.uniform(-1, 1, (QUERY, 1)).astype(np.float32)
        qy = rng.uniform(-1, 1, (QUERY, 1)).astype(np.float32)
        rids.append(server.submit(sx, sy, qx, qy, k))
    return rids


ks_strategy = st.lists(st.integers(1, K_MAX), min_size=1, max_size=24)


@given(ks=ks_strategy, slots=st.integers(1, 4), spt=st.integers(1, 4))
@settings(**SET)
def test_request_conservation(ks, slots, spt):
    """Every admitted request retires exactly once, with exactly its
    requested number of adaptation steps."""
    server = server_for(slots, spt)
    rids = submit_stream(server, ks)
    results = server.drain()
    assert server.idle
    got = sorted(r.rid for r in results)
    assert got == sorted(rids)                      # exactly-once
    by_rid = {r.rid: r for r in results}
    for rid, k in zip(rids, ks):
        assert by_rid[rid].steps == k               # exactly k steps
    assert server.trace_count == 1


@given(ks=ks_strategy, slots=st.integers(1, 4), spt=st.integers(1, 4))
@settings(**SET)
def test_no_starvation_tick_bound(ks, slots, spt):
    """Adversarial ragged k cannot stall the queue: the drain finishes
    within the serial worst-case bound (every request admitted, run,
    and retired strictly one after another), and usually far under it.
    """
    server = server_for(slots, spt)
    submit_stream(server, ks)
    server.drain()
    bound = sum(math.ceil(k / spt) for k in ks) + len(ks) + 1
    assert server.ticks <= bound, (server.ticks, bound)


@given(ks=ks_strategy)
@settings(**SET)
def test_fifo_order_single_slot(ks):
    """With one slot the server is a pure FIFO: retirement order ==
    submission order (no request ever overtakes an earlier one)."""
    server = server_for(1, 2)
    rids = submit_stream(server, ks)
    results = server.drain()
    assert [r.rid for r in results] == rids


@given(ks=st.lists(st.integers(1, K_MAX), min_size=2, max_size=12),
       probe_k=st.integers(1, K_MAX))
@settings(**SET)
def test_no_cross_slot_leakage(ks, probe_k):
    """The probe request's query loss is companion-independent: served
    alone vs inside an adversarial ragged batch agree to fp32 vmap
    tolerance (the int8 route's exact-equality version lives in
    tests/test_serving.py)."""
    server = server_for(3, 2)
    rids = submit_stream(server, [probe_k] + ks, seed=7)
    together = {r.rid: r for r in server.drain()}[rids[0]]
    server.reset()
    submit_stream(server, [probe_k], seed=7)        # same rng -> same probe
    alone = server.drain()[0]
    np.testing.assert_allclose(together.query_loss, alone.query_loss,
                               rtol=1e-5, atol=1e-6)
    assert together.steps == alone.steps == probe_k
