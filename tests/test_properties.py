"""Hypothesis property tests on system invariants.

The suite must collect from a clean environment, so `hypothesis` is an
optional dependency: these tests skip (not error) when it is absent.
"""
import math

import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from hypothesis import given, settings  # noqa: E402

from repro.core.meta import tree_lerp
from repro.kernels import ops
from repro.models.moe import capacity
from repro.models.transformer import chunked_cross_entropy, find_period
from repro.optim.schedules import cosine, linear_anneal, wsd

SET = dict(deadline=None, max_examples=20, derandomize=True)


@given(st.integers(1, 400), st.floats(0.0, 1.0))
@settings(**SET)
def test_meta_update_convexity(n, alpha):
    """phi' lies on the segment [phi, phi_hat]; endpoints exact."""
    r = np.random.default_rng(n)
    w = jnp.asarray(r.normal(size=n), jnp.float32)
    wh = jnp.asarray(r.normal(size=n), jnp.float32)
    out = ops.meta_update(w, wh, alpha)
    lo = jnp.minimum(w, wh) - 1e-5
    hi = jnp.maximum(w, wh) + 1e-5
    assert bool(((out >= lo) & (out <= hi)).all())
    # endpoints: alpha=0 exact; alpha=1 only up to fp32 cancellation in
    # w + (wh - w)
    np.testing.assert_array_equal(ops.meta_update(w, wh, 0.0), w)
    np.testing.assert_allclose(ops.meta_update(w, wh, 1.0), wh,
                               rtol=1e-5, atol=1e-6)


@given(st.integers(0, 1000))
@settings(**SET)
def test_kernel_tree_update_matches_tree_lerp(seed):
    r = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(r.normal(size=(3, 7)), jnp.float32),
            "b": [jnp.asarray(r.normal(size=11), jnp.float32)]}
    tree2 = jax.tree.map(lambda x: x + 1.0, tree)
    got = ops.tree_meta_update(tree, tree2, 0.25)
    want = tree_lerp(tree, tree2, 0.25)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@given(st.integers(1, 64), st.integers(1, 8), st.integers(1, 128))
@settings(**SET)
def test_moe_capacity_invariants(tokens, k, experts):
    c = capacity(tokens, k, experts)
    assert c % 8 == 0
    assert c * experts >= tokens * k  # enough slots for cf >= 1


@given(st.integers(2, 6), st.integers(1, 4), st.integers(5, 40))
@settings(**SET)
def test_chunked_ce_matches_full(b, nch, vocab):
    r = np.random.default_rng(b * 100 + nch)
    S, d = nch * 4, 16
    x = jnp.asarray(r.normal(size=(b, S, d)), jnp.float32)
    w = jnp.asarray(r.normal(size=(d, vocab)), jnp.float32)
    labels = jnp.asarray(r.integers(0, vocab, size=(b, S)), jnp.int32)
    full = chunked_cross_entropy(x, w, labels, chunk=S)
    chunked = chunked_cross_entropy(x, w, labels, chunk=4)
    np.testing.assert_allclose(full, chunked, rtol=1e-5, atol=1e-6)


@given(st.integers(0, 100))
@settings(**SET)
def test_chunked_ce_ignores_masked(seed):
    r = np.random.default_rng(seed)
    b, S, d, vocab = 2, 8, 8, 13
    x = jnp.asarray(r.normal(size=(b, S, d)), jnp.float32)
    w = jnp.asarray(r.normal(size=(d, vocab)), jnp.float32)
    labels = jnp.asarray(r.integers(0, vocab, size=(b, S)), jnp.int32)
    masked = labels.at[:, -3:].set(-1)
    base = chunked_cross_entropy(x[:, :-3], w, labels[:, :-3], chunk=4)
    got = chunked_cross_entropy(x, w, masked, chunk=4)
    np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-6)


@given(st.lists(st.sampled_from(["a", "b", "c"]), min_size=1, max_size=24))
@settings(**SET)
def test_find_period_minimal_and_correct(pattern):
    specs = [(k, 0) for k in pattern]
    p = find_period(specs)
    assert len(specs) % p == 0
    assert specs == specs[:p] * (len(specs) // p)
    for q in range(1, p):
        assert not (len(specs) % q == 0
                    and specs == specs[:q] * (len(specs) // q))


@given(st.floats(1e-5, 1.0), st.integers(10, 1000))
@settings(**SET)
def test_schedules_bounded(lr, total):
    for sched in (wsd(lr, total), cosine(lr, total, warmup=total // 10),
                  linear_anneal(lr, total)):
        for step in (0, 1, total // 2, total - 1, total):
            v = float(sched(step))
            assert 0.0 <= v <= lr * (1 + 1e-6), (sched, step, v)


@given(st.integers(0, 50))
@settings(**SET)
def test_wsd_shape(seed):
    """WSD: warmup rises, plateau constant at lr, decay falls."""
    lr, total = 0.01, 1000
    s = wsd(lr, total)
    assert float(s(0)) < float(s(9))                # warmup rising
    assert abs(float(s(500)) - lr) < 1e-9           # stable plateau
    assert float(s(999)) < lr                       # decaying tail


_LEAVES = ["embed", "lm_head", "wq", "wk", "wv", "wo", "w_gate", "w_up",
           "w_down", "w_z", "w_B", "conv_w", "norm1"]


@given(st.sampled_from(_LEAVES),
       st.lists(st.sampled_from([1, 2, 3, 8, 16, 40, 128, 640, 2048]),
                min_size=1, max_size=4),
       st.booleans())
@settings(**SET)
def test_sharding_specs_always_divide(leaf, dims, multi_pod):
    """param_spec never produces uneven sharding, on either mesh."""
    from jax.sharding import AbstractMesh
    from repro.runtime.sharding import param_spec, _size
    mesh = (AbstractMesh((2, 16, 16), ("pod", "data", "model")) if multi_pod
            else AbstractMesh((16, 16), ("data", "model")))
    path = f"layers/0/attn/{leaf}" if leaf.startswith("w") else leaf
    spec = param_spec(path, tuple(dims), mesh)
    for dim, ax in zip(dims, spec):
        if ax is not None:
            assert dim % _size(mesh, ax) == 0, (leaf, dims, spec)


@given(st.integers(0, 30))
@settings(**SET)
def test_checkpoint_roundtrip(seed):
    import tempfile
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    r = np.random.default_rng(seed)
    tree = {"w": jnp.asarray(r.normal(size=(4, 5)), jnp.float32),
            "nested": {"b": jnp.asarray(r.normal(size=7), jnp.float32)},
            "stack": [jnp.asarray(r.integers(0, 9, size=3), jnp.int32)]}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, tree, step=seed, extra={"k": 1})
        got, step, extra = restore_checkpoint(d, tree)
        assert step == seed and extra == {"k": 1}
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(a, b)
