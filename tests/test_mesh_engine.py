"""Multi-device execution of the mesh-sharded round engine (PR 5).

Runs in SUBPROCESSES with XLA_FLAGS=--xla_force_host_platform_device_count=8
(the test_launch.py pattern) so the forced host-device topology never
leaks into the rest of the suite. Covers the tentpole contracts:

- seeded 1-vs-8-device parity for all five strategies, including an
  uneven cohort (padded to the shard multiple via the validity/
  participation masks) — training trajectory, eval history, and the
  exact integer transport bills;
- pooled mesh runs: identity state (last_seen/staleness/checkins)
  EXACTLY equal to the 1-device run, FedBuff buffered aggregation with
  the per-shard buffer reduced across shards at flush, availability
  processes, and per-client bills summed across shards;
- one jit trace per (strategy, beta, channel, schedule-shape,
  pool-shape, mesh) config across uneven eval blocks;
- the runner cache under changed device topology: a 4-device and an
  8-device mesh are distinct cache keys (a stale trace can never be
  served), counted by runner_cache_stats()["mesh_entries"];
- mesh argument resolution (int / "auto" / explicit Mesh) and
  validation.
"""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_PRELUDE = """
import functools
import jax, numpy as np
from repro.configs.paper_models import SINE_MLP
from repro.core import (BufferedAggregation, ClientPool, CommChannel,
                        DiurnalAvailability, PartialParticipation,
                        UniformSampling, clear_runner_cache, client_mesh,
                        run_federated, runner_cache_stats)
from repro.core.engine import _block_runner
from repro.core.strategies import (FedAvgStrategy, FedSGDStrategy,
                                   ReptileStrategy, TifedStrategy,
                                   TinyReptileStrategy, TransferStrategy)
from repro.core.tifed import tifed_train
from repro.data import SineTasks
from repro.models.paper_nets import (init_paper_model, paper_model_loss,
                                     relu_mlp_loss)

LOSS = functools.partial(paper_model_loss, SINE_MLP)
EVAL = dict(num_tasks=2, support=4, k_steps=2, lr=0.02, query=8)
params = init_paper_model(SINE_MLP, jax.random.PRNGKey(0))
dist = SineTasks()

def assert_close(a, b, tol=3e-4):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=tol, atol=tol)
"""


def _run(code: str, devices: int = 8):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", _PRELUDE + code],
                       capture_output=True, text=True, env=env, cwd=REPO,
                       timeout=500)
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_mesh_parity_all_five_strategies():
    """1-device vs 8-device seeded parity for every strategy, with an
    UNEVEN cohort (5 and 6 slots pad to 8); metered strategies must
    agree on the exact integer transport bills, and every sharded
    config must trace exactly once across uneven eval blocks."""
    out = _run("""
cases = [
    (TinyReptileStrategy(LOSS, use_pallas=None), dict(clients_per_round=5)),
    (ReptileStrategy(LOSS, epochs=2, use_pallas=None),
     dict(clients_per_round=6)),
    (FedAvgStrategy(LOSS, epochs=2), dict(clients_per_round=6)),
    (FedSGDStrategy(LOSS), dict(clients_per_round=5)),
    (TransferStrategy(LOSS), dict(clients_per_round=6)),
]
mesh = client_mesh(8)
clear_runner_cache()
for i, (strategy, kw) in enumerate(cases):
    beta = 0.02 + 1e-4 * i
    base = dict(rounds=7, beta=beta, support=6, seed=3, eval_every=3,
                eval_kwargs=EVAL, **kw)
    flat = run_federated(params, dist, strategy, **base)
    shrd = run_federated(params, dist, strategy, mesh=mesh, **base)
    assert_close(flat["params"], shrd["params"])
    assert len(flat["history"]) == len(shrd["history"])
    for fe, se in zip(flat["history"], shrd["history"]):
        np.testing.assert_allclose(fe["query_loss"], se["query_loss"],
                                   rtol=1e-3, atol=1e-4)
    if strategy.meters_comm:
        assert flat["comm_bytes"] == shrd["comm_bytes"]
        assert flat["per_client_bytes"] == shrd["per_client_bytes"]
        assert sum(shrd["per_client_bytes"]) == shrd["comm_bytes"]
    runner = _block_runner(strategy, beta, CommChannel(), scheduled=True,
                           mesh=mesh, masked=False)
    assert runner.trace_count == 1, (type(strategy).__name__,
                                     runner.trace_count)
print("five-strategy parity ok")
""")
    assert "five-strategy parity ok" in out


def test_mesh_parity_tifed_int8():
    """tifed (PR 6) on the client mesh: the int8 result trees shard and
    psum-aggregate like the fp32 strategies — 1-vs-8-device seeded
    parity on params, eval history, and the exact int8 transport bill,
    at one jit trace for the sharded config."""
    out = _run("""
S = TifedStrategy(relu_mlp_loss, epochs=8)
ch = CommChannel("int8", quantize=False)
mesh = client_mesh(8)
clear_runner_cache()
TEVAL = dict(num_tasks=2, support=4, k_steps=2, lr=0.01, query=8)
kw = dict(rounds=7, beta=0.0, support=16, seed=3, clients_per_round=8,
          eval_every=3, eval_kwargs=TEVAL, channel=ch)
flat = run_federated(params, dist, S, **kw)
shrd = run_federated(params, dist, S, mesh=mesh, **kw)
assert_close(flat["params"], shrd["params"])
assert len(flat["history"]) == len(shrd["history"]) == 2
for fe, se in zip(flat["history"], shrd["history"]):
    np.testing.assert_allclose(fe["query_loss"], se["query_loss"],
                               rtol=1e-3, atol=1e-4)
assert flat["comm_bytes"] == shrd["comm_bytes"]
n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
assert shrd["comm_bytes"] == 2 * 8 * 7 * n      # 1 byte/param, both ways
runner = _block_runner(S, 0.0, ch, scheduled=True, mesh=mesh,
                       masked=False)
assert runner.trace_count == 1, runner.trace_count
print("tifed mesh parity ok")
""")
    assert "tifed mesh parity ok" in out


def test_mesh_pooled_buffered_and_availability():
    """Pooled mesh runs: integer identity state exactly equals the
    1-device run (the shard-local scatter is exact), FedBuff flush
    counts/pending match (the per-shard buffer reduces across shards at
    flush), availability troughs stay no-ops, and pooled bills sum
    across shards to the total."""
    out = _run("""
S = TinyReptileStrategy(LOSS, use_pallas=None)
mesh = client_mesh(8)
kw = dict(rounds=11, beta=0.02, support=4, seed=6, eval_every=4,
          eval_kwargs=EVAL, clients_per_round=3)

# pool of 7 pads to 8 state rows; partial participation skips clients
for case_kw in (dict(sampling=PartialParticipation(0.5)),
                dict(buffered=BufferedAggregation(4)),
                dict(buffered=BufferedAggregation(100, flush_staleness=2)),
                dict(sampling=DiurnalAvailability(period=4))):
    flat = run_federated(params, dist, S, pool=ClientPool(dist, 7),
                         **case_kw, **kw)
    shrd = run_federated(params, dist, S, pool=ClientPool(dist, 7),
                         mesh=mesh, **case_kw, **kw)
    for k in ("last_seen", "staleness", "checkins"):
        np.testing.assert_array_equal(flat["pool_state"][k],
                                      shrd["pool_state"][k])
        assert len(shrd["pool_state"][k]) == 7   # pad rows sliced off
    assert_close(flat["params"], shrd["params"])
    assert flat["per_client_bytes"] == shrd["per_client_bytes"]
    assert sum(shrd["per_client_bytes"]) == shrd["comm_bytes"]
    if "buffered" in case_kw:
        assert (flat["pool_state"]["flushes"]
                == shrd["pool_state"]["flushes"])
        assert (flat["pool_state"]["buffered_pending"]
                == shrd["pool_state"]["buffered_pending"])
print("pooled mesh parity ok")
""")
    assert "pooled mesh parity ok" in out


def test_mesh_cache_topology_and_resolution():
    """A runner traced for one device topology is never served for
    another: 4- and 8-device meshes are distinct cache keys, counted by
    mesh_entries and dropped by clear_runner_cache. mesh=int / "auto"
    resolve through client_mesh; non-"clients" meshes are rejected."""
    out = _run("""
from jax.sharding import Mesh
S = TinyReptileStrategy(LOSS, use_pallas=None)
clear_runner_cache()
r8 = _block_runner(S, 0.05, CommChannel(), scheduled=True,
                   mesh=client_mesh(8))
r4 = _block_runner(S, 0.05, CommChannel(), scheduled=True,
                   mesh=client_mesh(4))
assert r8 is not r4                       # changed topology: fresh trace
assert runner_cache_stats()["mesh_entries"] == 2
# an equal topology hits the same entry (Mesh hashes by devices+axes)
assert _block_runner(S, 0.05, CommChannel(), scheduled=True,
                     mesh=client_mesh(8)) is r8
clear_runner_cache()
assert runner_cache_stats()["mesh_entries"] == 0

# resolution: int and "auto" build client meshes; results agree
kw = dict(rounds=4, clients_per_round=4, beta=0.02, support=4, seed=1)
a = run_federated(params, dist, S, mesh=4, **kw)
b = run_federated(params, dist, S, mesh=client_mesh(4), **kw)
assert_close(a["params"], b["params"], tol=0.0)   # same mesh: bitwise
c = run_federated(params, dist, S, mesh="auto", **kw)
for l in jax.tree.leaves(c["params"]):
    assert np.isfinite(np.asarray(l)).all()
try:
    run_federated(params, dist, S, mesh=Mesh(np.array(jax.devices()),
                                             ("data",)), **kw)
    raise SystemExit("bad mesh accepted")
except ValueError as e:
    assert "clients" in str(e)
try:
    client_mesh(99)
    raise SystemExit("too many devices accepted")
except ValueError:
    pass
print("cache topology ok")
""")
    assert "cache topology ok" in out
