"""Substrate tests: optimizers, schedules, data pipeline, checkpointing,
metering, step builders."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import KWSTasks, LMClientStream, OmniglotTasks, SineTasks
from repro.metering import algorithm_memory_report
from repro.optim import adamw, sgd, wsd


def _quad_problem():
    target = jnp.asarray([1.0, -2.0, 3.0])
    def loss(p):
        return jnp.sum(jnp.square(p - target))
    return target, loss


def test_sgd_converges():
    target, loss = _quad_problem()
    opt = sgd()
    p = jnp.zeros(3)
    state = opt.init(p)
    for _ in range(200):
        g = jax.grad(loss)(p)
        p, state = opt.update(g, state, p, 0.1)
    np.testing.assert_allclose(p, target, atol=1e-3)


def test_sgd_momentum_converges():
    target, loss = _quad_problem()
    opt = sgd(momentum=0.9)
    p = jnp.zeros(3)
    state = opt.init(p)
    for _ in range(200):
        g = jax.grad(loss)(p)
        p, state = opt.update(g, state, p, 0.02)
    np.testing.assert_allclose(p, target, atol=1e-3)


def test_adamw_converges():
    target, loss = _quad_problem()
    opt = adamw()
    p = jnp.zeros(3)
    state = opt.init(p)
    for _ in range(500):
        g = jax.grad(loss)(p)
        p, state = opt.update(g, state, p, 0.05)
    np.testing.assert_allclose(p, target, atol=1e-2)


def test_adamw_weight_decay_shrinks():
    opt = adamw(weight_decay=0.1)
    p = jnp.ones(4) * 5.0
    state = opt.init(p)
    g = jnp.zeros(4)
    p2, _ = opt.update(g, state, p, 0.1)
    assert float(jnp.abs(p2).max()) < 5.0


def test_data_determinism_and_heterogeneity():
    dist = SineTasks()
    t1 = dist.sample_task(np.random.default_rng(0))
    t2 = dist.sample_task(np.random.default_rng(0))
    b1 = t1.support_batch(np.random.default_rng(1), 8)
    b2 = t2.support_batch(np.random.default_rng(1), 8)
    np.testing.assert_array_equal(b1["x"], b2["x"])  # deterministic
    t3 = dist.sample_task(np.random.default_rng(5))
    b3 = t3.support_batch(np.random.default_rng(1), 8)
    assert not np.allclose(b1["y"], b3["y"])         # heterogeneous


@pytest.mark.parametrize("dist_cls,shape", [
    (OmniglotTasks, (28, 28, 1)), (KWSTasks, (49, 10, 1))])
def test_classification_tasks_shapes(dist_cls, shape):
    dist = dist_cls()
    task = dist.sample_task(np.random.default_rng(0))
    b = task.support_batch(np.random.default_rng(1), 6)
    assert b["x"].shape == (6,) + shape
    assert b["y"].min() >= 0 and b["y"].max() < dist.ways
    # stream view yields identical structure one sample at a time
    stream = list(task.support_stream(np.random.default_rng(1), 6))
    assert len(stream) == 6
    np.testing.assert_array_equal(stream[0][0], b["x"][0])


def test_lm_client_streams_distinct():
    s1 = LMClientStream(1000, client_id=1)
    s2 = LMClientStream(1000, client_id=2)
    b1 = s1.batch(np.random.default_rng(0), 2, 64)
    b2 = s2.batch(np.random.default_rng(0), 2, 64)
    assert b1["tokens"].shape == (2, 64)
    assert (b1["tokens"] != b2["tokens"]).mean() > 0.5  # different domains
    assert b1["labels"][0, -1] == -1                    # tail masked


def test_memory_report_matches_paper_structure():
    from repro.configs.paper_models import OMNIGLOT_CONV, SINE_MLP
    sine = algorithm_memory_report(SINE_MLP, support=32)
    omni = algorithm_memory_report(OMNIGLOT_CONV, support=32)
    assert sine["params"] == 1153
    # paper: only the sine model trains on the 256-KB Arduino
    assert sine["fits_arduino_256kb_tinyreptile"]
    assert not omni["fits_arduino_256kb_reptile"]
    assert omni["reduction_factor"] >= 2.0


def test_microbatch_reshape():
    from repro.runtime.steps import microbatch
    b = {"tokens": jnp.arange(24).reshape(8, 3)}
    mb = microbatch(b, 4)
    assert mb["tokens"].shape == (4, 2, 3)
    np.testing.assert_array_equal(mb["tokens"].reshape(8, 3), b["tokens"])


def test_joint_train_step_runs():
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.optim import adamw, constant
    from repro.runtime.steps import make_joint_train_step
    cfg = get_arch("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw()
    step = make_joint_train_step(model, opt, constant(1e-3))
    state = opt.init(params)
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (2, 16), 0, cfg.vocab_size)}
    p2, s2, n, metrics = jax.jit(step)(params, state, jnp.int32(0), batch)
    assert jnp.isfinite(metrics["loss"])
    assert int(n) == 1


def test_meta_step_interpolation_semantics():
    """alpha=0 -> params unchanged; alpha=1 -> params = inner result."""
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.runtime.steps import make_meta_train_step, microbatch
    cfg = get_arch("tinyllama-1.1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = microbatch(
        {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (2, 16), 0, cfg.vocab_size)}, 2)
    frozen, _ = jax.jit(make_meta_train_step(model, beta=0.01, alpha=0.0))(
        params, batch)
    for a, b in zip(jax.tree.leaves(frozen), jax.tree.leaves(params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)
