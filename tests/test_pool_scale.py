"""Fleet-scale ClientPool (PR 8): counter-derived identity, bounded
host caches, host-resident identity slabs, and the O(cohort) samplers.

Everything here runs in-process (no forced device topology): the
mesh-sharded and cross-host variants of the same contracts live in
tests/test_mesh_engine.py and tests/test_distributed.py.
"""
import functools
import json

import jax
import numpy as np
import pytest

from repro.configs.paper_models import SINE_MLP
from repro.core import (BufferedAggregation, ClientPool,
                        DiurnalAvailability, MarkovAvailability,
                        run_federated)
from repro.core.pipeline import seat_cohorts
from repro.core.pool import _MAX_TEMPLATES, AvailabilityProcess
from repro.core.strategies import ReptileStrategy, TinyReptileStrategy
from repro.data import KWSTasks, OmniglotTasks, SineTasks, TaskDistribution
from repro.metering.memory import MemoryMeter
from repro.models.paper_nets import init_paper_model, paper_model_loss

LOSS = functools.partial(paper_model_loss, SINE_MLP)
PARAMS = init_paper_model(SINE_MLP, jax.random.PRNGKey(0))
BIG = 1_000_000


def _rngs(seed, i, k):
    # the counter-sampler stream contract: task from (seed, i), data
    # from (seed, i, k) — mirrors pool.py's stream constants
    return (np.random.default_rng([seed, 0x9E37, i]),
            np.random.default_rng([seed, 0x5EED, i, k]))


# ---------------------------------------------------------------- identity

def test_sine_support_override_matches_generic_fallback():
    """SineTasks.sample_client_support (the closed-form fast path) is
    BIT-equal to TaskDistribution's materialize-and-replay fallback for
    both data modes."""
    dist = SineTasks()
    for mode in ("batch", "stream"):
        for i, k in ((0, 0), (3, 2), (BIG - 1, 7)):
            x1, y1 = dist.sample_client_support(*_rngs(5, i, k), 6,
                                                data_mode=mode)
            x2, y2 = TaskDistribution.sample_client_support(
                dist, *_rngs(5, i, k), 6, data_mode=mode)
            np.testing.assert_array_equal(x1, x2)
            np.testing.assert_array_equal(y1, y2)


@pytest.mark.parametrize("dist", [OmniglotTasks(), KWSTasks()],
                         ids=["omniglot", "kws"])
def test_classification_support_overrides(dist):
    """The classification block overrides draw deterministic,
    well-shaped support sets whose labels match the generic fallback's
    task (same class subset from the same task stream)."""
    xa, ya = dist.sample_client_support(*_rngs(1, 4, 2), 5)
    xb, yb = dist.sample_client_support(*_rngs(1, 4, 2), 5)
    np.testing.assert_array_equal(xa, xb)
    np.testing.assert_array_equal(ya, yb)
    xg, yg = TaskDistribution.sample_client_support(dist, *_rngs(1, 4, 2),
                                                    5)
    assert xa.shape == xg.shape and xa.dtype == xg.dtype
    assert ya.shape == yg.shape and ya.dtype == yg.dtype
    assert set(np.unique(ya)) <= set(range(dist.ways))
    xc, _ = dist.sample_client_support(*_rngs(1, 4, 3), 5)
    assert not np.array_equal(xa, xc)        # fresh draw per check-in


def test_counter_sampler_advances_per_checkin():
    """Block sampling under sampler='vectorized': participating slots
    draw client-and-check-in-keyed data (repeat check-ins differ,
    replays are exact), scheduled-out slots stay zero, and NO per-client
    host objects accrete."""
    dist = SineTasks()
    pool = ClientPool(dist, 50, seed=2, sampler="vectorized")
    cohort = np.array([[3, 7], [3, 9]])
    part = np.array([[True, False], [True, True]])
    b1 = pool.sample_cohort_block(cohort, part, 4)
    assert np.all(b1["x"][0, 1] == 0) and np.all(b1["y"][0, 1] == 0)
    assert not np.array_equal(b1["x"][0, 0], b1["x"][1, 0])  # k=0 vs k=1
    np.testing.assert_array_equal(pool._checkins[[3, 7, 9]], [2, 0, 1])
    assert len(pool._rngs) == 0
    # the draws are pure functions of (seed, client, k)
    x, y = dist.sample_client_support(*_rngs(2, 3, 1), 4)
    np.testing.assert_array_equal(b1["x"][1, 0], x)
    np.testing.assert_array_equal(b1["y"][1, 0], y)
    fresh = ClientPool(dist, 50, seed=2, sampler="vectorized")
    r1 = fresh.sample_cohort_block(cohort, part, 4)
    np.testing.assert_array_equal(b1["x"], r1["x"])


def test_host_state_roundtrip_at_million_clients():
    """At N=10^6 the vectorized pool's whole mutable host state is the
    nonzero check-in counters: the snapshot is tiny and JSON-able, and
    a fresh pool restored from it reproduces the next block
    bit-for-bit."""
    dist = SineTasks()
    pool = ClientPool(dist, BIG, seed=9, sampler="vectorized")
    rng = np.random.default_rng(0)
    cohort = seat_cohorts(rng, BIG, 256, 4)
    part = np.ones(cohort.shape, bool)
    pool.sample_cohort_block(cohort, part, 2)
    snap = pool.host_state()
    assert set(snap) == {"checkins"}
    assert len(snap["checkins"]) <= 4 * 256          # O(cohort), not O(N)
    assert len(json.dumps(snap)) < 64 * 1024
    fresh = ClientPool(dist, BIG, seed=9, sampler="vectorized")
    fresh.load_host_state(json.loads(json.dumps(snap)))
    nxt = seat_cohorts(rng, BIG, 256, 1)
    a = pool.sample_cohort_block(nxt, np.ones(nxt.shape, bool), 2)
    b = fresh.sample_cohort_block(nxt, np.ones(nxt.shape, bool), 2)
    np.testing.assert_array_equal(a["x"], b["x"])
    np.testing.assert_array_equal(a["y"], b["y"])


def test_host_state_cross_format_rejected():
    dist = SineTasks()
    vec = ClientPool(dist, 4, sampler="vectorized")
    ref = ClientPool(dist, 4, sampler="reference")
    ref.sample_cohort_block(np.array([[1]]), np.array([[True]]), 2)
    vec.sample_cohort_block(np.array([[1]]), np.array([[True]]), 2)
    with pytest.raises(ValueError, match="legacy per-client rng"):
        vec.load_host_state(ref.host_state())
    with pytest.raises(ValueError, match="counter snapshot"):
        ref.load_host_state(vec.host_state())
    with pytest.raises(ValueError, match="out of range"):
        vec.load_host_state({"checkins": {"99": 1}})


def test_constructor_validation():
    dist = SineTasks()
    with pytest.raises(ValueError, match="sampler"):
        ClientPool(dist, 4, sampler="bogus")
    with pytest.raises(ValueError, match="residency"):
        ClientPool(dist, 4, residency="gpu")
    with pytest.raises(ValueError, match="mmap_dir"):
        ClientPool(dist, 4, mmap_dir="/tmp/x")
    with pytest.raises(ValueError, match="max_cached_tasks"):
        ClientPool(dist, 4, max_cached_tasks=0)


def test_host_caches_stay_bounded():
    """A long-lived vectorized pool touching MANY distinct clients keeps
    O(1) host objects: the task LRU respects max_cached_tasks, no
    per-client generators exist, and the shape-template cache is capped
    — the regression gate for the legacy O(N)-dicts liability."""
    dist = SineTasks()
    pool = ClientPool(dist, 100_000, seed=1, sampler="vectorized",
                      max_cached_tasks=32)
    rng = np.random.default_rng(3)
    for blk in range(6):
        cohort = seat_cohorts(rng, 100_000, 64, 4)
        pool.sample_cohort_block(cohort, np.ones(cohort.shape, bool), 2)
        for s in range(blk + 2):
            pool._template(s + 1, "batch")
    assert len(pool._tasks) <= 32
    assert len(pool._rngs) == 0
    assert len(pool._templates) <= _MAX_TEMPLATES
    # the reference pool on the same workload accretes one generator
    # per distinct client ever seated — the liability being removed
    ref = ClientPool(dist, 100_000, seed=1)
    cohort = seat_cohorts(np.random.default_rng(3), 100_000, 64, 4)
    ref.sample_cohort_block(cohort, np.ones(cohort.shape, bool), 2)
    assert len(ref._rngs) == len(np.unique(cohort))


# ---------------------------------------------------------------- seating

def test_seat_cohorts_sparse_and_dense():
    """seat_cohorts: unique in-range seats per round in both regimes
    (rejection sampling at cohort << pool, plain without-replacement
    choice when dense), deterministic in the rng stream."""
    for pool_size, clients in ((BIG, 256), (40, 11), (8, 8)):
        out = seat_cohorts(np.random.default_rng(7), pool_size, clients,
                           5)
        assert out.shape == (5, clients)
        assert out.min() >= 0 and out.max() < pool_size
        for r in range(5):
            assert len(set(out[r].tolist())) == clients
        again = seat_cohorts(np.random.default_rng(7), pool_size,
                             clients, 5)
        np.testing.assert_array_equal(out, again)


def test_vectorized_availability_seating():
    """The loop-free availability seating keeps the reference LAYOUT:
    sorted ascending cohort ids packed into the leading slots, False
    tail, every seated client actually available, capped at the cohort
    width."""
    rng = np.random.default_rng(4)
    avail = np.random.default_rng(0).uniform(size=(6, 500)) < 0.3
    avail[2] = False                                  # a trough round
    cohort, part = AvailabilityProcess._seat_available_block(rng, avail,
                                                             8)
    assert cohort.shape == part.shape == (6, 8)
    assert not part[2].any() and not cohort[2].any()
    for r in (0, 1, 3, 4, 5):
        m = int(part[r].sum())
        assert m == min(8, int(avail[r].sum()))
        assert part[r, :m].all() and not part[r, m:].any()
        seats = cohort[r, :m]
        assert np.all(np.diff(seats) > 0)             # sorted, unique
        assert avail[r, seats].all()
        assert not cohort[r, m:].any()


def test_diurnal_parameter_validation():
    DiurnalAvailability(base=0.0, amplitude=1.0, phase_spread=1.0)
    with pytest.raises(ValueError, match="base"):
        DiurnalAvailability(base=1.5)
    with pytest.raises(ValueError, match="amplitude"):
        DiurnalAvailability(amplitude=-0.1)
    with pytest.raises(ValueError, match="phase_spread"):
        DiurnalAvailability(phase_spread=2.0)
    with pytest.raises(ValueError, match="sampler"):
        DiurnalAvailability(sampler="bogus")


# -------------------------------------------------------------- residency

def _run(pool, rounds=8, **kw):
    base = dict(rounds=rounds, clients_per_round=3, beta=0.02, support=4,
                seed=5, eval_every=4,
                eval_kwargs=dict(num_tasks=2, support=4, k_steps=2,
                                 lr=0.02, query=8))
    base.update(kw)
    return run_federated(PARAMS, SineTasks(), TinyReptileStrategy(LOSS),
                         pool=pool, **base)


def _assert_same(a, b):
    for x, y in zip(jax.tree.leaves(a["params"]),
                    jax.tree.leaves(b["params"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for k in ("last_seen", "staleness", "checkins"):
        np.testing.assert_array_equal(a["pool_state"][k],
                                      b["pool_state"][k])
    assert a["per_client_bytes"] == b["per_client_bytes"]
    assert [h["query_loss"] for h in a["history"]] == \
        [h["query_loss"] for h in b["history"]]


@pytest.mark.parametrize("sampler", ["reference", "vectorized"])
def test_host_residency_parity(sampler):
    """residency='host' (cohort-windowed identity staged from host
    slabs) is BIT-for-bit the device-resident run — params, identity
    state, bills, eval — for both samplers, with FedBuff buffering."""
    dist = SineTasks()
    kw = dict(buffered=BufferedAggregation(4))
    dev = _run(ClientPool(dist, 9, seed=5, sampler=sampler), **kw)
    hst = _run(ClientPool(dist, 9, seed=5, sampler=sampler,
                          residency="host"), **kw)
    _assert_same(dev, hst)


def test_host_residency_mmap_and_availability(tmp_path):
    """File-backed (np.memmap) slabs and an availability process on the
    vectorized sampler reproduce the in-RAM host-resident run exactly;
    the slab files exist on disk."""
    dist = SineTasks()
    kw = dict(sampling=DiurnalAvailability(period=4,
                                           sampler="vectorized"))
    ram = _run(ClientPool(dist, 9, seed=5, sampler="vectorized",
                          residency="host"), **kw)
    mm = _run(ClientPool(dist, 9, seed=5, sampler="vectorized",
                         residency="host", mmap_dir=str(tmp_path)), **kw)
    _assert_same(ram, mm)
    assert sorted(p.name for p in tmp_path.iterdir()) == [
        "pool_checkins.i32", "pool_last_seen.i32", "pool_staleness.i32"]


def test_resume_parity_across_residencies(tmp_path):
    """A host-resident vectorized run snapshots the FULL identity
    layout: an interrupted run resumes bit-for-bit — even when the
    resuming pool is DEVICE-resident (checkpoints are
    residency-portable) — against the uninterrupted run. anneal=False:
    the alpha schedule is horizon-dependent."""
    dist = SineTasks()

    def pool(residency):
        return ClientPool(dist, 9, seed=5, sampler="vectorized",
                          residency=residency)

    kw = dict(buffered=BufferedAggregation(4), anneal=False)
    full = _run(pool("host"), rounds=12, **kw)
    d = str(tmp_path / "ck")
    _run(pool("host"), rounds=6, ckpt_dir=d, ckpt_every=3, **kw)
    for residency in ("host", "device"):
        resumed = _run(pool(residency), rounds=12, ckpt_dir=d,
                       ckpt_every=3, resume=True, **kw)
        _assert_same(full, resumed)


def test_pool_sampler_resume_mismatch_rejected(tmp_path):
    """The checkpoint fingerprint pins the pool's sampler: resuming a
    vectorized run with a reference pool (a different identity stream)
    is rejected instead of silently diverging."""
    dist = SineTasks()
    d = str(tmp_path / "ck")
    _run(ClientPool(dist, 9, seed=5, sampler="vectorized"), rounds=6,
         ckpt_dir=d, ckpt_every=3, anneal=False)
    with pytest.raises(ValueError, match="pool_sampler"):
        _run(ClientPool(dist, 9, seed=5), rounds=12, ckpt_dir=d,
             ckpt_every=3, resume=True, anneal=False)


def test_million_client_pool_end_to_end():
    """The headline contract: a pooled run over N=10^6 persistent
    clients (vectorized sampler, host-resident slabs) trains rounds,
    reports the full-size identity arrays, and keeps per-round host
    work O(cohort): the block draws touch only seated clients and the
    compact snapshot stays cohort-sized."""
    dist = SineTasks()
    pool = ClientPool(dist, BIG, seed=5, sampler="vectorized",
                      residency="host", max_cached_tasks=64)
    meter = MemoryMeter()
    out = _run(pool, rounds=4, clients_per_round=8, eval_every=0)
    rep = meter.report()
    assert rep["host_baseline_bytes"] >= 0        # meter wiring smoke
    st = out["pool_state"]
    assert st["last_seen"].shape == (BIG,)
    seated = np.flatnonzero(st["checkins"])
    assert 0 < len(seated) <= 4 * 8
    np.testing.assert_array_equal(
        np.sort(seated), np.sort(np.flatnonzero(pool._checkins)))
    assert len(pool._tasks) <= 64 and len(pool._rngs) == 0
    snap = pool.host_state()
    assert len(snap["checkins"]) == len(seated)


def test_memory_meter_reports_growth():
    meter = MemoryMeter()
    ballast = np.ones(4 * 1024 * 1024, np.float64)   # 32 MB
    ballast[0] = 2.0
    rep = meter.report()
    assert rep["host_baseline_bytes"] > 0            # /proc available here
    assert rep["host_current_bytes"] >= rep["host_current_growth_bytes"]
    # peak (ru_maxrss) and current (statm) come from different kernel
    # accounting and may disagree by a few pages — assert each alone
    assert rep["host_peak_bytes"] > 0 and rep["host_current_bytes"] > 0
    assert rep["host_peak_growth_bytes"] >= 0
    assert rep["device_peak_bytes"] >= 0             # 0 on CPU backends
    del ballast
