"""Engine refactor parity: the strategy-backed ``*_train`` entry points
must reproduce the pre-engine per-round Python loops — same seed, same
history, same final params (tolerance <= 1e-5).

The legacy loops live HERE as fixtures (verbatim from the seed
implementations, evals included), not in src/: the engine is the only
production loop.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import SINE_MLP
from repro.core import (CommChannel, fedavg_train, fedsgd_train,
                        reptile_train, tifed_train, tinyreptile_train,
                        transfer_train)
from repro.core.engine import _block_runner
from repro.core.meta import (evaluate_init, finetune_batch, finetune_online,
                             tree_bytes, tree_lerp)
from repro.core.strategies import TifedStrategy
from repro.data import SineTasks
from repro.models.paper_nets import (init_paper_model, paper_model_loss,
                                     relu_mlp_loss)

LOSS = functools.partial(paper_model_loss, SINE_MLP)
EVAL = dict(num_tasks=4, support=8, k_steps=4, lr=0.02, query=16)


# ---------------------------------------------------------------------------
# legacy loops (seed implementations, kept as the parity reference)
# ---------------------------------------------------------------------------

def _legacy_tinyreptile(loss_fn, init_params, task_dist, *, rounds, alpha,
                        beta, support, anneal=True, seed=0, eval_every=0,
                        eval_kwargs=None):
    rng = np.random.default_rng(seed)
    phi = init_params
    history = []
    pbytes = tree_bytes(phi)
    comm_bytes = 0
    for rnd in range(rounds):
        alpha_t = alpha * (1 - rnd / rounds) if anneal else alpha
        task = task_dist.sample_task(rng)
        comm_bytes += pbytes
        xs, ys = zip(*task.support_stream(rng, support))
        phi_hat, inner_losses = finetune_online(
            loss_fn, phi, jnp.stack(xs), jnp.stack(ys), jnp.float32(beta))
        comm_bytes += pbytes
        phi = tree_lerp(phi, phi_hat, alpha_t)
        if eval_every and (rnd + 1) % eval_every == 0:
            ev = evaluate_init(loss_fn, phi, task_dist,
                               np.random.default_rng(10_000 + rnd),
                               **(eval_kwargs or {}))
            ev.update(round=rnd + 1, comm_bytes=comm_bytes,
                      inner_loss=float(inner_losses.mean()))
            history.append(ev)
    return {"params": phi, "history": history, "comm_bytes": comm_bytes}


def _legacy_reptile(loss_fn, init_params, task_dist, *, rounds, alpha, beta,
                    support, epochs, clients_per_round=1, anneal=True,
                    seed=0, eval_every=0, eval_kwargs=None):
    rng = np.random.default_rng(seed)
    phi = init_params
    history = []
    pbytes = tree_bytes(phi)
    comm_bytes = 0
    for rnd in range(rounds):
        alpha_t = alpha * (1 - rnd / rounds) if anneal else alpha
        deltas = None
        inner_loss = 0.0
        for _ in range(clients_per_round):
            task = task_dist.sample_task(rng)
            comm_bytes += 2 * pbytes
            sup = task.support_batch(rng, support)
            phi_hat, losses = finetune_batch(loss_fn, phi, sup, epochs,
                                             jnp.float32(beta))
            inner_loss += float(losses.mean()) / clients_per_round
            d = jax.tree.map(lambda q, p: q - p, phi_hat, phi)
            deltas = d if deltas is None else jax.tree.map(
                lambda a, b: a + b, deltas, d)
        phi = jax.tree.map(
            lambda p, d: p + alpha_t * d / clients_per_round, phi, deltas)
        if eval_every and (rnd + 1) % eval_every == 0:
            ev = evaluate_init(loss_fn, phi, task_dist,
                               np.random.default_rng(10_000 + rnd),
                               **(eval_kwargs or {}))
            ev.update(round=rnd + 1, comm_bytes=comm_bytes,
                      inner_loss=inner_loss)
            history.append(ev)
    return {"params": phi, "history": history, "comm_bytes": comm_bytes}


def _legacy_fedavg(loss_fn, init_params, task_dist, *, rounds, beta, support,
                   epochs, clients_per_round, seed=0, eval_every=0,
                   eval_kwargs=None):
    rng = np.random.default_rng(seed)
    phi = init_params
    history = []
    pbytes = tree_bytes(phi)
    comm_bytes = 0
    for rnd in range(rounds):
        acc = None
        for _ in range(clients_per_round):
            task = task_dist.sample_task(rng)
            comm_bytes += 2 * pbytes
            sup = task.support_batch(rng, support)
            phi_c, _ = finetune_batch(loss_fn, phi, sup, epochs,
                                      jnp.float32(beta))
            acc = phi_c if acc is None else jax.tree.map(
                lambda a, b: a + b, acc, phi_c)
        phi = jax.tree.map(lambda a: a / clients_per_round, acc)
        if eval_every and (rnd + 1) % eval_every == 0:
            ev = evaluate_init(loss_fn, phi, task_dist,
                               np.random.default_rng(10_000 + rnd),
                               **(eval_kwargs or {}))
            ev.update(round=rnd + 1, comm_bytes=comm_bytes)
            history.append(ev)
    return {"params": phi, "history": history, "comm_bytes": comm_bytes}


def _legacy_fedsgd(loss_fn, init_params, task_dist, *, rounds, beta, support,
                   clients_per_round, seed=0, eval_every=0, eval_kwargs=None):
    rng = np.random.default_rng(seed)
    phi = init_params
    history = []
    pbytes = tree_bytes(phi)
    comm_bytes = 0
    grad_fn = jax.jit(jax.grad(loss_fn))
    for rnd in range(rounds):
        gacc = None
        for _ in range(clients_per_round):
            task = task_dist.sample_task(rng)
            comm_bytes += 2 * pbytes
            sup = task.support_batch(rng, support)
            g = grad_fn(phi, sup)
            gacc = g if gacc is None else jax.tree.map(
                lambda a, b: a + b, gacc, g)
        phi = jax.tree.map(lambda p, g: p - beta * g / clients_per_round,
                           phi, gacc)
        if eval_every and (rnd + 1) % eval_every == 0:
            ev = evaluate_init(loss_fn, phi, task_dist,
                               np.random.default_rng(10_000 + rnd),
                               **(eval_kwargs or {}))
            ev.update(round=rnd + 1, comm_bytes=comm_bytes)
            history.append(ev)
    return {"params": phi, "history": history, "comm_bytes": comm_bytes}


def _legacy_transfer(loss_fn, init_params, task_dist, *, rounds, beta,
                     batch_per_round=32, tasks_per_round=8, seed=0,
                     eval_every=0, eval_kwargs=None):
    rng = np.random.default_rng(seed)
    phi = init_params
    history = []
    step = jax.jit(lambda p, b, lr: jax.tree.map(
        lambda w, g: w - lr * g, p, jax.grad(loss_fn)(p, b)))
    per_task = max(batch_per_round // tasks_per_round, 1)
    for rnd in range(rounds):
        xs, ys = [], []
        for _ in range(tasks_per_round):
            task = task_dist.sample_task(rng)
            b = task.support_batch(rng, per_task)
            xs.append(b["x"])
            ys.append(b["y"])
        batch = {"x": np.concatenate(xs), "y": np.concatenate(ys)}
        phi = step(phi, batch, jnp.float32(beta))
        if eval_every and (rnd + 1) % eval_every == 0:
            ev = evaluate_init(loss_fn, phi, task_dist,
                               np.random.default_rng(10_000 + rnd),
                               **(eval_kwargs or {}))
            ev.update(round=rnd + 1)
            history.append(ev)
    return {"params": phi, "history": history}


# ---------------------------------------------------------------------------
# parity assertions
# ---------------------------------------------------------------------------

def _assert_parity(got, want, *, check_comm=True):
    for a, b in zip(jax.tree.leaves(got["params"]),
                    jax.tree.leaves(want["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
    if check_comm:
        assert got["comm_bytes"] == want["comm_bytes"]
    assert len(got["history"]) == len(want["history"])
    for ge, we in zip(got["history"], want["history"]):
        assert set(ge) == set(we), (ge, we)
        for k, v in we.items():
            if isinstance(v, (int, np.integer)):
                assert ge[k] == v, (k, ge[k], v)
            else:
                np.testing.assert_allclose(ge[k], v, rtol=1e-5, atol=1e-5,
                                           err_msg=k)


@pytest.fixture(scope="module")
def setup():
    return init_paper_model(SINE_MLP, jax.random.PRNGKey(0)), SineTasks()


def test_tinyreptile_parity(setup):
    params, dist = setup
    kw = dict(rounds=60, alpha=1.0, beta=0.02, support=8, seed=11,
              eval_every=20, eval_kwargs=EVAL)
    _assert_parity(tinyreptile_train(LOSS, params, dist, **kw),
                   _legacy_tinyreptile(LOSS, params, dist, **kw))


def test_tinyreptile_no_anneal_no_eval_parity(setup):
    params, dist = setup
    kw = dict(rounds=25, alpha=0.7, beta=0.02, support=8, seed=12,
              anneal=False)
    _assert_parity(tinyreptile_train(LOSS, params, dist, **kw),
                   _legacy_tinyreptile(LOSS, params, dist, **kw))


def test_reptile_serial_parity(setup):
    params, dist = setup
    kw = dict(rounds=40, alpha=1.0, beta=0.02, support=8, epochs=4,
              clients_per_round=1, seed=13, eval_every=20, eval_kwargs=EVAL)
    _assert_parity(reptile_train(LOSS, params, dist, **kw),
                   _legacy_reptile(LOSS, params, dist, **kw))


def test_reptile_batched_parity(setup):
    params, dist = setup
    kw = dict(rounds=30, alpha=1.0, beta=0.02, support=8, epochs=4,
              clients_per_round=3, seed=14, eval_every=15, eval_kwargs=EVAL)
    _assert_parity(reptile_train(LOSS, params, dist, **kw),
                   _legacy_reptile(LOSS, params, dist, **kw))


def test_fedavg_parity(setup):
    params, dist = setup
    kw = dict(rounds=20, beta=0.02, support=8, epochs=4,
              clients_per_round=3, seed=15, eval_every=10, eval_kwargs=EVAL)
    _assert_parity(fedavg_train(LOSS, params, dist, **kw),
                   _legacy_fedavg(LOSS, params, dist, **kw))


def test_fedsgd_parity(setup):
    params, dist = setup
    kw = dict(rounds=30, beta=0.02, support=8, clients_per_round=3,
              seed=16, eval_every=15, eval_kwargs=EVAL)
    _assert_parity(fedsgd_train(LOSS, params, dist, **kw),
                   _legacy_fedsgd(LOSS, params, dist, **kw))


def test_transfer_parity(setup):
    params, dist = setup
    kw = dict(rounds=40, beta=0.02, batch_per_round=24, tasks_per_round=6,
              seed=17, eval_every=20, eval_kwargs=EVAL)
    got = transfer_train(LOSS, params, dist, **kw)
    want = _legacy_transfer(LOSS, params, dist, **kw)
    assert "comm_bytes" not in got and "comm_bytes" not in want
    _assert_parity(got, want, check_comm=False)


def test_schedule_threaded_engine_is_bitwise_uniform(setup):
    """PR-3 acceptance: with UniformSampling (full participation,
    uniform local steps) the schedule-threaded engine must be bit-for-bit
    the PR-2 engine for all five strategies. The legacy-loop parity
    tests above pin the numerics to the seed; this pins the explicit
    schedule object to the default path — the ClientSchedule arrays ride
    the scan but the uniform body must not touch them."""
    from repro.core import UniformSampling
    params, dist = setup
    cases = [
        (tinyreptile_train, dict(rounds=15, alpha=1.0, beta=0.02,
                                 support=6, seed=21)),
        (reptile_train, dict(rounds=10, alpha=1.0, beta=0.02, support=6,
                             epochs=3, clients_per_round=3, seed=22)),
        (fedavg_train, dict(rounds=8, beta=0.02, support=6, epochs=3,
                            clients_per_round=3, seed=23)),
        (fedsgd_train, dict(rounds=10, beta=0.02, support=6,
                            clients_per_round=3, seed=24)),
        (transfer_train, dict(rounds=10, beta=0.02, batch_per_round=12,
                              tasks_per_round=3, seed=25)),
    ]
    for train_fn, kw in cases:
        default = train_fn(LOSS, params, dist, **kw)
        threaded = train_fn(LOSS, params, dist,
                            sampling=UniformSampling(), **kw)
        for a, b in zip(jax.tree.leaves(default["params"]),
                        jax.tree.leaves(threaded["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        if "comm_bytes" in default:
            assert default["comm_bytes"] == threaded["comm_bytes"]
            assert default["per_client_bytes"] == \
                threaded["per_client_bytes"]


def test_engine_does_not_clobber_init_params(setup):
    """The engine donates its working buffers; the caller's init_params
    must survive (they are reused across algorithm runs in benches)."""
    params, dist = setup
    before = jax.tree.map(lambda x: np.array(x), params)
    tinyreptile_train(LOSS, params, dist, rounds=8, beta=0.02, support=4,
                      seed=0)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(params)):
        np.testing.assert_array_equal(a, np.asarray(b))


TIFED_EVAL = dict(num_tasks=4, support=8, k_steps=4, lr=0.005, query=16)


def test_tifed_seeded_determinism(setup):
    """Same seed -> bitwise-identical params and history (the dither
    planes are baked trace constants, so nothing is run-dependent)."""
    params, dist = setup
    kw = dict(rounds=20, alpha=1.0, support=16, clients_per_round=4,
              seed=31, eval_every=10, eval_kwargs=TIFED_EVAL)
    a = tifed_train(params, dist, **kw)
    b = tifed_train(params, dist, **kw)
    for x, y in zip(jax.tree.leaves(a["params"]),
                    jax.tree.leaves(b["params"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert a["comm_bytes"] == b["comm_bytes"]
    assert len(a["history"]) == 2
    for ea, eb in zip(a["history"], b["history"]):
        assert set(ea) == set(eb)
        for k in ea:
            np.testing.assert_array_equal(ea[k], eb[k], err_msg=k)


def test_tifed_pipelined_matches_sync_bitwise(setup):
    """Prefetch + block splitting must not change the integer
    trajectory at all (sampler held fixed: the two sampler flavours have
    documentedly different block RNG orders)."""
    params, dist = setup
    kw = dict(rounds=16, alpha=1.0, support=16, clients_per_round=4,
              seed=32, sampler="reference")
    sync = tifed_train(params, dist, prefetch=0, **kw)
    piped = tifed_train(params, dist, prefetch=2, max_block=4, **kw)
    for x, y in zip(jax.tree.leaves(sync["params"]),
                    jax.tree.leaves(piped["params"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert sync["comm_bytes"] == piped["comm_bytes"]


def test_tifed_single_trace_and_int8_billing(setup):
    """One jit trace per config, and the uplink bills at the int8 rate:
    1 byte/param both directions, 4x under the fp32 bill for the same
    traffic (the 6 exponent scalars ride free)."""
    params, dist = setup
    # lr_shift=5 gives this test its own cached runner (the runner cache
    # keys on the strategy dataclass), so trace_count pins THIS config
    rounds, clients = 12, 4
    out = tifed_train(params, dist, rounds=rounds, alpha=1.0, support=16,
                      clients_per_round=clients, lr_shift=5, seed=33)
    runner = _block_runner(TifedStrategy(relu_mlp_loss, epochs=8,
                                         lr_shift=5), 0.0,
                           CommChannel("int8", quantize=False),
                           scheduled=False)
    assert runner.trace_count == 1
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    assert out["comm_bytes"] == 2 * clients * rounds * n_params
    fp32_bill = 2 * clients * rounds * tree_bytes(params)
    assert out["comm_bytes"] * 4 == fp32_bill


def test_tifed_rejects_incompatible_channels(setup):
    """tifed uplinks NATIVE int8 trees: an fp32 wire or a simulating
    channel would double-quantize or mis-bill, so the engine refuses."""
    params, dist = setup
    for bad in (CommChannel(),                       # fp32 wire
                CommChannel("int8"),                 # simulates int8
                CommChannel("float16", quantize=False)):  # wrong width
        with pytest.raises(ValueError, match="payload_dtype"):
            tifed_train(params, dist, rounds=2, support=4, channel=bad)


def test_tifed_learns_sine(setup):
    """End-to-end sanity: integer training actually reduces query loss
    vs the untrained init under the paper's eval protocol."""
    params, dist = setup
    out = tifed_train(params, dist, rounds=40, alpha=1.0, support=32,
                      clients_per_round=4, seed=34, eval_every=40,
                      eval_kwargs=TIFED_EVAL)
    ev0 = evaluate_init(relu_mlp_loss, params, dist,
                        np.random.default_rng(10_039), **TIFED_EVAL)
    assert np.isfinite(out["history"][-1]["query_loss"])
    assert out["history"][-1]["query_loss"] < ev0["query_loss"]


def test_pallas_server_update_in_scan(setup):
    """The engine's Pallas meta_update route agrees with the XLA route."""
    params, dist = setup
    kw = dict(rounds=12, alpha=0.8, beta=0.02, support=8, seed=18)
    xla = tinyreptile_train(LOSS, params, dist, use_pallas=False, **kw)
    pal = tinyreptile_train(LOSS, params, dist, use_pallas=True, **kw)
    for a, b in zip(jax.tree.leaves(xla["params"]),
                    jax.tree.leaves(pal["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
