"""CommChannel: transport byte accounting and quantized-payload training.

The channel generalizes the paper's Table-II accounting (fp32 payloads)
to fp16/int8 wires (int8 motivated by TIFeD's integer-based FL) and can
simulate the lossy payload in-round.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import SINE_MLP
from repro.core import CommChannel, tinyreptile_train
from repro.core.engine import PAYLOAD_ITEMSIZE
from repro.core.meta import evaluate_init, tree_bytes
from repro.data import SineTasks
from repro.models.paper_nets import init_paper_model, paper_model_loss

LOSS = functools.partial(paper_model_loss, SINE_MLP)
EVAL = dict(num_tasks=6, support=8, k_steps=8, lr=0.02, query=32)


@pytest.fixture(scope="module")
def setup():
    return init_paper_model(SINE_MLP, jax.random.PRNGKey(0)), SineTasks()


def test_payload_bytes_scale_with_itemsize(setup):
    """fp16/int8 accounting == tree_bytes scaled by the itemsize ratio."""
    params, _ = setup
    fp32 = tree_bytes(params)
    for dtype, itemsize in PAYLOAD_ITEMSIZE.items():
        ch = CommChannel(dtype)
        assert ch.payload_bytes(params) == fp32 * itemsize // 4
        for clients in (1, 5):
            assert ch.round_bytes(params, clients) == \
                2 * clients * fp32 * itemsize // 4


def test_unknown_payload_dtype_rejected():
    with pytest.raises(ValueError):
        CommChannel("int4")


def test_run_comm_bytes_scale(setup):
    """An int8 link meters 4x fewer bytes than fp32 over a whole run,
    and accounting-only channels (quantize=False) do not perturb the
    training numerics at all."""
    params, dist = setup
    kw = dict(rounds=20, alpha=1.0, beta=0.02, support=8, seed=0,
              eval_every=10, eval_kwargs=EVAL)
    base = tinyreptile_train(LOSS, params, dist, **kw)
    int8 = tinyreptile_train(LOSS, params, dist,
                             channel=CommChannel("int8", quantize=False),
                             **kw)
    assert int8["comm_bytes"] * 4 == base["comm_bytes"]
    assert [h["comm_bytes"] * 4 for h in int8["history"]] == \
        [h["comm_bytes"] for h in base["history"]]
    for a, b in zip(jax.tree.leaves(base["params"]),
                    jax.tree.leaves(int8["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_transmit_fp16_roundtrip():
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(33,)), jnp.float32)
    got = CommChannel("float16").transmit({"w": x})["w"]
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(x.astype(jnp.float16), np.float32))


def test_transmit_int8_error_bound():
    """Symmetric int8: per-leaf error <= scale/2 = max|x|/254."""
    r = np.random.default_rng(1)
    x = jnp.asarray(r.normal(size=(257,)), jnp.float32)
    got = CommChannel("int8").transmit({"w": x})["w"]
    bound = float(jnp.abs(x).max()) / 254.0 + 1e-6
    assert float(jnp.abs(got - x).max()) <= bound
    # fp32 channel is the identity
    same = CommChannel().transmit({"w": x})["w"]
    np.testing.assert_array_equal(np.asarray(same), np.asarray(x))


def test_quantized_transport_tinyreptile_converges(setup):
    """TinyReptile over a lossy int8 uplink/downlink still learns an
    adaptable init on the sine task (paper-claim robustness; the TIFeD
    direction)."""
    params, dist = setup
    base = evaluate_init(LOSS, params, dist, np.random.default_rng(7), **EVAL)
    out = tinyreptile_train(LOSS, params, dist, rounds=150, alpha=1.0,
                            beta=0.02, support=32, eval_every=150,
                            eval_kwargs=EVAL, seed=1,
                            channel=CommChannel("int8"))
    final = out["history"][-1]["query_loss"]
    assert final < base["query_loss"] * 0.6, (final, base)
    # and it metered a 4x cheaper link
    assert out["comm_bytes"] == 150 * 2 * tree_bytes(params) // 4
