"""Quickstart: the paper's Sine-wave case study end-to-end (Fig. 1 + 2).

Trains TinyReptile, Reptile, and transfer learning on the sine-wave
meta-learning problem with the paper's exact 1->32->32->1 MLP (1,153
params), then adapts each to an unseen client with 8 samples / 8 SGD
steps and prints the query MSE.

Every algorithm here is a strategy on the shared federated round engine
(repro.core.engine); the final section swaps the transport for an int8
CommChannel to show a 4x cheaper (and still converging) federated link.

  PYTHONPATH=src python examples/quickstart.py
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import SINE_MLP
from repro.core import (CommChannel, evaluate_init, reptile_train,
                        tinyreptile_train, transfer_train)
from repro.data import SineTasks
from repro.models.paper_nets import (init_paper_model, paper_model_apply,
                                     paper_model_loss, param_count)

LOSS = functools.partial(paper_model_loss, SINE_MLP)
EVAL = dict(num_tasks=10, support=8, k_steps=8, lr=0.02, query=64)
ROUNDS = 600


def main():
    params = init_paper_model(SINE_MLP, jax.random.PRNGKey(0))
    print(f"model: {SINE_MLP.name}, params = {param_count(params)} "
          "(paper Table I: 1,153)")
    dist = SineTasks()
    base = evaluate_init(LOSS, params, dist, np.random.default_rng(7), **EVAL)
    print(f"random init     : query MSE after adaptation = "
          f"{base['query_loss']:.3f}")

    tiny = tinyreptile_train(LOSS, params, dist, rounds=ROUNDS, alpha=1.0,
                             beta=0.02, support=32, eval_every=ROUNDS,
                             eval_kwargs=EVAL, seed=1)
    print(f"TinyReptile     : query MSE after adaptation = "
          f"{tiny['history'][-1]['query_loss']:.3f} "
          f"(comm = {tiny['comm_bytes']/1e6:.1f} MB)")

    rep = reptile_train(LOSS, params, dist, rounds=ROUNDS, alpha=1.0,
                        beta=0.02, support=32, epochs=8, eval_every=ROUNDS,
                        eval_kwargs=EVAL, seed=1)
    print(f"Reptile (serial): query MSE after adaptation = "
          f"{rep['history'][-1]['query_loss']:.3f}")

    tr = transfer_train(LOSS, params, dist, rounds=ROUNDS, beta=0.02,
                        eval_every=ROUNDS, eval_kwargs=EVAL, seed=1)
    print(f"transfer        : query MSE after adaptation = "
          f"{tr['history'][-1]['query_loss']:.3f}  <- fails (Fig. 1)")

    # show the transfer collapse: predictions ~ E[f] ~ 0 everywhere
    xs = jnp.linspace(-5, 5, 9)[:, None]
    preds = paper_model_apply(SINE_MLP, tr["params"], xs)
    print("transfer model predicts ~0 for all x:",
          np.round(np.asarray(preds[:, 0]), 2))

    # beyond the paper: the same engine over a quantized int8 transport
    # (TIFeD direction) — 4x fewer bytes on the wire, still converges
    q = tinyreptile_train(LOSS, params, dist, rounds=ROUNDS, alpha=1.0,
                          beta=0.02, support=32, eval_every=ROUNDS,
                          eval_kwargs=EVAL, seed=1,
                          channel=CommChannel("int8"))
    print(f"TinyReptile int8: query MSE after adaptation = "
          f"{q['history'][-1]['query_loss']:.3f} "
          f"(comm = {q['comm_bytes']/1e6:.1f} MB)")


if __name__ == "__main__":
    main()
