"""Keywords spotting (the paper's contributed TinyML dataset, §IV-A):
federated meta-learning of a 4-way keyword classifier across a simulated
heterogeneous IoT fleet, with the paper's resource accounting.

This is the end-to-end driver of the paper's kind, upgraded to the
engine's deployment-scenario plugins. By default the cohort runs through
``run_federated`` with a ``PartialParticipation`` schedule — each round
only half the fleet checks in, trains, and pays transport — and the run
reports the per-client transport bill (paper Table-II style: bytes per
device, not just a fleet total) next to the Table-II memory model.

With ``--pool-size`` / ``--availability`` / ``--buffer-size`` the fleet
becomes a PERSISTENT ``ClientPool``: every device keeps its own keyword
task and data stream across check-ins (the TinyReptile deployment
model), check-ins follow a diurnal sine or two-state Markov process, and
aggregation optionally goes FedBuff-style async (a server buffer that
flushes every K arrivals with staleness-discounted weights). The run
then prints each device's check-in count, staleness, and transport bill.

  PYTHONPATH=src python examples/federated_keyword_spotting.py
  PYTHONPATH=src python examples/federated_keyword_spotting.py \\
      --availability diurnal --buffer-size 4
"""
import argparse
import functools
import time

import jax
import numpy as np

from repro.configs.paper_models import KWS_CONV
from repro.core import (BufferedAggregation, ClientPool, CommChannel,
                        DiurnalAvailability, MarkovAvailability,
                        PartialParticipation, evaluate_init, run_federated,
                        tinyreptile_train)
from repro.core.strategies import TinyReptileStrategy
from repro.data import KWSTasks
from repro.metering import algorithm_memory_report
from repro.models.paper_nets import (init_paper_model, paper_model_accuracy,
                                     paper_model_loss, param_count)

LOSS = functools.partial(paper_model_loss, KWS_CONV)
ACC = functools.partial(paper_model_accuracy, KWS_CONV)
EVAL = dict(num_tasks=8, support=16, k_steps=8, lr=0.01, query=32,
            metric_fn=ACC)

COHORT = 8          # fleet slots per round
FRACTION = 0.5      # half the fleet checks in each round (default mode)


def positive_int(s):
    v = int(s)
    if v < 1:
        raise argparse.ArgumentTypeError(f"must be >= 1, got {v}")
    return v


def parse_args():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=positive_int, default=200)
    ap.add_argument("--pool-size", type=positive_int, default=None,
                    help="run on a persistent ClientPool of this many "
                         "devices (default 16 when --availability or "
                         "--buffer-size imply a pool)")
    ap.add_argument("--availability", default="none",
                    choices=("none", "diurnal", "markov"),
                    help="check-in process over the pool: diurnal sine "
                         "or two-state Markov (implies a pool)")
    ap.add_argument("--buffer-size", type=positive_int, default=None,
                    help="FedBuff-style async aggregation: flush the "
                         "server buffer every K arrivals (implies a pool)")
    return ap.parse_args()


def transport_table(out, params, rounds, label, staleness=None):
    """Paper Table-II style per-device bill (+ pooled identity state)."""
    round_bill = 2 * CommChannel().payload_bytes(params)  # down + up
    print(f"\ntransport accounting over {rounds} rounds "
          f"(fp32 wire, downlink + uplink, "
          f"{round_bill / 1024:.1f} KB per participated round):")
    header = f"  {'client':>8}  {'rounds':>7}  {'KB paid':>9}"
    if staleness is not None:
        header += f"  {'staleness':>10}  {'last seen':>10}"
    print(header)
    for c, paid in enumerate(out["per_client_bytes"]):
        row = f"  {c:>8}  {paid // round_bill:>7}  {paid / 1024:>9.1f}"
        if staleness is not None:
            row += (f"  {staleness['staleness'][c]:>10d}"
                    f"  {staleness['last_seen'][c]:>10d}")
        print(row)
    total = out["comm_bytes"]
    full = rounds * COHORT * round_bill
    print(f"  {'total':>8}  {total // round_bill:>7}  {total / 1024:>9.1f}"
          f"   ({total / full:.0%} of a full-participation fleet)  "
          f"[{label}]")


def main():
    args = parse_args()
    pooled = (args.pool_size is not None or args.availability != "none"
              or args.buffer_size is not None)
    pool_size = args.pool_size or 16
    if pooled and pool_size < COHORT:
        raise SystemExit(f"--pool-size must seat the {COHORT}-slot cohort")

    params = init_paper_model(KWS_CONV, jax.random.PRNGKey(0))
    print(f"model: {KWS_CONV.name}, params = {param_count(params)}")
    dist = KWSTasks()

    mem = algorithm_memory_report(KWS_CONV, support=16)
    print(f"memory model (Table II analogue): Reptile "
          f"{mem['reptile_bytes']/1024:.1f} KB vs TinyReptile "
          f"{mem['tinyreptile_bytes']/1024:.1f} KB "
          f"({mem['reduction_factor']:.1f}x reduction)")

    base = evaluate_init(LOSS, params, dist, np.random.default_rng(3), **EVAL)
    print(f"random init accuracy: {base['query_metric']:.2%} (chance 25%)")

    # --- serial TinyReptile (the paper's Algorithm 1 schema) ------------
    t0 = time.time()
    tiny = tinyreptile_train(LOSS, params, dist, rounds=args.rounds,
                             alpha=1.0, beta=0.01, support=16,
                             eval_every=max(args.rounds // 2, 1),
                             eval_kwargs=EVAL, seed=1)
    t_tiny = time.time() - t0
    for ev in tiny["history"]:
        print(f"  TinyReptile round {ev['round']:4d}: "
              f"acc {ev['query_metric']:.2%}  loss {ev['query_loss']:.3f}")
    print(f"TinyReptile serial final acc: "
          f"{tiny['history'][-1]['query_metric']:.2%} ({t_tiny:.1f}s, "
          f"{tiny['comm_bytes']/1024:.0f} KB total transport)")

    # --- the fleet through the round engine -----------------------------
    if pooled:
        pool = ClientPool(dist, pool_size, seed=1)
        policy = {"none": None,
                  "diurnal": DiurnalAvailability(period=24),
                  "markov": MarkovAvailability()}[args.availability]
        buffered = (BufferedAggregation(args.buffer_size)
                    if args.buffer_size else None)
        label = (f"pool of {pool_size}, {args.availability} check-ins"
                 + (f", FedBuff K={args.buffer_size}" if buffered else ""))
        print(f"\npersistent fleet: {label}")
        t0 = time.time()
        fleet = run_federated(params, dist, TinyReptileStrategy(LOSS),
                              rounds=args.rounds, clients_per_round=COHORT,
                              alpha=1.0, beta=0.01, support=16, seed=1,
                              eval_every=max(args.rounds // 2, 1),
                              eval_kwargs=EVAL, sampling=policy,
                              pool=pool, buffered=buffered)
        t_fleet = time.time() - t0
        for ev in fleet["history"]:
            print(f"  fleet round {ev['round']:4d}: "
                  f"acc {ev['query_metric']:.2%}  "
                  f"loss {ev['query_loss']:.3f}")
        ps = fleet["pool_state"]
        idle = int((ps["checkins"] == 0).sum())
        print(f"persistent fleet final acc: "
              f"{fleet['history'][-1]['query_metric']:.2%} ({t_fleet:.1f}s; "
              f"{idle}/{pool_size} devices never checked in"
              + (f"; {ps['flushes']} buffer flushes, "
                 f"{ps['buffered_pending']} updates still pending"
                 if buffered else "") + ")")
        transport_table(fleet, params, args.rounds, label, staleness=ps)
        return

    policy = PartialParticipation(FRACTION)
    t0 = time.time()
    fleet = run_federated(params, dist, TinyReptileStrategy(LOSS),
                          rounds=args.rounds, clients_per_round=COHORT,
                          alpha=1.0, beta=0.01, support=16, seed=1,
                          eval_every=max(args.rounds // 2, 1),
                          eval_kwargs=EVAL, sampling=policy)
    t_fleet = time.time() - t0
    for ev in fleet["history"]:
        print(f"  fleet round {ev['round']:4d}: "
              f"acc {ev['query_metric']:.2%}  loss {ev['query_loss']:.3f}")
    print(f"partial-participation fleet ({COHORT} slots, "
          f"{policy.cohort(COHORT)}/round check in) final acc: "
          f"{fleet['history'][-1]['query_metric']:.2%} ({t_fleet:.1f}s)")
    transport_table(fleet, params, args.rounds,
                    f"anonymous cohort, {FRACTION:.0%} participation")


if __name__ == "__main__":
    main()
