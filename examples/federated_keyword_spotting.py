"""Keywords spotting (the paper's contributed TinyML dataset, §IV-A):
federated meta-learning of a 4-way keyword classifier across simulated
IoT clients, with the paper's resource accounting.

This is the end-to-end driver of the paper's kind: a full federated
meta-learning run (server + streaming clients + evaluation + memory
metering) at the paper's own scale.

  PYTHONPATH=src python examples/federated_keyword_spotting.py
"""
import functools
import time

import jax
import numpy as np

from repro.configs.paper_models import KWS_CONV
from repro.core import evaluate_init, reptile_train, tinyreptile_train
from repro.data import KWSTasks
from repro.metering import algorithm_memory_report
from repro.models.paper_nets import (init_paper_model, paper_model_accuracy,
                                     paper_model_loss, param_count)

LOSS = functools.partial(paper_model_loss, KWS_CONV)
ACC = functools.partial(paper_model_accuracy, KWS_CONV)
EVAL = dict(num_tasks=8, support=16, k_steps=8, lr=0.01, query=32,
            metric_fn=ACC)


def main():
    params = init_paper_model(KWS_CONV, jax.random.PRNGKey(0))
    print(f"model: {KWS_CONV.name}, params = {param_count(params)}")
    dist = KWSTasks()

    mem = algorithm_memory_report(KWS_CONV, support=16)
    print(f"memory model (Table II analogue): Reptile "
          f"{mem['reptile_bytes']/1024:.1f} KB vs TinyReptile "
          f"{mem['tinyreptile_bytes']/1024:.1f} KB "
          f"({mem['reduction_factor']:.1f}x reduction)")

    base = evaluate_init(LOSS, params, dist, np.random.default_rng(3), **EVAL)
    print(f"random init accuracy: {base['query_metric']:.2%} (chance 25%)")

    t0 = time.time()
    tiny = tinyreptile_train(LOSS, params, dist, rounds=200, alpha=1.0,
                             beta=0.01, support=16, eval_every=100,
                             eval_kwargs=EVAL, seed=1)
    t_tiny = time.time() - t0
    for ev in tiny["history"]:
        print(f"  TinyReptile round {ev['round']:4d}: "
              f"acc {ev['query_metric']:.2%}  loss {ev['query_loss']:.3f}")

    t0 = time.time()
    rep = reptile_train(LOSS, params, dist, rounds=200, alpha=1.0, beta=0.01,
                        support=16, epochs=8, eval_every=200,
                        eval_kwargs=EVAL, seed=1)
    t_rep = time.time() - t0
    print(f"Reptile   final acc: {rep['history'][-1]['query_metric']:.2%} "
          f"({t_rep:.1f}s)")
    print(f"TinyReptile final acc: "
          f"{tiny['history'][-1]['query_metric']:.2%} ({t_tiny:.1f}s)")


if __name__ == "__main__":
    main()
