"""Keywords spotting (the paper's contributed TinyML dataset, §IV-A):
federated meta-learning of a 4-way keyword classifier across a simulated
heterogeneous IoT fleet, with the paper's resource accounting.

This is the end-to-end driver of the paper's kind, upgraded to the
engine's deployment-scenario plugins: the cohort runs through
``run_federated`` with a ``PartialParticipation`` schedule — each round
only half the fleet checks in, trains, and pays transport — and the run
reports the per-client transport bill (paper Table-II style: bytes per
device, not just a fleet total) next to the Table-II memory model.

  PYTHONPATH=src python examples/federated_keyword_spotting.py
"""
import functools
import time

import jax
import numpy as np

from repro.configs.paper_models import KWS_CONV
from repro.core import (CommChannel, PartialParticipation, evaluate_init,
                        run_federated, tinyreptile_train)
from repro.core.strategies import TinyReptileStrategy
from repro.data import KWSTasks
from repro.metering import algorithm_memory_report
from repro.models.paper_nets import (init_paper_model, paper_model_accuracy,
                                     paper_model_loss, param_count)

LOSS = functools.partial(paper_model_loss, KWS_CONV)
ACC = functools.partial(paper_model_accuracy, KWS_CONV)
EVAL = dict(num_tasks=8, support=16, k_steps=8, lr=0.01, query=32,
            metric_fn=ACC)

ROUNDS = 200
COHORT = 8          # fleet slots per round
FRACTION = 0.5      # half the fleet checks in each round


def main():
    params = init_paper_model(KWS_CONV, jax.random.PRNGKey(0))
    print(f"model: {KWS_CONV.name}, params = {param_count(params)}")
    dist = KWSTasks()

    mem = algorithm_memory_report(KWS_CONV, support=16)
    print(f"memory model (Table II analogue): Reptile "
          f"{mem['reptile_bytes']/1024:.1f} KB vs TinyReptile "
          f"{mem['tinyreptile_bytes']/1024:.1f} KB "
          f"({mem['reduction_factor']:.1f}x reduction)")

    base = evaluate_init(LOSS, params, dist, np.random.default_rng(3), **EVAL)
    print(f"random init accuracy: {base['query_metric']:.2%} (chance 25%)")

    # --- serial TinyReptile (the paper's Algorithm 1 schema) ------------
    t0 = time.time()
    tiny = tinyreptile_train(LOSS, params, dist, rounds=ROUNDS, alpha=1.0,
                             beta=0.01, support=16, eval_every=100,
                             eval_kwargs=EVAL, seed=1)
    t_tiny = time.time() - t0
    for ev in tiny["history"]:
        print(f"  TinyReptile round {ev['round']:4d}: "
              f"acc {ev['query_metric']:.2%}  loss {ev['query_loss']:.3f}")
    print(f"TinyReptile serial final acc: "
          f"{tiny['history'][-1]['query_metric']:.2%} ({t_tiny:.1f}s, "
          f"{tiny['comm_bytes']/1024:.0f} KB total transport)")

    # --- partial-participation fleet through the round engine -----------
    policy = PartialParticipation(FRACTION)
    t0 = time.time()
    fleet = run_federated(params, dist, TinyReptileStrategy(LOSS),
                          rounds=ROUNDS, clients_per_round=COHORT,
                          alpha=1.0, beta=0.01, support=16, seed=1,
                          eval_every=100, eval_kwargs=EVAL,
                          sampling=policy)
    t_fleet = time.time() - t0
    for ev in fleet["history"]:
        print(f"  fleet round {ev['round']:4d}: "
              f"acc {ev['query_metric']:.2%}  loss {ev['query_loss']:.3f}")
    print(f"partial-participation fleet ({COHORT} slots, "
          f"{policy.cohort(COHORT)}/round check in) final acc: "
          f"{fleet['history'][-1]['query_metric']:.2%} ({t_fleet:.1f}s)")

    # --- per-client transport accounting (paper Table-II style) ---------
    round_bill = 2 * CommChannel().payload_bytes(params)  # down + up
    print(f"\ntransport accounting over {ROUNDS} rounds "
          f"(fp32 wire, downlink + uplink, "
          f"{round_bill / 1024:.1f} KB per participated round):")
    print(f"  {'client':>8}  {'rounds':>7}  {'KB paid':>9}")
    for c, paid in enumerate(fleet["per_client_bytes"]):
        print(f"  {c:>8}  {paid // round_bill:>7}  {paid / 1024:>9.1f}")
    total = fleet["comm_bytes"]
    full = ROUNDS * COHORT * round_bill
    print(f"  {'total':>8}  {ROUNDS * policy.cohort(COHORT):>7}  "
          f"{total / 1024:>9.1f}   "
          f"({total / full:.0%} of a full-participation fleet)")


if __name__ == "__main__":
    main()
