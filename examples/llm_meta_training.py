"""TinyReptile at framework scale: federated meta-training of a (reduced)
assigned architecture over heterogeneous LM clients, then serving it.

Uses the same public API the production launchers use:
  - repro.runtime.steps.make_meta_train_step  (the paper's round as a step)
  - repro.models.build_model                  (any --arch)
  - repro.checkpoint                          (save/restore)

  PYTHONPATH=src python examples/llm_meta_training.py [arch]
"""
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_arch
from repro.data import LMClientStream
from repro.models import build_model
from repro.runtime.steps import make_meta_train_step, microbatch

ARCH = sys.argv[1] if len(sys.argv) > 1 else "tinyllama-1.1b"
ROUNDS, BATCH, SEQ, K = 30, 8, 64, 4


def main():
    cfg = get_arch(ARCH).reduced()
    model = build_model(cfg)
    phi = model.init(jax.random.PRNGKey(0))
    clients = [LMClientStream(cfg.vocab_size, cid) for cid in range(16)]
    step = jax.jit(make_meta_train_step(model, beta=0.02, alpha=1.0),
                   donate_argnums=(0,))
    rng = np.random.default_rng(0)

    first = last = None
    for rnd in range(ROUNDS):
        client = clients[int(rng.integers(len(clients)))]
        batch = jax.tree.map(jnp.asarray, client.batch(rng, BATCH, SEQ))
        phi, m = step(phi, microbatch(batch, K))
        if rnd == 0:
            first = float(m["loss"])
        last = float(m["loss"])
        if rnd % 10 == 0:
            print(f"round {rnd:3d}  loss {float(m['loss']):.3f}  "
                  f"(inner {float(m['inner_first']):.3f} -> "
                  f"{float(m['inner_last']):.3f})")
    print(f"meta-training: {first:.3f} -> {last:.3f}")
    assert last < first, "meta loss should improve"

    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, phi, ROUNDS, extra={"arch": ARCH})
        phi2, rnd, extra = restore_checkpoint(d, phi)
        print(f"checkpoint round-trip ok (round {rnd}, {extra})")

    # serve a few greedy tokens from the meta-learned init
    cache = model.init_cache(1, 32)
    tok = jnp.asarray([[1]], jnp.int32)
    outs = []
    for t in range(8):
        logits, cache = jax.jit(model.decode_fn)(
            phi, {"tokens": tok, "cache": cache, "cache_len": jnp.int32(t)})
        tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        outs.append(int(tok[0, 0]))
    print("greedy sample:", outs)


if __name__ == "__main__":
    main()
