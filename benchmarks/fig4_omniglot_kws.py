"""Paper Fig. 4: Reptile (batched & serial) vs TinyReptile on Omniglot
(5-way) and Keywords spotting (4-way). derived = query accuracy after
adaptation (chance: 20% / 25%)."""
import functools

import jax
import numpy as np

from benchmarks.common import timed
from repro.configs.paper_models import KWS_CONV, OMNIGLOT_CONV
from repro.core import reptile_train, tinyreptile_train
from repro.data import KWSTasks, OmniglotTasks
from repro.models.paper_nets import (init_paper_model, paper_model_accuracy,
                                     paper_model_loss)

ROUNDS = 120


def _bench(name, cfg, dist, rows):
    loss = functools.partial(paper_model_loss, cfg)
    acc = functools.partial(paper_model_accuracy, cfg)
    ev = dict(num_tasks=6, support=16, k_steps=8, lr=0.01, query=32,
              metric_fn=acc)
    params = init_paper_model(cfg, jax.random.PRNGKey(0))

    out, us = timed(lambda: tinyreptile_train(
        loss, params, dist, rounds=ROUNDS, alpha=1.0, beta=0.01, support=16,
        eval_every=ROUNDS, eval_kwargs=ev, seed=4), repeats=1, warmup=0)
    rows.append((f"fig4/{name}_tinyreptile", us / ROUNDS,
                 f"acc={out['history'][-1]['query_metric']:.2%}"))

    out, us = timed(lambda: reptile_train(
        loss, params, dist, rounds=ROUNDS, alpha=1.0, beta=0.01, support=16,
        epochs=8, eval_every=ROUNDS, eval_kwargs=ev, seed=4),
        repeats=1, warmup=0)
    rows.append((f"fig4/{name}_reptile_serial", us / ROUNDS,
                 f"acc={out['history'][-1]['query_metric']:.2%}"))

    out, us = timed(lambda: reptile_train(
        loss, params, dist, rounds=ROUNDS // 4, alpha=1.0, beta=0.01,
        support=16, epochs=8, clients_per_round=4,
        eval_every=ROUNDS // 4, eval_kwargs=ev, seed=4), repeats=1, warmup=0)
    rows.append((f"fig4/{name}_reptile_batched", us / (ROUNDS // 4),
                 f"acc={out['history'][-1]['query_metric']:.2%}"))


def run():
    rows = []
    _bench("omniglot5", OMNIGLOT_CONV, OmniglotTasks(), rows)
    _bench("kws4", KWS_CONV, KWSTasks(), rows)
    return rows
