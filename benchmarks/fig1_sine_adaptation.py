"""Paper Fig. 1: adaptation quality on the Sine-wave example.

Transfer learning vs Reptile vs TinyReptile, each fine-tuned on 8 support
points for 8 SGD steps on an unseen client; derived = query MSE."""
import functools

import jax
import numpy as np

from benchmarks.common import timed
from repro.configs.paper_models import SINE_MLP
from repro.core import (evaluate_init, reptile_train, tinyreptile_train,
                        transfer_train)
from repro.data import SineTasks
from repro.models.paper_nets import init_paper_model, paper_model_loss

LOSS = functools.partial(paper_model_loss, SINE_MLP)
EVAL = dict(num_tasks=10, support=8, k_steps=8, lr=0.02, query=64)
ROUNDS = 400


def run():
    params = init_paper_model(SINE_MLP, jax.random.PRNGKey(0))
    dist = SineTasks()
    rows = []
    base = evaluate_init(LOSS, params, dist, np.random.default_rng(7), **EVAL)
    rows.append(("fig1/random_init", 0.0, f"mse={base['query_loss']:.3f}"))

    out, us = timed(lambda: tinyreptile_train(
        LOSS, params, dist, rounds=ROUNDS, alpha=1.0, beta=0.02, support=32,
        eval_every=ROUNDS, eval_kwargs=EVAL, seed=1), repeats=1, warmup=0)
    rows.append(("fig1/tinyreptile", us / ROUNDS,
                 f"mse={out['history'][-1]['query_loss']:.3f}"))

    out, us = timed(lambda: reptile_train(
        LOSS, params, dist, rounds=ROUNDS, alpha=1.0, beta=0.02, support=32,
        epochs=8, eval_every=ROUNDS, eval_kwargs=EVAL, seed=1),
        repeats=1, warmup=0)
    rows.append(("fig1/reptile", us / ROUNDS,
                 f"mse={out['history'][-1]['query_loss']:.3f}"))

    out, us = timed(lambda: transfer_train(
        LOSS, params, dist, rounds=ROUNDS, beta=0.02, eval_every=ROUNDS,
        eval_kwargs=EVAL, seed=1), repeats=1, warmup=0)
    rows.append(("fig1/transfer", us / ROUNDS,
                 f"mse={out['history'][-1]['query_loss']:.3f}"))
    return rows
