"""Benchmark driver: one module per paper table/figure + the assignment's
roofline table. Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import argparse
import importlib
import sys
import traceback

from benchmarks.common import emit

MODULES = [
    "benchmarks.table1_models",
    "benchmarks.table2_memory",
    "benchmarks.fig1_sine_adaptation",
    "benchmarks.fig2_convergence",
    "benchmarks.fig3_device_convergence",
    "benchmarks.fig4_omniglot_kws",
    "benchmarks.table34_round_time",
    "benchmarks.engine_bench",
    "benchmarks.fig56_hyperparams",
    "benchmarks.kernels_bench",
    "benchmarks.podclient_collectives",
    "benchmarks.roofline",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on module names")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for modname in MODULES:
        if args.only and args.only not in modname:
            continue
        try:
            mod = importlib.import_module(modname)
            emit(mod.run())
        except Exception:
            failures += 1
            print(f"{modname},0.0,ERROR", file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
