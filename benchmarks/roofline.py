"""Roofline table: reads the dry-run sweep artifacts (results/dryrun/)
and emits per-(arch x shape x mesh): compute / memory / collective terms,
the dominant bottleneck, and the useful-FLOPs ratio. derived column is
the dominant term + its seconds."""
import glob
import json
import os


def run(outdir="results/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        name = f"roofline/{d['arch']}__{d['shape']}__{d.get('mesh','?')}"
        if d.get("status") == "SKIP":
            rows.append((name, 0.0, f"SKIP({d.get('reason','')[:50]})"))
            continue
        if d.get("status") != "OK":
            rows.append((name, 0.0, f"{d.get('status')}"))
            continue
        r = d.get("roofline", {})
        dom = r.get("dominant", "?")
        rows.append((
            name, 0.0,
            f"dom={dom}:{r.get(dom, 0):.4f}s "
            f"compute={r.get('compute_s', 0):.4f} "
            f"memory={r.get('memory_s', 0):.4f} "
            f"collective={r.get('collective_s', 0):.4f} "
            f"useful={d.get('useful_ratio', 0):.3f}"))
    if not rows:
        rows.append(("roofline/none", 0.0,
                     "no sweep artifacts; run python -m repro.launch.sweep"))
    return rows
