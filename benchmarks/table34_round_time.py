"""Paper Tables III-IV: per-round time, Reptile vs TinyReptile (S=32).

On the paper's hardware TinyReptile's local training is up to 16x faster
(no batch stacking / reuse). Here the same effect appears as fewer
sample-gradient evaluations per round: TinyReptile does S single-sample
steps; Reptile does E epochs x S-sample batches (E*S sample-grads).

The local client work is timed through the SAME strategy hooks the round
engine executes (FedStrategy.client_update), so these numbers are the
engine's per-client costs. derived = local train time + speedup ratio."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.configs.paper_models import PAPER_MODELS
from repro.core.strategies import ReptileStrategy, TinyReptileStrategy
from repro.data import KWSTasks, OmniglotTasks, SineTasks
from repro.models.paper_nets import init_paper_model, paper_model_loss

DISTS = {"sine_mlp": SineTasks(), "kws_conv": KWSTasks(),
         "omniglot_conv": OmniglotTasks()}
S = 32


def run():
    rows = []
    rng = np.random.default_rng(0)
    beta = jnp.float32(0.01)
    for name, cfg in PAPER_MODELS.items():
        loss = functools.partial(paper_model_loss, cfg)
        tiny = TinyReptileStrategy(loss)
        rep = ReptileStrategy(loss, epochs=8)
        params = init_paper_model(cfg, jax.random.PRNGKey(0))
        task = DISTS[name].sample_task(rng)
        sup = task.support_batch(rng, S)
        batch = {"x": jnp.asarray(sup["x"]), "y": jnp.asarray(sup["y"])}

        _, us_tiny = timed(
            lambda: jax.block_until_ready(
                tiny.client_update(params, batch, beta)[0]),
            repeats=5)
        _, us_rep = timed(
            lambda: jax.block_until_ready(
                rep.client_update(params, batch, beta)[0]),
            repeats=5)
        rows.append((f"table34/{name}_tinyreptile_local", us_tiny,
                     f"ms={us_tiny/1e3:.2f}"))
        rows.append((f"table34/{name}_reptile_local", us_rep,
                     f"ms={us_rep/1e3:.2f} tiny_speedup={us_rep/us_tiny:.2f}x"))
    return rows
