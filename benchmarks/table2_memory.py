"""Paper Table II: memory requirement, Reptile vs TinyReptile (S=32).
derived = modelled bytes + reduction factor (paper claims >= 2x)."""
from repro.configs.paper_models import PAPER_MODELS
from repro.metering import algorithm_memory_report


def run():
    rows = []
    for name, cfg in PAPER_MODELS.items():
        r = algorithm_memory_report(cfg, support=32)
        rows.append((
            f"table2/{name}", 0.0,
            f"reptile_kb={r['reptile_bytes']/1024:.1f} "
            f"tiny_kb={r['tinyreptile_bytes']/1024:.1f} "
            f"reduction={r['reduction_factor']:.1f}x "
            f"arduino_ok={r['fits_arduino_256kb_tinyreptile']}"))
    return rows
