"""Render the EXPERIMENTS.md §Dry-run and §Roofline tables from
results/dryrun/*.json.

  PYTHONPATH=src python -m benchmarks.report [--outdir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ARCH_ORDER = [
    "llama4-maverick-400b-a17b", "mamba2-130m", "mixtral-8x22b",
    "whisper-tiny", "tinyllama-1.1b", "glm4-9b", "zamba2-1.2b",
    "minicpm-2b", "paligemma-3b", "starcoder2-15b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(outdir):
    cells = {}
    for path in glob.glob(os.path.join(outdir, "*.json")):
        with open(path) as f:
            d = json.load(f)
        cells[(d["arch"], d["shape"], d.get("mesh", "?"))] = d
    return cells


def fmt_bytes(n):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(n) < 1024:
            return f"{n:.1f}{unit}"
        n /= 1024
    return f"{n:.1f}PB"


def dryrun_table(cells, mesh):
    lines = [
        f"### Mesh {mesh}",
        "",
        "| arch | shape | status | HBM/chip (arg+tmp) | HLO flops/chip | "
        "collectives (AG/AR/RS/A2A/CP bytes) | compile s |",
        "|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = cells.get((arch, shape, mesh))
            if d is None:
                lines.append(f"| {arch} | {shape} | MISSING | | | | |")
                continue
            if d["status"] == "SKIP":
                lines.append(f"| {arch} | {shape} | SKIP | "
                             f"{d.get('reason','')[:60]} | | | |")
                continue
            if d["status"] != "OK":
                err = d.get("stderr", d.get("probe_error", ""))[-60:]
                lines.append(
                    f"| {arch} | {shape} | {d['status']} | {err} | | | |")
                continue
            mem = d.get("memory", {})
            hbm = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("temp_size_in_bytes", 0))
            flops = d.get("probe_cost", d.get("cost", {})).get("flops", 0)
            cb = d.get("collective_bytes", {})
            coll = "/".join(fmt_bytes(cb.get(k, 0)) for k in
                            ("all-gather", "all-reduce", "reduce-scatter",
                             "all-to-all", "collective-permute"))
            lines.append(
                f"| {arch} | {shape} | OK | {fmt_bytes(hbm)} | "
                f"{flops:.2e} | {coll} | "
                f"{d.get('timing',{}).get('compile_s','')} |")
    return "\n".join(lines)


def roofline_table(cells, mesh="16x16"):
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPS/HLO | one-line lever |",
        "|---|---|---|---|---|---|---|---|",
    ]
    levers = {
        "compute_s": "skip masked causal blocks / bf16 everywhere",
        "memory_s": "fuse score traffic (flash), cut cache copies, remat",
        "collective_s": "reshard to cut all-gathers; overlap with compute",
    }
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = cells.get((arch, shape, mesh))
            if not d or d.get("status") != "OK":
                continue
            r = d["roofline"]
            dom = r["dominant"]
            lines.append(
                f"| {arch} | {shape} | {r['compute_s']:.4g} | "
                f"{r['memory_s']:.4g} | {r['collective_s']:.4g} | "
                f"**{dom[:-2]}** | {d.get('useful_ratio', 0):.3f} | "
                f"{levers[dom]} |")
    return "\n".join(lines)


def summary(cells):
    ok = sum(1 for d in cells.values() if d["status"] == "OK")
    skip = sum(1 for d in cells.values() if d["status"] == "SKIP")
    bad = sum(1 for d in cells.values()
              if d["status"] not in ("OK", "SKIP"))
    return f"{len(cells)} cells: {ok} OK, {skip} SKIP (documented), {bad} failed"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline"])
    args = ap.parse_args()
    cells = load(args.outdir)
    print(summary(cells))
    print()
    if args.section in ("all", "dryrun"):
        for mesh in ("16x16", "2x16x16"):
            print(dryrun_table(cells, mesh))
            print()
    if args.section in ("all", "roofline"):
        print(roofline_table(cells))


if __name__ == "__main__":
    main()
