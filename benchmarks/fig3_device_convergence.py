"""Paper Fig. 3: convergence on Raspberry Pi (fp32) vs Arduino MCU
(reduced numerical precision). The MCU gate is simulated by casting
weights to bfloat16 after every update — reproducing the paper's finding
that Reptile's batched gradients degrade MORE at low precision than
TinyReptile's per-sample updates. Both algorithms run on the shared
federated round engine (repro.core.engine).
derived = query MSE fp32 vs bf16."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.configs.paper_models import SINE_MLP
from repro.core import reptile_train, tinyreptile_train
from repro.data import SineTasks
from repro.models.paper_nets import init_paper_model, paper_model_loss

EVAL = dict(num_tasks=10, support=8, k_steps=8, lr=0.02, query=64)
ROUNDS = 250


def _lowp_loss(cfg_loss):
    """Simulated MCU: weights pass through bf16 before every forward."""
    def loss(params, batch):
        q = jax.tree.map(
            lambda w: w.astype(jnp.bfloat16).astype(jnp.float32), params)
        return cfg_loss(q, batch)
    return loss


def run():
    loss32 = functools.partial(paper_model_loss, SINE_MLP)
    loss16 = _lowp_loss(loss32)
    params = init_paper_model(SINE_MLP, jax.random.PRNGKey(0))
    dist = SineTasks()
    rows = []
    for dev, loss in (("rpi_fp32", loss32), ("mcu_bf16", loss16)):
        out, us = timed(lambda l=loss: tinyreptile_train(
            l, params, dist, rounds=ROUNDS, alpha=1.0, beta=0.02, support=32,
            eval_every=ROUNDS, eval_kwargs=EVAL, seed=3),
            repeats=1, warmup=0)
        rows.append((f"fig3/tinyreptile_{dev}", us / ROUNDS,
                     f"mse={out['history'][-1]['query_loss']:.3f}"))
        out, us = timed(lambda l=loss: reptile_train(
            l, params, dist, rounds=ROUNDS, alpha=1.0, beta=0.02, support=32,
            epochs=8, eval_every=ROUNDS, eval_kwargs=EVAL, seed=3),
            repeats=1, warmup=0)
        rows.append((f"fig3/reptile_{dev}", us / ROUNDS,
                     f"mse={out['history'][-1]['query_loss']:.3f}"))
    return rows
