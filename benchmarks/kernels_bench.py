"""Kernel microbenchmarks: Pallas (interpret on CPU — correctness-path
timing only) vs the jnp reference under jit. On TPU the pallas_call path
compiles natively; derived here = achieved GB/s of the jit ref path (the
XLA floor the kernel must beat) + allclose check against the oracle."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.kernels import ops, ref


def run():
    rows = []
    key = jax.random.PRNGKey(0)

    # meta_update on a ~8M-param tree
    n = 8 * 1024 * 1024
    w = jax.random.normal(key, (n,), jnp.float32)
    wh = w + 0.01
    jr = jax.jit(lambda a, b: ref.meta_update(a, b, 0.5))
    _, us = timed(lambda: jax.block_until_ready(jr(w, wh)), repeats=5)
    gbs = 3 * n * 4 / (us / 1e6) / 1e9  # 2 reads + 1 write
    ok = np.allclose(np.asarray(ops.meta_update(w[:4096], wh[:4096], 0.5)),
                     np.asarray(ref.meta_update(w[:4096], wh[:4096], 0.5)),
                     rtol=1e-5)
    rows.append(("kernels/meta_update_8M", us,
                 f"xla_floor_GBps={gbs:.1f} pallas_allclose={ok}"))

    # flash_decode 32k cache
    B, H, Kv, hd, S = 4, 8, 4, 64, 32768
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, Kv, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, Kv, hd), jnp.float32)
    jr = jax.jit(lambda q, k, v: ref.flash_decode(q, k, v, S))
    _, us = timed(lambda: jax.block_until_ready(jr(q, kc, vc)), repeats=3)
    bytes_moved = 2 * B * S * Kv * hd * 4
    ok = np.allclose(
        np.asarray(ops.flash_decode(q[:1], kc[:1, :2048], vc[:1, :2048],
                                    2048)),
        np.asarray(ref.flash_decode(q[:1], kc[:1, :2048], vc[:1, :2048],
                                    2048)), rtol=3e-4, atol=3e-4)
    rows.append(("kernels/flash_decode_32k", us,
                 f"xla_floor_GBps={bytes_moved/(us/1e6)/1e9:.1f} "
                 f"pallas_allclose={ok}"))

    # ssd_scan mamba2-130m geometry, S=4096
    Bm_, Hh, nc, Q, P, N = 1, 24, 16, 256, 64, 128
    ks = jax.random.split(key, 4)
    xd = jax.random.normal(ks[0], (Bm_, Hh, nc, Q, P), jnp.float32)
    dA = -jnp.abs(jax.random.normal(ks[1], (Bm_, Hh, nc, Q))) * 0.1
    Bmat = jax.random.normal(ks[2], (Bm_, nc, Q, N)) * 0.3
    Cmat = jax.random.normal(ks[3], (Bm_, nc, Q, N)) * 0.3
    jr = jax.jit(ref.ssd_scan)
    _, us = timed(lambda: jax.block_until_ready(jr(xd, dA, Bmat, Cmat)),
                  repeats=2)
    flops = 2 * Bm_ * Hh * nc * (Q * Q * N + 2 * Q * Q * P + 2 * Q * P * N)
    small = (xd[:, :2, :2], dA[:, :2, :2], Bmat[:, :2], Cmat[:, :2])
    ok = np.allclose(np.asarray(ops.ssd_scan(*small)),
                     np.asarray(ref.ssd_scan(*small)), rtol=2e-4, atol=2e-4)
    rows.append(("kernels/ssd_scan_4k", us,
                 f"xla_floor_GFLOPs={flops/(us/1e6)/1e9:.1f} "
                 f"pallas_allclose={ok}"))
    return rows
