"""Cohort vs pod-client federated schedules on the 2x16x16 mesh.

The paper's claim, at pod scale: TinyReptile's serial/interpolation
schema needs O(1) cross-client exchanges per round, while a synchronous
cohort all-reduces gradients every inner step. Here: clients = pods.
We lower both steps (probe mode, L=1, K=2) and split the collective
bytes into intra-pod vs cross-pod by parsing replica_groups.

Run in a fresh process (needs 512 host devices):
  PYTHONPATH=src python -m benchmarks.podclient_collectives
"""
import os

if __name__ == "__main__":
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512")

import json  # noqa: E402
import re  # noqa: E402


def measure():
    import dataclasses
    import jax
    from repro.configs import get_arch, get_shape
    from repro.core.federated import make_pod_client_meta_step
    from repro.launch import specs as specs_mod
    from repro.launch.mesh import make_production_mesh
    from repro.models import build_model
    from repro.runtime import steps as steps_lib
    from repro.runtime.flags import probe_scope
    from repro.runtime.shardctx import mesh_context

    mesh = make_production_mesh(multi_pod=True)
    cfg = dataclasses.replace(get_arch("tinyllama-1.1b"), num_layers=1,
                              dtype="float32")
    shape = get_shape("train_4k")
    model = build_model(cfg)

    import numpy as np

    def groups_cross_pod(line, half=256):
        """True iff any replica group mixes devices < half and >= half.
        Handles explicit {{...}} lists and iota [G,S]<=[dims]T(perm)."""
        g = re.search(r"replica_groups=(\{\{.*?\}\}|\[[^ ]*)", line)
        if not g:
            return False  # no groups = all devices = crosses pods
        txt = g.group(1)
        if txt.startswith("{{"):
            for b in re.findall(r"\{([\d,]+)\}", txt):
                ds = [int(x) for x in b.split(",") if x]
                if ds and (min(ds) < half <= max(ds)):
                    return True
            return False
        m = re.match(r"\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?", txt)
        if not m:
            return True  # unknown format: conservative
        G, S = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(x) for x in m.group(4).split(",")])
        ids = ids.reshape(G, S)
        return bool(((ids.min(1) < half) & (ids.max(1) >= half)).any())

    def coll_split(hlo):
        intra = cross = 0
        for line in hlo.splitlines():
            m = re.search(
                r"=\s*(.*?)\s+(all-gather|all-reduce|reduce-scatter|"
                r"all-to-all|collective-permute)\(", line)
            if not m:
                continue
            nbytes = 0
            for dt, dims in re.findall(r"(\w+)\[([\d,]*)\]", m.group(1)):
                sz = {"bf16": 2, "f32": 4, "s32": 4, "u32": 4,
                      "pred": 1}.get(dt)
                if sz is None:
                    continue
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                nbytes += n * sz
            if "collective-permute" in line:
                # permutes list source_target_pairs instead
                st = re.search(r"source_target_pairs=\{(.*?)\}\s*(,|$)", line)
                is_cross = True
                if st:
                    pairs = re.findall(r"\{(\d+),(\d+)\}", st.group(0))
                    is_cross = any((int(a) < 256) != (int(b) < 256)
                                   for a, b in pairs)
            else:
                is_cross = groups_cross_pod(line)
            if is_cross:
                cross += nbytes
            else:
                intra += nbytes
        return intra, cross

    out = {}
    with probe_scope(True), mesh_context(mesh):
        params = specs_mod.param_specs(cfg, mesh)
        batch = specs_mod.train_batch_specs(cfg, shape, mesh, k_inner=2)
        cohort = steps_lib.make_meta_train_step(model)
        hlo = jax.jit(cohort).lower(params, batch).compile().as_text()
        out["cohort"] = coll_split(hlo)
        pod = make_pod_client_meta_step(model, mesh)
        hlo = jax.jit(pod).lower(params, batch).compile().as_text()
        out["pod_client"] = coll_split(hlo)
    return out


def run():
    """Benchmark-driver entry: runs in a subprocess (needs 512 devices)."""
    import subprocess
    import sys
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-m",
                        "benchmarks.podclient_collectives"],
                       capture_output=True, text=True, env=env, timeout=2400)
    rows = []
    if r.returncode != 0:
        return [("podclient/error", 0.0, r.stderr[-120:])]
    d = json.loads(r.stdout.strip().splitlines()[-1])
    for mode, (intra, cross) in d.items():
        rows.append((f"podclient/{mode}", 0.0,
                     f"intra_pod={intra/1e6:.1f}MB cross_pod={cross/1e6:.1f}MB"))
    return rows


if __name__ == "__main__":
    print(json.dumps(measure()))
