"""Paper Appendix A (Figs. 5-6): hyperparameter recipe.

Fig. 5: client LR beta x training support size S_training.
Fig. 6: testing support size S_testing (0 -> no adaptation; the paper
shows even ONE sample helps dramatically).
derived = query MSE on sine."""
import functools

import jax
import numpy as np

from benchmarks.common import timed
from repro.configs.paper_models import SINE_MLP
from repro.core import evaluate_init, tinyreptile_train
from repro.data import SineTasks
from repro.models.paper_nets import init_paper_model, paper_model_loss

LOSS = functools.partial(paper_model_loss, SINE_MLP)
ROUNDS = 200


def run():
    params = init_paper_model(SINE_MLP, jax.random.PRNGKey(0))
    dist = SineTasks()
    rows = []
    ev = dict(num_tasks=8, support=8, k_steps=8, lr=0.02, query=64)

    # Fig. 5: beta x S_training grid
    for beta in (0.002, 0.01, 0.02):
        for s_train in (8, 32):
            out, us = timed(lambda b=beta, s=s_train: tinyreptile_train(
                LOSS, params, dist, rounds=ROUNDS, alpha=1.0, beta=b,
                support=s, eval_every=ROUNDS, eval_kwargs=ev, seed=5),
                repeats=1, warmup=0)
            rows.append((f"fig5/beta{beta}_S{s_train}", us / ROUNDS,
                         f"mse={out['history'][-1]['query_loss']:.3f}"))

    # Fig. 6: S_testing sweep on one trained init
    trained = tinyreptile_train(LOSS, params, dist, rounds=ROUNDS, alpha=1.0,
                                beta=0.02, support=32, seed=5)["params"]
    for s_test in (0, 1, 2, 4, 8, 16):
        e = evaluate_init(LOSS, trained, dist, np.random.default_rng(9),
                          num_tasks=10, support=s_test, k_steps=8, lr=0.02,
                          query=64)
        rows.append((f"fig6/S_test{s_test}", 0.0,
                     f"mse={e['query_loss']:.3f}"))
    return rows
