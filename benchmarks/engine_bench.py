"""Engine speedup tracking: rounds/sec for (1) the pre-refactor per-client
Python loops, (2) the PR-1 synchronous engine (prefetch=0, reference
per-task sampling), and (3) the pipelined engine (vectorized block
sampling + double-buffered background prefetch), on the paper's sine
task. Acceptance floors: engine >= 3x the Python loops (PR 1) and
pipelined >= 1.5x the synchronous engine (PR 2) for batched-client
Reptile (clients_per_round=8) on CPU.

A "heterogeneity" section (PR 3) benchmarks the ClientSchedule layer on
the same batched-Reptile cohort: full participation vs 50% partial
participation vs a straggler cohort — rounds/sec plus the transport
bill (total and per-client min/max), showing that scenario plugins ride
the fixed-shape scan at full speed while partial participation halves
the bytes.

A "pool_async" section (PR 4) benchmarks persistent client identities:
the same cohort seated from a 32-client ClientPool — uniform seating
(floor: >= 0.9x the anonymous-cohort legacy path), diurnal-availability
check-ins, and FedBuff buffered aggregation (flush every 16 arrivals)
— with the block runner's trace counters recorded to pin the
one-jit-trace-per-config contract.

A "ckpt_overhead" section (PR 7) times the preemption-safety layer:
the pipelined cohort on the wide fleet-simulation MLP (support 128)
with the async round-state snapshotter armed at --ckpt-every 10 vs the
same run without a checkpoint directory, plus the fixed per-snapshot
cost in ms. Floor: < 5% rounds/sec cost (the writer thread keeps
device->host transfer and npz serialization off the scan's critical
path).

An "int8_training" section (PR 6) benchmarks TIFeD integer-only local
training (tifed_train: int8 DFA client epochs, native int8 uplinks,
quantization-aware aggregation) against the fp32 batched-Reptile
baseline at the SAME cohort/model/support/epochs. Floors: tifed
pipelined rounds/sec >= 1.5x fp32 reptile pipelined, uplink bytes at
the int8 rate (0.25x the fp32 bill), trace_count 1.

A "pool_scale" section (PR 8) sweeps the persistent-fleet size N in
{256, 10^4, 10^6} at a fixed cohort of 256 (vectorized counter-derived
identity, host-resident slabs): rounds/sec per N plus a live
host-memory meter (repro.metering.memory.MemoryMeter) and the size of
the pool's compact host snapshot. Floor: the N=10^6 run stays within
1.2x of the N=256 run's rounds/sec — per-round host work is O(cohort),
and the only O(N) residual is the int32 identity (16 bytes/client:
check-in counter + 3 slab fields).

A "mesh_scaling" section (PR 5) sweeps cohort size x device count for
the client-sharded engine (run_federated(mesh=...)) on a wider sine
MLP with a longer support stream, demonstrated on CPU CI under
XLA_FLAGS=--xla_force_host_platform_device_count=8 (bench() spawns the
forced-device subprocess itself when the parent is single-device).
Floors: >= 2x rounds/sec at cohort 64 on 8 host devices vs 1 device,
>= 1.5x at cohort 32 on 4, trace_count 1 for every sharded config.

An "lm_mesh" section (PR 10) benchmarks federated meta-learning over a
LARGE client model: the reduced transformer on heterogeneous LM-domain
clients (cohort 8), 1-D client mesh (phi replicated) vs the 2-D
(clients x model) mesh (phi's weight matrices split over the model
axis per its ModelPartitioner, GSPMD-scheduled collectives) —
rounds/sec plus the analytic per-device parameter bytes of each
layout. Floor: 2-D phi bytes <= 0.6x the replicated 1-D layout
(armed under --smoke; the mesh2d CI job runs --lm-mesh-only --smoke
on 4 forced host devices).

A "serving" section (PR 9) benchmarks the continuous-batching
`serving.AdaptationServer` on the meta-learned sine-MLP init: sustained
client-adaptation requests/sec plus p50/p95/p99 submit->retire latency
for the fp32 online-SGD route and the int8 TIFeD route, each under a
uniform-k and an adversarial ragged-k stream. Floors: >= 500 req/s at
k=10 for fp32 on CPU smoke, exactly 1 jit trace per server config.

Every section runs under a per-section wall-clock budget in --smoke
mode (`_SectionBudget`): a section that overruns raises loudly with its
elapsed time instead of silently eating the CI job's timeout, and each
section's seconds land in the payload as ``section_seconds``.

Writes BENCH_engine.json next to the repo root (same spirit as the
results/dryrun JSON cells consumed by benchmarks/report.py) so the
speedup is tracked across future PRs.

  PYTHONPATH=src python -m benchmarks.engine_bench            # full run
  PYTHONPATH=src python -m benchmarks.engine_bench --json     # JSON out
  PYTHONPATH=src python -m benchmarks.engine_bench --rounds 8 --smoke
                       # tier-1-budget smoke: pipeline on/off +
                       # heterogeneity only (no legacy Python loops)
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import SINE_MLP
from repro.core import (BufferedAggregation, ClientPool, CommChannel,
                        DiurnalAvailability, PartialParticipation,
                        StragglerSampling, UniformSampling, client_mesh,
                        reptile_train, tifed_train, tinyreptile_train)
from repro.core.engine import _block_runner
from repro.core.meta import finetune_batch, finetune_online, tree_lerp
from repro.core.strategies import (ReptileStrategy, TifedStrategy,
                                   TinyReptileStrategy)
from repro.data import SineTasks
from repro.models.paper_nets import (init_paper_model, paper_model_loss,
                                     relu_mlp_loss)

LOSS = functools.partial(paper_model_loss, SINE_MLP)
ROUNDS = 120
SUPPORT = 32
OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_engine.json")

# -- mesh-scaling workload (PR 5) -------------------------------------------
# The sharded client axis is demonstrated on a WIDER sine MLP (96x96
# hidden, ~9.6k params) with a longer support stream: a vmapped cohort
# carries every client's inner-loop parameter state across every scan
# step (cohort x params x fp32 — ~1.2 MB at cohort 32, ~2.5 MB at 64),
# which falls out of a single CPU device's cache, while each mesh
# shard's slice stays cache-resident — exactly the fleet-simulation
# regime sharding the client axis targets. The paper-faithful 32x32
# net stays the workload for every other section.
MESH_MLP = dataclasses.replace(SINE_MLP, name="sine_mlp_wide",
                               hidden=(96, 96))
MESH_LOSS = functools.partial(paper_model_loss, MESH_MLP)
MESH_SUPPORT = 128
MESH_DEVICES = (1, 4, 8)
MESH_COHORTS = (32, 64)


# -- pre-refactor loops (one host->device dispatch per client per round) ----

def _python_loop_tinyreptile(params, dist, rounds):
    rng = np.random.default_rng(0)
    phi = params
    for rnd in range(rounds):
        alpha_t = 1.0 * (1 - rnd / rounds)
        task = dist.sample_task(rng)
        xs, ys = zip(*task.support_stream(rng, SUPPORT))
        phi_hat, _ = finetune_online(LOSS, phi, jnp.stack(xs), jnp.stack(ys),
                                     jnp.float32(0.02))
        phi = tree_lerp(phi, phi_hat, alpha_t)
    return jax.block_until_ready(jax.tree.leaves(phi)[0])


def _python_loop_reptile(params, dist, rounds, clients, epochs=8):
    rng = np.random.default_rng(0)
    phi = params
    for rnd in range(rounds):
        alpha_t = 1.0 * (1 - rnd / rounds)
        deltas = None
        for _ in range(clients):
            task = dist.sample_task(rng)
            sup = task.support_batch(rng, SUPPORT)
            phi_hat, _ = finetune_batch(LOSS, phi, sup, epochs,
                                        jnp.float32(0.02))
            d = jax.tree.map(lambda q, p: q - p, phi_hat, phi)
            deltas = d if deltas is None else jax.tree.map(
                lambda a, b: a + b, deltas, d)
        phi = jax.tree.map(lambda p, d: p + alpha_t * d / clients,
                           phi, deltas)
    return jax.block_until_ready(jax.tree.leaves(phi)[0])


class _SectionBudget:
    """Per-section wall-clock guard for --smoke runs. ``check(name)``
    closes the section that just ran, records its elapsed seconds, and
    (when armed) raises RuntimeError past the budget — so a section
    that regresses from seconds to minutes fails the CI smoke loudly
    with a name and a number instead of burning the job's 45-minute
    timeout. Full runs record seconds but never raise (the canonical
    120-round numbers are allowed to be slow)."""

    def __init__(self, enabled: bool, per_section_s: float = 300.0):
        self.enabled = enabled
        self.limit = per_section_s
        self.seconds = {}
        self._t0 = time.perf_counter()

    def check(self, name: str) -> None:
        now = time.perf_counter()
        dt = now - self._t0
        self._t0 = now
        self.seconds[name] = round(dt, 2)
        if self.enabled and dt > self.limit:
            raise RuntimeError(
                f"--smoke section {name!r} took {dt:.1f}s, over its "
                f"{self.limit:.0f}s budget — smoke sections must stay "
                f"CI-cheap; profile the regression or move the workload "
                f"to the full bench")


def _rounds_per_sec(fn, rounds, reps: int = 3, warm: bool = True):
    """Warmup once (compile + caches; skipped when the caller already
    ran ``fn`` for its output), then best of ``reps`` timed runs (the
    timeit convention: min elapsed suppresses host load jitter — one
    120-round pass is a fraction of a second, far too short for a
    single sample to be a stable ratio on a shared machine)."""
    if warm:
        fn()                              # warmup: compile + caches
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return rounds / best


def mesh_scaling(rounds: int = ROUNDS, smoke: bool = False):
    """The mesh_scaling section: rounds/sec for cohort size x device
    count, sharding the client axis over the devices THIS process has
    (run under XLA_FLAGS=--xla_force_host_platform_device_count=8 on
    CPU; ``bench`` spawns that subprocess automatically when the parent
    has a single device). devices=1 is the legacy mesh=None engine —
    the strongest single-device baseline. Acceptance floors (see
    docs/BENCHMARKS.md): >= 2x rounds/sec at cohort 64 on 8 host
    devices vs 1, >= 1.5x at cohort 32 on 4, every sharded config at
    trace_count 1.

    Returns (rows, section).
    """
    ndev = len(jax.devices())
    if ndev < 2:
        raise RuntimeError(
            "mesh_scaling needs multiple devices; run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    if smoke:
        devices = tuple(dict.fromkeys((1, min(4, ndev))))
        cohorts = (32,)
    else:
        devices = tuple(d for d in MESH_DEVICES if d <= ndev)
        if len(devices) < 2:
            # a 2-3-device host: none of the canonical sharded device
            # counts fit, but the host's own width still demonstrates
            # the sweep (better than silently recording baselines only)
            devices = (1, ndev)
        cohorts = MESH_COHORTS
    params = init_paper_model(MESH_MLP, jax.random.PRNGKey(0))
    dist = SineTasks()
    # 16-round scan blocks: long enough that per-block dispatch +
    # collective warm-up amortizes on every device count, short enough
    # that prefetch still overlaps host sampling
    pipe = dict(prefetch=2, max_block=16)
    section = {"devices_available": ndev, "model": MESH_MLP.name,
               "support": MESH_SUPPORT, "devices": list(devices),
               "cohorts": list(cohorts)}
    rows = []
    for ci, cohort in enumerate(cohorts):
        # a distinct beta per cohort keeps every (cohort, device) pair on
        # its OWN cached runner, so trace_count == 1 really pins one jit
        # trace per config (cohort size changes the block shape)
        beta = 0.02 + 1e-4 * ci
        for d in devices:
            mesh = None if d == 1 else client_mesh(d)

            def run(mesh=mesh, cohort=cohort, beta=beta):
                out = tinyreptile_train(
                    MESH_LOSS, params, dist, rounds=rounds, alpha=1.0,
                    beta=beta, support=MESH_SUPPORT, seed=0,
                    clients_per_round=cohort, sampler="vectorized",
                    mesh=mesh, **pipe)
                jax.block_until_ready(jax.tree.leaves(out["params"])[0])
            rps = _rounds_per_sec(run, rounds)
            row = {"rounds_per_sec": round(rps, 2)}
            if mesh is not None:
                runner = _block_runner(
                    TinyReptileStrategy(MESH_LOSS, use_pallas=None),
                    beta, CommChannel(), scheduled=True, mesh=mesh,
                    masked=False)
                row["trace_count"] = runner.trace_count
            section[f"c{cohort}_d{d}"] = row
            rows.append((f"engine/mesh_c{cohort}_d{d}", 1e6 / rps,
                         f"rounds_per_sec={rps:.1f}"))
    for cohort in cohorts:
        base = section[f"c{cohort}_d1"]["rounds_per_sec"]
        for d in devices[1:]:
            section[f"c{cohort}_d{d}"]["speedup_vs_1dev"] = round(
                section[f"c{cohort}_d{d}"]["rounds_per_sec"] / base, 2)
    return rows, section


def _mesh_scaling_subprocess(rounds: int, devices: int = 8):
    """Run ``mesh_scaling`` in a child process with forced host devices
    (the device count is fixed at backend init, so the parent cannot
    grow its own); returns the section dict."""
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if "host_platform_device_count" not in f]
    env["XLA_FLAGS"] = " ".join(
        flags + [f"--xla_force_host_platform_device_count={devices}"])
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.engine_bench", "--mesh-only",
         "--rounds", str(rounds)],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if r.returncode != 0:
        return {"status": "FAILED", "stderr": r.stderr[-2000:]}
    try:
        # tolerate stray non-JSON stdout from the child's imports: the
        # section object is the last thing printed, starting at its
        # opening brace
        return json.loads(r.stdout[r.stdout.index("{"):])
    except (ValueError, json.JSONDecodeError):
        return {"status": "FAILED",
                "stderr": f"unparseable child stdout: {r.stdout[-2000:]!r}"}


def lm_mesh_bench(rounds: int = ROUNDS, smoke: bool = False):
    """The lm_mesh section (PR 10): federated meta-learning over a
    LARGE client model — a reduced transformer whose clients are
    heterogeneous LM domains (LmTaskDistribution) — comparing the 1-D
    client mesh (phi fully replicated on every device) against the 2-D
    (clients, model) mesh (phi's weight matrices split over the model
    axis per the transformer ModelPartitioner, GSPMD route). Records
    rounds/sec for both layouts, the live host-memory meter, and the
    ANALYTIC per-device parameter bytes of each placed phi
    (leaf.sharding.shard_shape — device memory meters read 0 on forced
    host devices). Acceptance floor (docs/BENCHMARKS.md): 2-D
    per-device parameter bytes <= 0.6x the replicated 1-D layout —
    enforced here under --smoke (the mesh2d CI job's contract).

    Needs >= 4 devices for the 2x2 mesh; on CPU run under
    XLA_FLAGS=--xla_force_host_platform_device_count=4.

    Returns (rows, section).
    """
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.configs import get_arch
    from repro.core import run_federated
    from repro.data import LmTaskDistribution, lm_loss
    from repro.metering.memory import MemoryMeter
    from repro.runtime.sharding import (DEFAULT_PARTITIONER,
                                        client_model_mesh,
                                        per_device_param_bytes)

    ndev = len(jax.devices())
    if ndev < 4:
        raise RuntimeError(
            "lm_mesh needs >= 4 devices (a 2x2 clients x model mesh); "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=4")
    from repro.models import build_model
    cfg = dataclasses.replace(
        get_arch("tinyllama-1.1b").reduced(), name="tinyllama-bench",
        vocab_size=256, d_model=128, d_ff=256, num_heads=4,
        num_kv_heads=4, head_dim=32)
    model = build_model(cfg)
    lm_dist = LmTaskDistribution(cfg.vocab_size, 32)
    phi = model.init(jax.random.PRNGKey(0))
    strategy = ReptileStrategy(lm_loss(model), epochs=2, use_pallas=None)
    lm_rounds = 6 if smoke else min(rounds, 24)
    param_count = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(phi))
    section = {"model": cfg.name, "param_count": param_count,
               "cohort": 8, "seq": 32, "rounds": lm_rounds}
    cases = (("1d_clients4", client_mesh(4)),
             ("2d_clients2_model2", client_model_mesh(2, 2)))
    rows, phi_bytes = [], {}
    for name, mesh in cases:
        model_sharded = "model" in mesh.axis_names
        meter = MemoryMeter()

        def run(mesh=mesh):
            out = run_federated(
                phi, lm_dist, strategy, rounds=lm_rounds,
                clients_per_round=8, alpha=1.0, beta=0.02, support=4,
                seed=0, mesh=mesh, prefetch=2,
                max_block=max(1, lm_rounds // 2))
            jax.block_until_ready(jax.tree.leaves(out["params"])[0])
        rps = _rounds_per_sec(run, lm_rounds, reps=2 if smoke else 3)
        mem = meter.report()
        placed = jax.device_put(
            phi, DEFAULT_PARTITIONER.shardings(phi, mesh) if model_sharded
            else NamedSharding(mesh, PartitionSpec()))
        phi_bytes[name] = per_device_param_bytes(placed)
        section[name] = {
            "rounds_per_sec": round(rps, 2),
            "per_device_param_bytes": phi_bytes[name],
            "host_peak_growth_mb": round(
                mem["host_peak_growth_bytes"] / 2 ** 20, 1),
        }
        rows.append((f"engine/lm_mesh_{name}", 1e6 / rps,
                     f"rounds_per_sec={rps:.2f} "
                     f"per_device_param_bytes={phi_bytes[name]}"))
    ratio = phi_bytes["2d_clients2_model2"] / phi_bytes["1d_clients4"]
    section["param_bytes_2d_over_1d"] = round(ratio, 3)
    if smoke and ratio > 0.6:
        raise RuntimeError(
            f"lm_mesh floor violated: 2-D per-device parameter bytes "
            f"must be <= 0.6x the replicated 1-D layout, got "
            f"{ratio:.3f} ({phi_bytes})")
    return rows, section


def _lm_mesh_subprocess(rounds: int, devices: int = 4):
    """Run ``lm_mesh_bench`` in a child with forced host devices (the
    _mesh_scaling_subprocess pattern); returns the section dict."""
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if "host_platform_device_count" not in f]
    env["XLA_FLAGS"] = " ".join(
        flags + [f"--xla_force_host_platform_device_count={devices}"])
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.engine_bench",
         "--lm-mesh-only", "--rounds", str(rounds)],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if r.returncode != 0:
        return {"status": "FAILED", "stderr": r.stderr[-2000:]}
    try:
        return json.loads(r.stdout[r.stdout.index("{"):])
    except (ValueError, json.JSONDecodeError):
        return {"status": "FAILED",
                "stderr": f"unparseable child stdout: {r.stdout[-2000:]!r}"}


def serving_bench(smoke: bool = False):
    """The serving section: sustained requests/sec + p50/p95/p99
    latency for the continuous-batching AdaptationServer, fp32 and int8
    routes, each under a uniform-k stream (every request asks the full
    budget — the paper's k=10 deployment fine-tune) and an adversarial
    ragged-k stream (k cycles pseudo-randomly over [1, k_max], the
    regime continuous batching exists for). Acceptance floors (see
    docs/SERVING.md): fp32 uniform k=10 >= 500 req/s on CPU smoke;
    exactly 1 jit trace per server across warmup + the timed stream.

    Returns (rows, section).
    """
    from repro.core.strategies import tifed_requantize
    from repro.metering import MetricsTracker
    from repro.serving import AdaptationServer, Fp32Adapter, TifedAdapter

    SLOTS, SPT = 64, 5
    phi32 = init_paper_model(SINE_MLP, jax.random.PRNGKey(0))
    configs = [
        ("fp32", Fp32Adapter(loss_fn=LOSS), phi32,
         dict(support=10, query=20, k_max=10,
              requests=512 if smoke else 4096)),
        ("tifed", TifedAdapter(support=8, k_max=6),
         tifed_requantize(phi32),
         dict(support=8, query=20, k_max=6,
              requests=256 if smoke else 2048)),
    ]
    section = {"slots": SLOTS, "steps_per_tick": SPT,
               "model": SINE_MLP.name}
    rows = []
    for name, adapter, phi, cfg in configs:
        rng = np.random.default_rng(0)

        def make_reqs(n, k_fn, cfg=cfg, rng=rng):
            reqs = []
            for i in range(n):
                a = rng.uniform(0.1, 5.0)
                b = rng.uniform(0.0, np.pi)
                sx = rng.uniform(-5, 5,
                                 (cfg["support"], 1)).astype(np.float32)
                qx = rng.uniform(-5, 5,
                                 (cfg["query"], 1)).astype(np.float32)
                reqs.append((sx, np.float32(a * np.sin(sx + b)), qx,
                             np.float32(a * np.sin(qx + b)), k_fn(i)))
            return reqs

        strat_sec = {k: cfg[k] for k in ("support", "query", "k_max",
                                         "requests")}
        for wname, k_fn in (
                ("uniform_k_max", lambda i, c=cfg: c["k_max"]),
                ("ragged", lambda i, c=cfg: 1 + (i * 7919) % c["k_max"])):
            server = AdaptationServer(phi, adapter, slots=SLOTS,
                                      k_max=cfg["k_max"],
                                      steps_per_tick=SPT)
            reqs = make_reqs(cfg["requests"], k_fn)
            for r in reqs[:SLOTS]:        # warm the (single) jit trace
                server.submit(*r)
            server.drain()
            server.reset()
            tracker = MetricsTracker()    # timed-stream latencies only
            server.metrics = tracker
            t0 = time.perf_counter()
            for r in reqs:
                server.submit(*r)
            done = server.drain()
            dt = time.perf_counter() - t0
            rps = len(done) / dt
            pct = tracker.percentiles("serve.latency_ms")
            strat_sec[wname] = {
                "req_per_s": round(rps, 1),
                "p50_ms": round(pct["p50"], 3),
                "p95_ms": round(pct["p95"], 3),
                "p99_ms": round(pct["p99"], 3),
                "ticks": server.ticks,
                "trace_count": server.trace_count,
            }
            rows.append((f"engine/serving_{name}_{wname}", 1e6 / rps,
                         f"req_per_s={rps:.1f} p99_ms={pct['p99']:.2f}"))
            if server.trace_count != 1:
                raise RuntimeError(
                    f"serving {name}/{wname}: {server.trace_count} jit "
                    f"traces across warmup + refills (contract: exactly "
                    f"1 per (adapter, slots, shapes) config)")
            if smoke and name == "fp32" and wname == "uniform_k_max" \
                    and rps < 500:
                raise RuntimeError(
                    f"serving smoke floor: fp32 k=10 sustained only "
                    f"{rps:.0f} req/s < 500 (slots={SLOTS}, "
                    f"steps_per_tick={SPT})")
        section[name] = strat_sec
    return rows, section


def bench(rounds: int = ROUNDS, smoke: bool = False):
    """Returns (rows, payload). ``smoke`` skips the slow legacy Python
    loops and only compares pipeline on vs off (tier-1 time budget)."""
    params = init_paper_model(SINE_MLP, jax.random.PRNGKey(0))
    dist = SineTasks()
    results = {}
    budget = _SectionBudget(enabled=smoke)

    # engine kwargs: PR-1 synchronous baseline vs the pipelined fast path.
    # The pipelined config caps blocks so the run splits into >= 4 blocks
    # and the prefetch thread actually overlaps host sampling of block N+1
    # with device compute on block N (one monolithic block would
    # degenerate to inline staging with nothing to overlap) — also at
    # smoke round counts.
    sync = dict(prefetch=0, sampler="reference")
    piped = dict(prefetch=2, sampler="vectorized",
                 max_block=min(16, max(1, rounds // 4)))

    cases = [
        ("tinyreptile",
         lambda: _python_loop_tinyreptile(params, dist, rounds),
         lambda kw: tinyreptile_train(LOSS, params, dist, rounds=rounds,
                                      alpha=1.0, beta=0.02, support=SUPPORT,
                                      seed=0, **kw)),
        ("reptile_batched_c8",
         lambda: _python_loop_reptile(params, dist, rounds, clients=8),
         lambda kw: reptile_train(LOSS, params, dist, rounds=rounds,
                                  alpha=1.0, beta=0.02, support=SUPPORT,
                                  epochs=8, clients_per_round=8, seed=0,
                                  **kw)),
    ]
    def synced(engine_fn, kw):
        # the engine returns as soon as the last block is dispatched;
        # block on the result so device compute is inside the timing
        out = engine_fn(kw)
        return jax.block_until_ready(jax.tree.leaves(out["params"])[0])

    rows = []
    for name, legacy_fn, engine_fn in cases:
        sync_rps = _rounds_per_sec(lambda: synced(engine_fn, sync), rounds)
        piped_rps = _rounds_per_sec(lambda: synced(engine_fn, piped), rounds)
        pipeline_speedup = piped_rps / sync_rps
        res = {"engine_sync_rounds_per_sec": round(sync_rps, 2),
               "engine_pipelined_rounds_per_sec": round(piped_rps, 2),
               "pipeline_speedup": round(pipeline_speedup, 2)}
        if not smoke:
            legacy_rps = _rounds_per_sec(legacy_fn, rounds)
            res["python_loop_rounds_per_sec"] = round(legacy_rps, 2)
            res["engine_speedup"] = round(sync_rps / legacy_rps, 2)
            res["pipelined_vs_python_loop"] = round(piped_rps / legacy_rps, 2)
            rows.append((f"engine/{name}_python_loop", 1e6 / legacy_rps,
                         f"rounds_per_sec={legacy_rps:.1f}"))
        results[name] = res
        rows.append((f"engine/{name}_engine_sync", 1e6 / sync_rps,
                     f"rounds_per_sec={sync_rps:.1f}"))
        rows.append((f"engine/{name}_engine_pipelined", 1e6 / piped_rps,
                     f"rounds_per_sec={piped_rps:.1f} "
                     f"pipeline_speedup={pipeline_speedup:.2f}x"))
    budget.check("pipeline")

    # -- int8 training: TIFeD integer DFA vs the fp32 reptile baseline --
    # Same cohort (8), model (SINE_MLP shapes), support, and epoch count
    # as reptile_batched_c8 — the matched-workload ratio the PR-6
    # acceptance floor (>= 1.5x) is judged on. The bytes ratio pins the
    # native int8 uplink bill against the analytic fp32 bill for the
    # same traffic (2 * C * rounds * fp32 payload): exactly 0.25.
    int8_ch = CommChannel("int8", quantize=False)

    def tifed_fn(kw):
        return tifed_train(params, dist, rounds=rounds, alpha=1.0,
                           support=SUPPORT, epochs=8, clients_per_round=8,
                           seed=0, channel=int8_ch, **kw)
    # sync and piped use different block shapes, so each config traces
    # once on the shared cached runner; pin the piped config's count as
    # a delta (1 = retrace-free across its repeated timed runs)
    runner = _block_runner(TifedStrategy(relu_mlp_loss, epochs=8), 0.0,
                           int8_ch, scheduled=False)
    t_sync = _rounds_per_sec(lambda: synced(tifed_fn, sync), rounds)
    traces_before = runner.trace_count
    t_piped = _rounds_per_sec(lambda: synced(tifed_fn, piped), rounds)
    out = tifed_fn(piped)
    fp32_rps = results["reptile_batched_c8"]["engine_pipelined_rounds_per_sec"]
    fp32_bytes = 2 * 8 * rounds * CommChannel().payload_bytes(params)
    results["int8_training"] = {
        "engine_sync_rounds_per_sec": round(t_sync, 2),
        "engine_pipelined_rounds_per_sec": round(t_piped, 2),
        "pipeline_speedup": round(t_piped / t_sync, 2),
        "vs_fp32_reptile": round(t_piped / fp32_rps, 2),
        "comm_bytes": out["comm_bytes"],
        "bytes_vs_fp32": round(out["comm_bytes"] / fp32_bytes, 3),
        "trace_count": runner.trace_count - traces_before,
    }
    rows.append(("engine/int8_tifed_pipelined", 1e6 / t_piped,
                 f"rounds_per_sec={t_piped:.1f} "
                 f"vs_fp32_reptile={t_piped / fp32_rps:.2f}x "
                 f"bytes_vs_fp32={out['comm_bytes'] / fp32_bytes:.3f}"))
    budget.check("int8_training")

    # -- heterogeneity: the ClientSchedule layer on the batched cohort --
    cohorts = [
        ("full_participation", UniformSampling("vectorized")),
        ("partial_participation_50", PartialParticipation(
            0.5, sampler="vectorized")),
        ("straggler_cohort_25", StragglerSampling(
            0.25, sampler="vectorized")),
    ]
    het = {}
    # the policies carry their own sampler; pass only the pipeline knobs
    # (run_federated rejects a non-default sampler= next to sampling=)
    pipe_kw = {k: piped[k] for k in ("prefetch", "max_block")}
    for name, policy in cohorts:
        def run_policy(policy=policy):
            out = reptile_train(LOSS, params, dist, rounds=rounds,
                                alpha=1.0, beta=0.02, support=SUPPORT,
                                epochs=8, clients_per_round=8, seed=0,
                                sampling=policy, **pipe_kw)
            jax.block_until_ready(jax.tree.leaves(out["params"])[0])
            return out
        out = run_policy()            # doubles as warmup + accounting
        rps = _rounds_per_sec(run_policy, rounds, warm=False)
        het[name] = {
            "rounds_per_sec": round(rps, 2),
            "comm_bytes": out["comm_bytes"],
            "per_client_bytes_min": min(out["per_client_bytes"]),
            "per_client_bytes_max": max(out["per_client_bytes"]),
        }
        rows.append((f"engine/heterogeneity_{name}", 1e6 / rps,
                     f"rounds_per_sec={rps:.1f} "
                     f"comm_bytes={out['comm_bytes']}"))
    full_rps = het["full_participation"]["rounds_per_sec"]
    for name in ("partial_participation_50", "straggler_cohort_25"):
        het[name]["vs_full_participation"] = round(
            het[name]["rounds_per_sec"] / full_rps, 2)
        het[name]["bytes_vs_full"] = round(
            het[name]["comm_bytes"]
            / het["full_participation"]["comm_bytes"], 3)
    results["heterogeneity"] = het
    budget.check("heterogeneity")

    # -- pool / async: persistent identities over a 32-client pool ------
    # Floor: pooled uniform seating >= 0.9x the legacy anonymous-cohort
    # path at the SAME host sampling style (per-task "reference" draws —
    # the pool samples each check-in from that client's private stream).
    POOL_N = 32
    fedbuff = BufferedAggregation(16)
    pool_cases = [
        ("legacy_uniform", dict(sampling=UniformSampling("reference")),
         None),
        ("pooled_uniform", dict(), None),
        ("pooled_diurnal", dict(sampling=DiurnalAvailability(period=24)),
         None),
        ("pooled_fedbuff_k16", dict(buffered=fedbuff), fedbuff),
    ]
    pool_sec = {}
    for name, case_kw, buffered in pool_cases:
        pooled_case = name != "legacy_uniform"

        def run_case(case_kw=case_kw, pooled_case=pooled_case):
            kw = dict(case_kw)
            if pooled_case:
                kw["pool"] = ClientPool(dist, POOL_N, seed=0)
            out = reptile_train(LOSS, params, dist, rounds=rounds,
                                alpha=1.0, beta=0.02, support=SUPPORT,
                                epochs=8, clients_per_round=8, seed=0,
                                **pipe_kw, **kw)
            jax.block_until_ready(jax.tree.leaves(out["params"])[0])
            return out
        out = run_case()              # doubles as warmup + pool state
        rps = _rounds_per_sec(run_case, rounds, warm=False)
        row = {"rounds_per_sec": round(rps, 2),
               "comm_bytes": out["comm_bytes"]}
        if pooled_case:
            ps = out["pool_state"]
            row["checkins_min"] = int(ps["checkins"].min())
            row["checkins_max"] = int(ps["checkins"].max())
            row["staleness_max"] = int(ps["staleness"].max())
            if buffered is not None:
                row["flushes"] = ps["flushes"]
            masked = name == "pooled_diurnal"    # availability process
            runner = _block_runner(ReptileStrategy(LOSS, epochs=8), 0.02,
                                   CommChannel(), scheduled=True,
                                   pooled=True, buffered=buffered,
                                   masked=masked)
            row["trace_count"] = runner.trace_count   # 1 = retrace-free
        pool_sec[name] = row
        rows.append((f"engine/pool_{name}", 1e6 / rps,
                     f"rounds_per_sec={rps:.1f} "
                     f"comm_bytes={out['comm_bytes']}"))
    for name in ("pooled_uniform", "pooled_diurnal", "pooled_fedbuff_k16"):
        pool_sec[name]["vs_legacy_uniform"] = round(
            pool_sec[name]["rounds_per_sec"]
            / pool_sec["legacy_uniform"]["rounds_per_sec"], 2)
    results["pool_async"] = pool_sec
    budget.check("pool_async")

    # -- pool_scale: the fleet-size sweep (PR 8) ------------------------
    # Fixed cohort (256), fleet size N in {256, 1e4, 1e6}: with the
    # counter-derived identity and host-resident slabs, per-round host
    # work is O(cohort), so rounds/sec must be flat in N (floor: 1e6
    # within 1.2x of 256). TinyReptile keeps the device step light so
    # host-side scaling regressions cannot hide behind client compute.
    from repro.core import run_federated as _rf
    from repro.metering.memory import MemoryMeter
    scale_rounds = 8 if smoke else min(rounds, 24)
    scale_sec = {"cohort": 256, "rounds": scale_rounds}
    scale_rps = {}
    for n in (256, 10_000, 1_000_000):
        pool = ClientPool(dist, n, seed=0, sampler="vectorized",
                          residency="host")
        meter = MemoryMeter()

        def run_scale(pool=pool):
            out = _rf(params, dist,
                      TinyReptileStrategy(LOSS, use_pallas=None),
                      rounds=scale_rounds, clients_per_round=256,
                      alpha=1.0, beta=0.02, support=8, seed=0,
                      **pipe_kw, pool=pool)
            jax.block_until_ready(jax.tree.leaves(out["params"])[0])

        rps = _rounds_per_sec(run_scale, scale_rounds,
                              reps=2 if smoke else 3)
        mem = meter.report()
        snap = pool.host_state()
        scale_rps[n] = rps
        scale_sec[f"n_{n}"] = {
            "rounds_per_sec": round(rps, 2),
            # the analytic O(N) residual: per-client int32 identity
            "identity_int32_mb": round(16 * n / 2 ** 20, 2),
            # measured growth since this size's baseline (upper bound:
            # ru_maxrss is a process-lifetime high-water mark)
            "host_current_growth_mb": round(
                mem["host_current_growth_bytes"] / 2 ** 20, 1),
            "host_peak_growth_mb": round(
                mem["host_peak_growth_bytes"] / 2 ** 20, 1),
            "snapshot_entries": len(snap["checkins"]),
        }
        rows.append((f"engine/pool_scale_n{n}", 1e6 / rps,
                     f"rounds_per_sec={rps:.1f}"))
    scale_sec["n256_over_n1000000"] = round(
        scale_rps[256] / scale_rps[1_000_000], 3)
    results["pool_scale"] = scale_sec
    budget.check("pool_scale")

    # -- checkpoint overhead: async round-state snapshots (PR 7) --------
    # The preemption-safety tentpole must be ~free on the round engine's
    # hot path: the consumer dispatches one fused device-side copy of
    # the carry and hands it to the background writer thread (D2H
    # transfer + in-memory npz + atomic writes off the critical path).
    # Judged on the WIDE fleet-simulation workload (the mesh_scaling
    # MLP, support 128) — the long-run regime checkpointing exists for,
    # where 10 rounds of compute amortize the ~2ms fixed per-snapshot
    # cost (also recorded, as snapshot_cost_ms, so the fixed cost stays
    # visible instead of hidden behind the ratio). Floor (see
    # docs/BENCHMARKS.md): < 5% rounds/sec cost at --ckpt-every 10.
    # Paired interleaved timing: base/ckpt alternate within one loop so
    # host-load drift hits both sides equally.
    import tempfile as _tempfile
    from repro.core import run_federated as _run_federated
    from repro.core.strategies import ReptileStrategy as _Reptile
    ck_params = init_paper_model(MESH_MLP, jax.random.PRNGKey(0))

    def ckpt_case(ckpt_dir):
        kw = {} if ckpt_dir is None else dict(ckpt_dir=ckpt_dir,
                                              ckpt_every=10)
        out = _run_federated(
            ck_params, dist, _Reptile(MESH_LOSS, epochs=8, use_pallas=None),
            rounds=rounds, alpha=1.0, beta=0.02, support=MESH_SUPPORT,
            clients_per_round=8, seed=0, prefetch=2, max_block=16,
            sampling=UniformSampling("vectorized"), **kw)
        jax.block_until_ready(jax.tree.leaves(out["params"])[0])

    with _tempfile.TemporaryDirectory() as ckpt_d:
        ckpt_case(None)
        ckpt_case(ckpt_d)                 # warm both traces
        t_base, t_ck = float("inf"), float("inf")
        for _ in range(2 if smoke else 5):
            t0 = time.perf_counter()
            ckpt_case(None)
            t_base = min(t_base, time.perf_counter() - t0)
            t0 = time.perf_counter()
            ckpt_case(ckpt_d)
            t_ck = min(t_ck, time.perf_counter() - t0)
    base_rps, ck_rps = rounds / t_base, rounds / t_ck
    n_snaps = max(1, rounds // 10)
    overhead_pct = (t_ck / t_base - 1.0) * 100.0
    results["ckpt_overhead"] = {
        "workload": f"{MESH_MLP.name} c8 support{MESH_SUPPORT}",
        "no_ckpt_rounds_per_sec": round(base_rps, 2),
        "ckpt_every_10_rounds_per_sec": round(ck_rps, 2),
        "overhead_pct": round(overhead_pct, 2),
        "snapshot_cost_ms": round((t_ck - t_base) / n_snaps * 1000, 3),
    }
    rows.append(("engine/ckpt_every_10_pipelined", 1e6 / ck_rps,
                 f"rounds_per_sec={ck_rps:.1f} "
                 f"overhead_pct={overhead_pct:.2f}"))
    budget.check("ckpt_overhead")

    # -- mesh scaling: shard the client axis over (forced) host devices --
    # Multi-device parents (the multi-device CI job, a real accelerator
    # host) sweep in-process; a single-device full run spawns the forced
    # 8-device subprocess; a single-device SMOKE run skips the section
    # (tier-1 time budget — the dedicated multi-device CI job covers it).
    if len(jax.devices()) > 1:
        mesh_rows, results["mesh_scaling"] = mesh_scaling(rounds, smoke)
        rows.extend(mesh_rows)
    elif not smoke:
        results["mesh_scaling"] = _mesh_scaling_subprocess(rounds)
    budget.check("mesh_scaling")

    # -- lm_mesh: the 2-D (clients x model) mesh on a transformer (PR 10) --
    # >= 4 devices sweep in-process (the mesh2d CI job forces 4 on CPU);
    # a single-device full run spawns the forced-device subprocess; a
    # single-device smoke skips (tier-1 time budget — the mesh2d job
    # runs --lm-mesh-only --smoke, which arms the 0.6x bytes floor).
    if len(jax.devices()) >= 4:
        lm_rows, results["lm_mesh"] = lm_mesh_bench(rounds, smoke)
        rows.extend(lm_rows)
    elif not smoke:
        results["lm_mesh"] = _lm_mesh_subprocess(rounds)
    budget.check("lm_mesh")

    # -- serving: the continuous-batching adaptation server (PR 9) ------
    serve_rows, results["serving"] = serving_bench(smoke)
    rows.extend(serve_rows)
    budget.check("serving")

    payload = {"bench": "engine", "status": "OK", "backend":
               jax.default_backend(), "rounds": rounds, "support": SUPPORT,
               "smoke": smoke, "section_seconds": budget.seconds,
               "results": results}
    return rows, payload


def run():
    """benchmarks.run contract: full bench, write BENCH_engine.json,
    return the CSV rows."""
    rows, payload = bench()
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--json", action="store_true",
                    help="print the result payload as JSON on stdout")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny pipeline-on/off check: skips the legacy "
                         "Python-loop baselines and does not overwrite "
                         "BENCH_engine.json")
    ap.add_argument("--mesh-only", action="store_true",
                    help="run ONLY the mesh_scaling sweep and print its "
                         "section as JSON (the multi-device subprocess "
                         "bench() spawns; needs forced host devices)")
    ap.add_argument("--serving-only", action="store_true",
                    help="run ONLY the serving section and print it as "
                         "JSON (the serving CI job's fast path; --smoke "
                         "arms the >= 500 req/s fp32 floor)")
    ap.add_argument("--lm-mesh-only", action="store_true",
                    help="run ONLY the lm_mesh section (2-D clients x "
                         "model mesh on the reduced transformer) and "
                         "print it as JSON; needs >= 4 devices — the "
                         "mesh2d CI job's fast path, where --smoke arms "
                         "the 0.6x per-device parameter bytes floor")
    args = ap.parse_args()

    if args.mesh_only:
        _, section = mesh_scaling(rounds=args.rounds)
        print(json.dumps(section, indent=2))
        return
    if args.lm_mesh_only:
        _, section = lm_mesh_bench(rounds=args.rounds, smoke=args.smoke)
        print(json.dumps(section, indent=2))
        return
    if args.serving_only:
        _, section = serving_bench(smoke=args.smoke)
        print(json.dumps(section, indent=2))
        return

    rows, payload = bench(rounds=args.rounds, smoke=args.smoke)
    # only the canonical config may update the tracked record — a quick
    # --rounds 8 iteration must not clobber the 120-round numbers the
    # acceptance thresholds are judged against
    if not args.smoke and args.rounds == ROUNDS:
        with open(OUT_PATH, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    if args.json:
        print(json.dumps(payload, indent=2))
    else:
        from benchmarks.common import emit
        emit(rows)


if __name__ == "__main__":
    main()
