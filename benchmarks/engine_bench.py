"""Engine speedup tracking: rounds/sec for (1) the pre-refactor per-client
Python loops, (2) the PR-1 synchronous engine (prefetch=0, reference
per-task sampling), and (3) the pipelined engine (vectorized block
sampling + double-buffered background prefetch), on the paper's sine
task. Acceptance floors: engine >= 3x the Python loops (PR 1) and
pipelined >= 1.5x the synchronous engine (PR 2) for batched-client
Reptile (clients_per_round=8) on CPU.

A "heterogeneity" section (PR 3) benchmarks the ClientSchedule layer on
the same batched-Reptile cohort: full participation vs 50% partial
participation vs a straggler cohort — rounds/sec plus the transport
bill (total and per-client min/max), showing that scenario plugins ride
the fixed-shape scan at full speed while partial participation halves
the bytes.

A "pool_async" section (PR 4) benchmarks persistent client identities:
the same cohort seated from a 32-client ClientPool — uniform seating
(floor: >= 0.9x the anonymous-cohort legacy path), diurnal-availability
check-ins, and FedBuff buffered aggregation (flush every 16 arrivals)
— with the block runner's trace counters recorded to pin the
one-jit-trace-per-config contract.

Writes BENCH_engine.json next to the repo root (same spirit as the
results/dryrun JSON cells consumed by benchmarks/report.py) so the
speedup is tracked across future PRs.

  PYTHONPATH=src python -m benchmarks.engine_bench            # full run
  PYTHONPATH=src python -m benchmarks.engine_bench --json     # JSON out
  PYTHONPATH=src python -m benchmarks.engine_bench --rounds 8 --smoke
                       # tier-1-budget smoke: pipeline on/off +
                       # heterogeneity only (no legacy Python loops)
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import SINE_MLP
from repro.core import (BufferedAggregation, ClientPool, CommChannel,
                        DiurnalAvailability, PartialParticipation,
                        StragglerSampling, UniformSampling, reptile_train,
                        tinyreptile_train)
from repro.core.engine import _block_runner
from repro.core.meta import finetune_batch, finetune_online, tree_lerp
from repro.core.strategies import ReptileStrategy
from repro.data import SineTasks
from repro.models.paper_nets import init_paper_model, paper_model_loss

LOSS = functools.partial(paper_model_loss, SINE_MLP)
ROUNDS = 120
SUPPORT = 32
OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_engine.json")


# -- pre-refactor loops (one host->device dispatch per client per round) ----

def _python_loop_tinyreptile(params, dist, rounds):
    rng = np.random.default_rng(0)
    phi = params
    for rnd in range(rounds):
        alpha_t = 1.0 * (1 - rnd / rounds)
        task = dist.sample_task(rng)
        xs, ys = zip(*task.support_stream(rng, SUPPORT))
        phi_hat, _ = finetune_online(LOSS, phi, jnp.stack(xs), jnp.stack(ys),
                                     jnp.float32(0.02))
        phi = tree_lerp(phi, phi_hat, alpha_t)
    return jax.block_until_ready(jax.tree.leaves(phi)[0])


def _python_loop_reptile(params, dist, rounds, clients, epochs=8):
    rng = np.random.default_rng(0)
    phi = params
    for rnd in range(rounds):
        alpha_t = 1.0 * (1 - rnd / rounds)
        deltas = None
        for _ in range(clients):
            task = dist.sample_task(rng)
            sup = task.support_batch(rng, SUPPORT)
            phi_hat, _ = finetune_batch(LOSS, phi, sup, epochs,
                                        jnp.float32(0.02))
            d = jax.tree.map(lambda q, p: q - p, phi_hat, phi)
            deltas = d if deltas is None else jax.tree.map(
                lambda a, b: a + b, deltas, d)
        phi = jax.tree.map(lambda p, d: p + alpha_t * d / clients,
                           phi, deltas)
    return jax.block_until_ready(jax.tree.leaves(phi)[0])


def _rounds_per_sec(fn, rounds, reps: int = 3, warm: bool = True):
    """Warmup once (compile + caches; skipped when the caller already
    ran ``fn`` for its output), then best of ``reps`` timed runs (the
    timeit convention: min elapsed suppresses host load jitter — one
    120-round pass is a fraction of a second, far too short for a
    single sample to be a stable ratio on a shared machine)."""
    if warm:
        fn()                              # warmup: compile + caches
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return rounds / best


def bench(rounds: int = ROUNDS, smoke: bool = False):
    """Returns (rows, payload). ``smoke`` skips the slow legacy Python
    loops and only compares pipeline on vs off (tier-1 time budget)."""
    params = init_paper_model(SINE_MLP, jax.random.PRNGKey(0))
    dist = SineTasks()
    results = {}

    # engine kwargs: PR-1 synchronous baseline vs the pipelined fast path.
    # The pipelined config caps blocks so the run splits into >= 4 blocks
    # and the prefetch thread actually overlaps host sampling of block N+1
    # with device compute on block N (one monolithic block would
    # degenerate to inline staging with nothing to overlap) — also at
    # smoke round counts.
    sync = dict(prefetch=0, sampler="reference")
    piped = dict(prefetch=2, sampler="vectorized",
                 max_block=min(16, max(1, rounds // 4)))

    cases = [
        ("tinyreptile",
         lambda: _python_loop_tinyreptile(params, dist, rounds),
         lambda kw: tinyreptile_train(LOSS, params, dist, rounds=rounds,
                                      alpha=1.0, beta=0.02, support=SUPPORT,
                                      seed=0, **kw)),
        ("reptile_batched_c8",
         lambda: _python_loop_reptile(params, dist, rounds, clients=8),
         lambda kw: reptile_train(LOSS, params, dist, rounds=rounds,
                                  alpha=1.0, beta=0.02, support=SUPPORT,
                                  epochs=8, clients_per_round=8, seed=0,
                                  **kw)),
    ]
    def synced(engine_fn, kw):
        # the engine returns as soon as the last block is dispatched;
        # block on the result so device compute is inside the timing
        out = engine_fn(kw)
        return jax.block_until_ready(jax.tree.leaves(out["params"])[0])

    rows = []
    for name, legacy_fn, engine_fn in cases:
        sync_rps = _rounds_per_sec(lambda: synced(engine_fn, sync), rounds)
        piped_rps = _rounds_per_sec(lambda: synced(engine_fn, piped), rounds)
        pipeline_speedup = piped_rps / sync_rps
        res = {"engine_sync_rounds_per_sec": round(sync_rps, 2),
               "engine_pipelined_rounds_per_sec": round(piped_rps, 2),
               "pipeline_speedup": round(pipeline_speedup, 2)}
        if not smoke:
            legacy_rps = _rounds_per_sec(legacy_fn, rounds)
            res["python_loop_rounds_per_sec"] = round(legacy_rps, 2)
            res["engine_speedup"] = round(sync_rps / legacy_rps, 2)
            res["pipelined_vs_python_loop"] = round(piped_rps / legacy_rps, 2)
            rows.append((f"engine/{name}_python_loop", 1e6 / legacy_rps,
                         f"rounds_per_sec={legacy_rps:.1f}"))
        results[name] = res
        rows.append((f"engine/{name}_engine_sync", 1e6 / sync_rps,
                     f"rounds_per_sec={sync_rps:.1f}"))
        rows.append((f"engine/{name}_engine_pipelined", 1e6 / piped_rps,
                     f"rounds_per_sec={piped_rps:.1f} "
                     f"pipeline_speedup={pipeline_speedup:.2f}x"))

    # -- heterogeneity: the ClientSchedule layer on the batched cohort --
    cohorts = [
        ("full_participation", UniformSampling("vectorized")),
        ("partial_participation_50", PartialParticipation(
            0.5, sampler="vectorized")),
        ("straggler_cohort_25", StragglerSampling(
            0.25, sampler="vectorized")),
    ]
    het = {}
    # the policies carry their own sampler; pass only the pipeline knobs
    # (run_federated rejects a non-default sampler= next to sampling=)
    pipe_kw = {k: piped[k] for k in ("prefetch", "max_block")}
    for name, policy in cohorts:
        def run_policy(policy=policy):
            out = reptile_train(LOSS, params, dist, rounds=rounds,
                                alpha=1.0, beta=0.02, support=SUPPORT,
                                epochs=8, clients_per_round=8, seed=0,
                                sampling=policy, **pipe_kw)
            jax.block_until_ready(jax.tree.leaves(out["params"])[0])
            return out
        out = run_policy()            # doubles as warmup + accounting
        rps = _rounds_per_sec(run_policy, rounds, warm=False)
        het[name] = {
            "rounds_per_sec": round(rps, 2),
            "comm_bytes": out["comm_bytes"],
            "per_client_bytes_min": min(out["per_client_bytes"]),
            "per_client_bytes_max": max(out["per_client_bytes"]),
        }
        rows.append((f"engine/heterogeneity_{name}", 1e6 / rps,
                     f"rounds_per_sec={rps:.1f} "
                     f"comm_bytes={out['comm_bytes']}"))
    full_rps = het["full_participation"]["rounds_per_sec"]
    for name in ("partial_participation_50", "straggler_cohort_25"):
        het[name]["vs_full_participation"] = round(
            het[name]["rounds_per_sec"] / full_rps, 2)
        het[name]["bytes_vs_full"] = round(
            het[name]["comm_bytes"]
            / het["full_participation"]["comm_bytes"], 3)
    results["heterogeneity"] = het

    # -- pool / async: persistent identities over a 32-client pool ------
    # Floor: pooled uniform seating >= 0.9x the legacy anonymous-cohort
    # path at the SAME host sampling style (per-task "reference" draws —
    # the pool samples each check-in from that client's private stream).
    POOL_N = 32
    fedbuff = BufferedAggregation(16)
    pool_cases = [
        ("legacy_uniform", dict(sampling=UniformSampling("reference")),
         None),
        ("pooled_uniform", dict(), None),
        ("pooled_diurnal", dict(sampling=DiurnalAvailability(period=24)),
         None),
        ("pooled_fedbuff_k16", dict(buffered=fedbuff), fedbuff),
    ]
    pool_sec = {}
    for name, case_kw, buffered in pool_cases:
        pooled_case = name != "legacy_uniform"

        def run_case(case_kw=case_kw, pooled_case=pooled_case):
            kw = dict(case_kw)
            if pooled_case:
                kw["pool"] = ClientPool(dist, POOL_N, seed=0)
            out = reptile_train(LOSS, params, dist, rounds=rounds,
                                alpha=1.0, beta=0.02, support=SUPPORT,
                                epochs=8, clients_per_round=8, seed=0,
                                **pipe_kw, **kw)
            jax.block_until_ready(jax.tree.leaves(out["params"])[0])
            return out
        out = run_case()              # doubles as warmup + pool state
        rps = _rounds_per_sec(run_case, rounds, warm=False)
        row = {"rounds_per_sec": round(rps, 2),
               "comm_bytes": out["comm_bytes"]}
        if pooled_case:
            ps = out["pool_state"]
            row["checkins_min"] = int(ps["checkins"].min())
            row["checkins_max"] = int(ps["checkins"].max())
            row["staleness_max"] = int(ps["staleness"].max())
            if buffered is not None:
                row["flushes"] = ps["flushes"]
            runner = _block_runner(ReptileStrategy(LOSS, epochs=8), 0.02,
                                   CommChannel(), scheduled=True,
                                   pooled=True, buffered=buffered)
            row["trace_count"] = runner.trace_count   # 1 = retrace-free
        pool_sec[name] = row
        rows.append((f"engine/pool_{name}", 1e6 / rps,
                     f"rounds_per_sec={rps:.1f} "
                     f"comm_bytes={out['comm_bytes']}"))
    for name in ("pooled_uniform", "pooled_diurnal", "pooled_fedbuff_k16"):
        pool_sec[name]["vs_legacy_uniform"] = round(
            pool_sec[name]["rounds_per_sec"]
            / pool_sec["legacy_uniform"]["rounds_per_sec"], 2)
    results["pool_async"] = pool_sec

    payload = {"bench": "engine", "status": "OK", "backend":
               jax.default_backend(), "rounds": rounds, "support": SUPPORT,
               "smoke": smoke, "results": results}
    return rows, payload


def run():
    """benchmarks.run contract: full bench, write BENCH_engine.json,
    return the CSV rows."""
    rows, payload = bench()
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--json", action="store_true",
                    help="print the result payload as JSON on stdout")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny pipeline-on/off check: skips the legacy "
                         "Python-loop baselines and does not overwrite "
                         "BENCH_engine.json")
    args = ap.parse_args()

    rows, payload = bench(rounds=args.rounds, smoke=args.smoke)
    # only the canonical config may update the tracked record — a quick
    # --rounds 8 iteration must not clobber the 120-round numbers the
    # acceptance thresholds are judged against
    if not args.smoke and args.rounds == ROUNDS:
        with open(OUT_PATH, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
    if args.json:
        print(json.dumps(payload, indent=2))
    else:
        from benchmarks.common import emit
        emit(rows)


if __name__ == "__main__":
    main()
