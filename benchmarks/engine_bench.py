"""Engine speedup tracking: rounds/sec for the pre-refactor per-client
Python loops vs the scanned/vmapped round engine, on the paper's sine
task. Acceptance floor (PR 1): >= 3x for batched-client Reptile
(clients_per_round=8) on CPU.

Writes BENCH_engine.json next to the repo root (same spirit as the
results/dryrun JSON cells consumed by benchmarks/report.py) so the
speedup is tracked across future PRs.

  PYTHONPATH=src python -m benchmarks.engine_bench
"""
from __future__ import annotations

import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import SINE_MLP
from repro.core import reptile_train, tinyreptile_train
from repro.core.meta import finetune_batch, finetune_online, tree_lerp
from repro.data import SineTasks
from repro.models.paper_nets import init_paper_model, paper_model_loss

LOSS = functools.partial(paper_model_loss, SINE_MLP)
ROUNDS = 120
SUPPORT = 32
OUT_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_engine.json")


# -- pre-refactor loops (one host->device dispatch per client per round) ----

def _python_loop_tinyreptile(params, dist, rounds):
    rng = np.random.default_rng(0)
    phi = params
    for rnd in range(rounds):
        alpha_t = 1.0 * (1 - rnd / rounds)
        task = dist.sample_task(rng)
        xs, ys = zip(*task.support_stream(rng, SUPPORT))
        phi_hat, _ = finetune_online(LOSS, phi, jnp.stack(xs), jnp.stack(ys),
                                     jnp.float32(0.02))
        phi = tree_lerp(phi, phi_hat, alpha_t)
    return jax.block_until_ready(jax.tree.leaves(phi)[0])


def _python_loop_reptile(params, dist, rounds, clients, epochs=8):
    rng = np.random.default_rng(0)
    phi = params
    for rnd in range(rounds):
        alpha_t = 1.0 * (1 - rnd / rounds)
        deltas = None
        for _ in range(clients):
            task = dist.sample_task(rng)
            sup = task.support_batch(rng, SUPPORT)
            phi_hat, _ = finetune_batch(LOSS, phi, sup, epochs,
                                        jnp.float32(0.02))
            d = jax.tree.map(lambda q, p: q - p, phi_hat, phi)
            deltas = d if deltas is None else jax.tree.map(
                lambda a, b: a + b, deltas, d)
        phi = jax.tree.map(lambda p, d: p + alpha_t * d / clients,
                           phi, deltas)
    return jax.block_until_ready(jax.tree.leaves(phi)[0])


def _rounds_per_sec(fn, rounds):
    fn()                                  # warmup: compile + caches
    t0 = time.perf_counter()
    fn()
    return rounds / (time.perf_counter() - t0)


def run():
    params = init_paper_model(SINE_MLP, jax.random.PRNGKey(0))
    dist = SineTasks()
    results = {}

    cases = [
        ("tinyreptile",
         lambda: _python_loop_tinyreptile(params, dist, ROUNDS),
         lambda: tinyreptile_train(LOSS, params, dist, rounds=ROUNDS,
                                   alpha=1.0, beta=0.02, support=SUPPORT,
                                   seed=0)),
        ("reptile_batched_c8",
         lambda: _python_loop_reptile(params, dist, ROUNDS, clients=8),
         lambda: reptile_train(LOSS, params, dist, rounds=ROUNDS, alpha=1.0,
                               beta=0.02, support=SUPPORT, epochs=8,
                               clients_per_round=8, seed=0)),
    ]
    rows = []
    for name, legacy_fn, engine_fn in cases:
        legacy_rps = _rounds_per_sec(legacy_fn, ROUNDS)
        engine_rps = _rounds_per_sec(engine_fn, ROUNDS)
        speedup = engine_rps / legacy_rps
        results[name] = {"python_loop_rounds_per_sec": round(legacy_rps, 2),
                         "engine_rounds_per_sec": round(engine_rps, 2),
                         "speedup": round(speedup, 2)}
        rows.append((f"engine/{name}_python_loop", 1e6 / legacy_rps,
                     f"rounds_per_sec={legacy_rps:.1f}"))
        rows.append((f"engine/{name}_engine", 1e6 / engine_rps,
                     f"rounds_per_sec={engine_rps:.1f} "
                     f"speedup={speedup:.2f}x"))

    payload = {"bench": "engine", "status": "OK", "backend":
               jax.default_backend(), "rounds": ROUNDS, "support": SUPPORT,
               "results": results}
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
