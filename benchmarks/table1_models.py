"""Paper Table I: the three MLPerf-Tiny-class models. derived = parameter
count (paper: 1,153 / 19,812 / 113,733) and fp32 size."""
import jax

from repro.configs.paper_models import PAPER_MODELS
from repro.models.paper_nets import init_paper_model, param_count


def run():
    rows = []
    for name, cfg in PAPER_MODELS.items():
        params = init_paper_model(cfg, jax.random.PRNGKey(0))
        n = param_count(params)
        rows.append((f"table1/{name}", 0.0,
                     f"params={n} size_kb={n * 4 / 1024:.1f}"))
    return rows
