"""Shared benchmark plumbing. Each bench module exposes run() -> rows of
(name, us_per_call, derived) where `derived` is the paper-facing number
(a loss, an accuracy, a ratio ...) as a string."""
from __future__ import annotations

import time
from typing import Callable


def timed(fn: Callable, *args, repeats: int = 3, warmup: int = 1):
    """Returns (result, us_per_call)."""
    result = None
    for _ in range(warmup):
        result = fn(*args)
    t0 = time.perf_counter()
    for _ in range(repeats):
        result = fn(*args)
    dt = (time.perf_counter() - t0) / repeats
    return result, dt * 1e6


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows
