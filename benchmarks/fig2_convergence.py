"""Paper Fig. 2: training convergence of FedSGD, FedAVG, Reptile
(batched & serial), and TinyReptile on the Sine-wave example.

All five run on the shared federated round engine (repro.core.engine):
one vmapped/scanned loop, so the per-round us here measures the engine,
not five hand-rolled Python loops.
derived = query MSE after adaptation at equal client-visit budget."""
import functools

import jax
import numpy as np

from benchmarks.common import timed
from repro.configs.paper_models import SINE_MLP
from repro.core import (fedavg_train, reptile_train, tinyreptile_train)
from repro.core.fedavg import fedsgd_train
from repro.data import SineTasks
from repro.models.paper_nets import init_paper_model, paper_model_loss

LOSS = functools.partial(paper_model_loss, SINE_MLP)
EVAL = dict(num_tasks=10, support=8, k_steps=8, lr=0.02, query=64)
VISITS = 300  # client visits for every algorithm (fair budget)


def run():
    params = init_paper_model(SINE_MLP, jax.random.PRNGKey(0))
    dist = SineTasks()
    rows = []

    def final(out):
        s = f"mse={out['history'][-1]['query_loss']:.3f}"
        if "comm_bytes" in out:
            s += f" comm_mb={out['comm_bytes']/1e6:.1f}"
        return s

    out, us = timed(lambda: tinyreptile_train(
        LOSS, params, dist, rounds=VISITS, alpha=1.0, beta=0.02, support=32,
        eval_every=VISITS, eval_kwargs=EVAL, seed=2), repeats=1, warmup=0)
    rows.append(("fig2/tinyreptile", us / VISITS, final(out)))

    out, us = timed(lambda: reptile_train(
        LOSS, params, dist, rounds=VISITS, alpha=1.0, beta=0.02, support=32,
        epochs=8, clients_per_round=1, eval_every=VISITS, eval_kwargs=EVAL,
        seed=2), repeats=1, warmup=0)
    rows.append(("fig2/reptile_serial", us / VISITS, final(out)))

    out, us = timed(lambda: reptile_train(
        LOSS, params, dist, rounds=VISITS // 5, alpha=1.0, beta=0.02,
        support=32, epochs=8, clients_per_round=5, eval_every=VISITS // 5,
        eval_kwargs=EVAL, seed=2), repeats=1, warmup=0)
    rows.append(("fig2/reptile_batched", us / (VISITS // 5), final(out)))

    out, us = timed(lambda: fedavg_train(
        LOSS, params, dist, rounds=VISITS // 5, beta=0.02, support=32,
        epochs=8, clients_per_round=5, eval_every=VISITS // 5,
        eval_kwargs=EVAL, seed=2), repeats=1, warmup=0)
    rows.append(("fig2/fedavg", us / (VISITS // 5),
                 final(out) + " (fails: no adaptation objective)"))

    out, us = timed(lambda: fedsgd_train(
        LOSS, params, dist, rounds=VISITS // 5, beta=0.02, support=32,
        clients_per_round=5, eval_every=VISITS // 5, eval_kwargs=EVAL,
        seed=2), repeats=1, warmup=0)
    rows.append(("fig2/fedsgd", us / (VISITS // 5),
                 final(out) + " (fails)"))
    return rows
