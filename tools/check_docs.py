#!/usr/bin/env python
"""Execute every ```python code block in docs/*.md so the examples
cannot rot (the CI docs job; see .github/workflows/ci.yml).

Blocks within one file run top to bottom in ONE shared namespace — a
file's first block may define setup (imports, params) that later blocks
reuse, exactly as a reader executing the page would. Files are isolated
from each other. Fences tagged anything other than exactly ``python``
(```bash, ```text, ```python notest, ...) are skipped.

  PYTHONPATH=src python tools/check_docs.py [docs/...md ...]
"""
from __future__ import annotations

import glob
import re
import sys
import time
import types

FENCE = re.compile(r"^```python[ \t]*\n(.*?)^```[ \t]*$",
                   re.MULTILINE | re.DOTALL)


def blocks_of(text: str):
    return [m.group(1) for m in FENCE.finditer(text)]


def check_file(path: str) -> int:
    with open(path) as f:
        blocks = blocks_of(f.read())
    if not blocks:
        print(f"  {path}: no python blocks")
        return 0
    # a REAL registered module, not a bare dict: dataclasses (among
    # others) resolves annotations via sys.modules[cls.__module__]
    mod = types.ModuleType("docs_" + re.sub(r"\W", "_", path))
    sys.modules[mod.__name__] = mod
    namespace = mod.__dict__
    for i, src in enumerate(blocks, 1):
        t0 = time.time()
        try:
            exec(compile(src, f"{path}#block{i}", "exec"), namespace)
        except Exception as exc:
            print(f"  {path} block {i}/{len(blocks)}: FAILED — "
                  f"{type(exc).__name__}: {exc}")
            for ln, line in enumerate(src.splitlines(), 1):
                print(f"    {ln:3d} | {line}")
            return 1
        print(f"  {path} block {i}/{len(blocks)}: ok "
              f"({time.time() - t0:.1f}s)")
    return 0


def main(argv):
    paths = argv or sorted(glob.glob("docs/*.md"))
    if not paths:
        print("no docs/*.md files found (run from the repo root)")
        return 1
    failures = 0
    for path in paths:
        failures += check_file(path)
    if failures:
        print(f"{failures} file(s) with failing blocks")
        return 1
    print("all doc code blocks executed cleanly")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
