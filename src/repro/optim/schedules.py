"""Learning-rate schedules.

``wsd`` is the Warmup-Stable-Decay schedule used to train MiniCPM-2B
[arXiv:2404.06395]; ``linear_anneal`` implements the annealing suggested
for TinyReptile's server rate alpha (paper Appendix A / Reptile paper).
"""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_anneal(lr, total_steps, floor=0.0):
    def f(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return jnp.asarray(lr * (1 - frac) + floor * frac, jnp.float32)
    return f


def cosine(lr, total_steps, warmup=0, floor_ratio=0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0, 1)
        cos = floor_ratio * lr + (1 - floor_ratio) * lr * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return f


def wsd(lr, total_steps, warmup_frac=0.01, decay_frac=0.1, floor_ratio=0.1):
    """Warmup-Stable-Decay (MiniCPM): linear warmup, long stable plateau,
    fast exponential-ish (linear here) decay tail."""
    warmup = max(int(total_steps * warmup_frac), 1)
    decay_start = int(total_steps * (1 - decay_frac))

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / warmup
        frac = jnp.clip((step - decay_start) / max(total_steps - decay_start, 1),
                        0, 1)
        tail = lr * (1 - (1 - floor_ratio) * frac)
        return jnp.where(step < warmup, warm,
                         jnp.where(step < decay_start, lr, tail))
    return f
