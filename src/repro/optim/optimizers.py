"""Minimal pytree optimizers (SGD / AdamW) in the optax (init, update)
style — built in-repo since the container is offline."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Tuple[Any, Any]]  # (grads, state, params, lr)


def sgd(momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params, lr):
        if momentum == 0.0:
            new_params = jax.tree.map(
                lambda p, g: p - (lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new_params, state
        new_state = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state, grads)
        new_params = jax.tree.map(
            lambda p, m: p - (lr * m).astype(p.dtype), params, new_state)
        return new_params, new_state

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jnp.ndarray


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return AdamState(mu=jax.tree.map(zeros, params),
                         nu=jax.tree.map(zeros, params),
                         count=jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr):
        count = state.count + 1
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)

        def upd(p, m, v):
            step = lr * (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                step = step + lr * weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamState(mu, nu, count)

    return Optimizer(init, update)
