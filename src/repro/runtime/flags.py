"""Runtime mode flags.

PROBE mode (env REPRO_PROBE=1 or probe_scope()): replaces every
jax.lax.scan / blockwise-flash loop with unrolled / single-block
equivalents so XLA's cost_analysis (which counts while-loop bodies ONCE,
not x trip-count) is exact. Probe compiles run at reduced layer / inner
counts and the dry-run extrapolates linearly. Never use probe mode for
real execution — the unrolled quadratic attention materializes S^2
score buffers.
"""
from __future__ import annotations

import contextlib
import os
import threading

_state = threading.local()


def probe_mode() -> bool:
    if getattr(_state, "probe", None) is not None:
        return _state.probe
    return os.environ.get("REPRO_PROBE", "0") == "1"


@contextlib.contextmanager
def probe_scope(on: bool = True):
    prev = getattr(_state, "probe", None)
    _state.probe = on
    try:
        yield
    finally:
        _state.probe = prev


# ---------------------------------------------------------------------------
# performance feature flags (§Perf hillclimbing levers; default = baseline)
# ---------------------------------------------------------------------------
# gqa_flat : compute GQA with K/V repeated to H flat heads so the head dim
#            shards even when num_kv_heads < mesh model size (kills score
#            replication for kv=8 on a 16-way model axis).
# banded   : sliding-window attention gathers only the KV band per Q block
#            (real FLOP cut) instead of masking the full row.
# moe2d    : 2D-shard MoE expert weights (d->data, f->model) stationarily
#            instead of FSDP weight all-gathers — activations all-reduce
#            (tiny at decode) replaces per-step weight movement.
# ringkv   : sliding-window layers keep only a window-sized ring-buffer KV
#            cache (K is RoPE'd at insert, so no position bookkeeping) —
#            cache footprint and attention read traffic / (S/window).
# moelocal : MoE routing/sort/dispatch per data-shard token group instead
#            of over the global token dim (GSPMD replicates the global
#            argsort+gather pipeline on every chip — TB/chip of traffic).
#            Capacity is enforced per shard, as real EP systems do.

# seqpar   : sequence-parallel attention — shard the QUERY dim over the
#            model axis for the attention section (works for any head
#            count, e.g. llama4's H=40 that 16 cannot divide; avoids
#            GSPMD's replicate-then-partition copies of S^2 scores).

# ssd_pallas : route mamba2's chunked SSD scan through the Pallas kernel
#            (repro.kernels.ssd_scan) on the train/prefill path —
#            interpret mode off-TPU, so federated mamba2 inner loops
#            exercise the kernel everywhere (see models.mamba2).

_FEATURES = ("gqa_flat", "banded", "moe2d", "ringkv", "moelocal",
             "seqpar", "ssd_pallas")


def feature(name: str) -> bool:
    assert name in _FEATURES, name
    st = getattr(_state, "features", None)
    if st is not None and name in st:
        return st[name]
    return os.environ.get(f"REPRO_OPT_{name.upper()}", "0") == "1"


@contextlib.contextmanager
def feature_scope(**kw):
    prev = getattr(_state, "features", None)
    merged = dict(prev or {})
    merged.update(kw)
    _state.features = merged
    try:
        yield
    finally:
        _state.features = prev


def set_features_from_env_string(s: str):
    """'gqa_flat,moe2d' -> enable those for this process (dryrun --opt)."""
    on = {x.strip() for x in s.split(",") if x.strip()}
    unknown = on - set(_FEATURES)
    assert not unknown, unknown
    _state.features = {f: (f in on) for f in _FEATURES}
