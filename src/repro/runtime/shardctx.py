"""Ambient sharding context.

Models are mesh-agnostic; step builders install the active mesh here and
layers call ``shard(x, *logical_axes)`` to drop GSPMD constraints. Outside
a mesh (CPU smoke tests) the helpers are no-ops.

Logical axes: "batch" -> all data-parallel mesh axes ("pod","data"),
"model" -> tensor axis, "expert" -> expert-parallel axis (aliases model),
None -> replicated dim.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


def current_mesh():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def mesh_context(mesh):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.mesh = prev


@contextlib.contextmanager
def manual_axes(*axes):
    """Axes handled manually (shard_map) — excluded from constraints."""
    prev = getattr(_state, "manual", ())
    _state.manual = tuple(set(prev) | set(axes))
    try:
        yield
    finally:
        _state.manual = prev


def _manual():
    return getattr(_state, "manual", ())


def resolve_axis(logical, mesh):
    names = tuple(a for a in mesh.axis_names if a not in _manual())
    if logical is None:
        return None
    if logical == "batch":
        ax = tuple(a for a in ("pod", "data") if a in names)
        return ax if ax else None
    if logical in ("model", "expert"):
        return "model" if "model" in names else None
    if logical in ("seq", "fsdp"):  # context-parallel / fsdp dim
        return "data" if "data" in names else None
    raise ValueError(f"unknown logical axis {logical!r}")


def spec(*logical):
    mesh = current_mesh()
    if mesh is None:
        return None
    return P(*(resolve_axis(l, mesh) for l in logical))


def _axis_size(mesh, ax):
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        out = 1
        for a in ax:
            out *= mesh.shape[a]
        return out
    return mesh.shape[ax]


def shard(x, *logical):
    """with_sharding_constraint if a mesh is active, else identity.

    Axes whose size does not evenly divide the corresponding dim are
    dropped (replicated) — avoids uneven-sharding pitfalls for small dims.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    if set(_manual()) >= set(mesh.axis_names):
        return x  # fully-manual shard_map: no GSPMD constraints apply
    resolved = []
    for dim, l in zip(x.shape, logical):
        ax = resolve_axis(l, mesh)
        if ax is not None and dim % _axis_size(mesh, ax) != 0:
            ax = None
        resolved.append(ax)
    s = P(*resolved)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))
