"""Parameter / input sharding rules for the production meshes.

Strategy (baseline; the §Perf loop iterates on it):
- tensor parallelism on the ``model`` axis: FFN hidden dim, attention
  heads (falling back to head_dim, then the contraction dim when head
  counts don't divide), MoE experts (expert parallelism when E >= axis),
  vocab for embed/lm_head;
- FSDP on the ``data`` axis for any leaf whose per-model-shard footprint
  exceeds a threshold (weights are all-gathered layer-by-layer under the
  scan, so the live working set stays one layer);
- batch on (``pod``, ``data``); long-context decode (batch=1) shards the
  KV-cache *sequence* dim instead (context parallelism).

All rules respect divisibility: an axis that does not divide the dim is
dropped (replicated) rather than unevenly sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

FSDP_THRESHOLD_BYTES = 32 * 1024 * 1024


def client_model_mesh(clients: int, model: int, devices=None):
    """Build the federated engine's 2-D ``("clients", "model")`` mesh.

    ``clients`` cohort shards x ``model`` tensor-parallel shards; the
    round engine runs its global block body under GSPMD on this mesh —
    the cohort axis partitions over "clients" and phi's per-leaf
    model-axis shardings (a ModelPartitioner's specs) flow through the
    block scan, so in-loop model collectives stay compiler-scheduled.
    Uses the first ``clients * model`` devices.
    """
    if clients < 1 or model < 1:
        raise ValueError(f"mesh extents must be >= 1, got "
                         f"clients={clients}, model={model}")
    devices = list(jax.devices() if devices is None else devices)
    need = clients * model
    if len(devices) < need:
        raise ValueError(
            f"client_model_mesh needs {clients}x{model}={need} devices, "
            f"have {len(devices)}; on CPU force host devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    grid = np.array(devices[:need]).reshape(clients, model)
    return jax.sharding.Mesh(grid, ("clients", "model"))


@dataclasses.dataclass(frozen=True)
class ModelPartitioner:
    """Per-architecture parameter-partitioning rules for the model axis.

    ``rules(path, shape, mesh) -> PartitionSpec`` maps one param leaf to
    its spec (Levanter-style: shard attention/MLP/expert weight matrices
    on "model", replicate norms/biases). Identity (equality, hash, and
    the checkpoint fingerprint) is the ``name`` alone, so a partitioner
    can be recorded in round-state snapshots and runner-cache keys.
    """
    name: str
    # None -> the shared default rules (param_spec, defined below).
    rules: Callable[[str, Tuple[int, ...], Any], P] = dataclasses.field(
        default=None, compare=False)

    def _rules(self):
        return param_spec if self.rules is None else self.rules

    def spec(self, path, shape: Tuple[int, ...], mesh) -> P:
        """Spec for one leaf; ``path`` is a "a.b.c" string or a raw
        jax key path (as handed to tree_map_with_path callbacks)."""
        if not isinstance(path, str):
            path = _path_str(path)
        return self._rules()(path, shape, mesh)

    def shardings(self, params, mesh):
        """Pytree of NamedSharding for ``params`` under these rules."""
        rules = self._rules()
        def leaf_spec(path, leaf):
            return NamedSharding(
                mesh, rules(_path_str(path), np.shape(leaf), mesh))
        return jax.tree_util.tree_map_with_path(leaf_spec, params)


_PARTITIONERS: Dict[str, ModelPartitioner] = {}


def register_partitioner(name: str, rules=None) -> ModelPartitioner:
    """Register (or fetch, when rules is None and it exists) a
    ``ModelPartitioner``. Registering an existing name with different
    rules raises — identity is the name, so it must stay unambiguous."""
    if rules is None:
        rules = param_spec
    existing = _PARTITIONERS.get(name)
    if existing is not None:
        if existing.rules is not rules:
            raise ValueError(f"partitioner {name!r} already registered "
                             "with different rules")
        return existing
    p = ModelPartitioner(name=name, rules=rules)
    _PARTITIONERS[name] = p
    return p


def partitioner_for(arch: str) -> ModelPartitioner:
    """The registered partitioner for an architecture family name.

    transformer / mamba2 / moe all ride the shared per-leaf
    ``param_spec`` rules (leaf names are the contract, so one rule set
    covers every shipped architecture); custom architectures register
    their own via ``register_partitioner`` (docs/PLUGINS.md §8)."""
    if arch in _PARTITIONERS:
        return _PARTITIONERS[arch]
    raise KeyError(f"no ModelPartitioner registered for {arch!r}; "
                   f"known: {sorted(_PARTITIONERS)} "
                   "(register_partitioner(name, rules) adds one)")


def per_device_param_bytes(params) -> int:
    """Analytic peak parameter bytes on ONE device: the sum over leaves
    of the per-shard footprint under each leaf's committed sharding
    (replicated leaves count full size). Backend-independent — on CPU,
    where live-buffer stats read 0, this is the number the 2-D-mesh
    memory floor is judged on."""
    total = 0
    for leaf in jax.tree.leaves(params):
        shard_shape = (leaf.sharding.shard_shape(leaf.shape)
                       if hasattr(leaf, "sharding") else np.shape(leaf))
        total += int(np.prod(shard_shape, dtype=np.int64)) * leaf.dtype.itemsize
    return total


def init_distributed(coordinator: str, num_processes: int,
                     process_id: int) -> None:
    """Join (or found) a multi-process JAX runtime, cross-host-collective
    ready.

    Must run BEFORE any other JAX call: on CPU backends the default
    collective implementation cannot execute multi-process computations
    at all ("Multiprocess computations aren't implemented on the CPU
    backend"), so this selects the gloo transport FIRST — config flags
    only take effect before backend initialization — and then calls
    ``jax.distributed.initialize``. After it returns, ``jax.devices()``
    spans every process (each host contributes its local devices, in
    process order), so the engine's 1-D "clients" mesh — whose block
    runner specs have been process-count agnostic since the mesh PR —
    picks up cross-host shards with no further changes.

    coordinator:   "host:port" of process 0's coordination service.
    num_processes: total process count in the job.
    process_id:    this process's rank in [0, num_processes).
    """
    if not 0 <= process_id < num_processes:
        raise ValueError(f"process_id={process_id} out of range for "
                         f"num_processes={num_processes}")
    if num_processes > 1:
        try:
            jax.config.update("jax_cpu_collectives_implementation",
                              "gloo")
        except (AttributeError, ValueError):
            pass        # older jaxlib: flag absent; TPU/GPU don't need it
        jax.distributed.initialize(coordinator_address=coordinator,
                                   num_processes=num_processes,
                                   process_id=process_id)


def shard_map_compat(f, *, mesh, in_specs, out_specs, manual_axes_names):
    """Version-portable shard_map: manual over `manual_axes_names`, GSPMD
    auto over every other mesh axis.

    Newer JAX exposes ``jax.shard_map(..., axis_names=...)`` (manual axes
    named directly); older releases only have
    ``jax.experimental.shard_map.shard_map(..., auto=...)`` (auto axes
    named, i.e. the complement). Resolve whichever exists.

    Shared by the pod-client mode (repro.core.federated, manual over
    "pod") and the round engine's client-sharded block runner
    (repro.core.engine, manual over "clients").
    """
    manual = frozenset(manual_axes_names)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False,
                             axis_names=set(manual))
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - manual
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False, auto=auto)


def _axes(mesh):
    names = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in names)
    return batch, ("model" if "model" in names else None)


def _size(mesh, ax):
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        s = 1
        for a in ax:
            s *= mesh.shape[a]
        return s
    return mesh.shape[ax]


def _fits(dim, mesh, ax):
    return ax is not None and dim % _size(mesh, ax) == 0


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


_BASE_RANK = {
    "embed": 2, "lm_head": 2, "vision_proj": 2, "final_norm": 1,
    "wq": 3, "wk": 3, "wv": 3, "wo": 3,
    "router": 2, "w_in": 2, "w_out": 2, "b_in": 1, "b_out": 1,
    "w_z": 2, "w_x": 2, "w_B": 2, "w_C": 2, "w_dt": 2,
    "dt_bias": 1, "A_log": 1, "D": 1, "conv_w": 2, "conv_b": 1,
    "gate_norm": 1, "norm1": 1, "norm2": 1, "norm_x": 1,
}


def _base_rank(path: str, leaf: str) -> int:
    if leaf in ("w_gate", "w_up"):
        return 3 if "/moe/" in "/" + path + "/" and "shared" not in path else 2
    if leaf == "w_down":
        return 3 if "/moe/" in "/" + path + "/" and "shared" not in path else 2
    if leaf == "w_out" and "mamba" in path:
        return 2
    return _BASE_RANK.get(leaf, 2)


def param_spec(path: str, shape: Tuple[int, ...], mesh) -> P:
    """Sharding rule for one parameter leaf."""
    batch_ax, model_ax = _axes(mesh)
    data_ax = "data" if "data" in mesh.axis_names else None
    leaf_name = path.rsplit("/", 1)[-1]
    base = _base_rank(path, leaf_name)
    if len(shape) < base:  # malformed/unknown leaf: replicate
        return P(*([None] * len(shape)))
    off = len(shape) - base  # scan stacks carry leading group dims
    dims = list(shape[off:])
    spec = [None] * len(shape)
    leaf = leaf_name

    def assign(rel_idx, ax):
        spec[off + rel_idx] = ax

    if len(dims) == 0 or model_ax is None:
        pass
    elif leaf == "embed":
        if _fits(dims[0], mesh, model_ax):
            assign(0, model_ax)  # vocab
    elif leaf == "lm_head":
        if _fits(dims[1], mesh, model_ax):
            assign(1, model_ax)  # vocab
    elif leaf in ("wq", "wk", "wv"):
        # (d, N, hd): heads -> head_dim -> contraction fallback
        if _fits(dims[1], mesh, model_ax):
            assign(1, model_ax)
        elif _fits(dims[2], mesh, model_ax):
            assign(2, model_ax)
        elif _fits(dims[0], mesh, model_ax):
            assign(0, model_ax)
    elif leaf == "wo":
        # (N, hd, d)
        if _fits(dims[0], mesh, model_ax):
            assign(0, model_ax)
        elif _fits(dims[1], mesh, model_ax):
            assign(1, model_ax)
        elif _fits(dims[2], mesh, model_ax):
            assign(2, model_ax)
    elif leaf in ("w_gate", "w_up"):
        if len(dims) == 3:  # MoE experts (E, d, f)
            from repro.runtime.flags import feature
            if feature("moe2d") and not _fits(dims[0], mesh, model_ax):
                # §Perf lever: stationary 2D sharding (d->data, f->model):
                # activations all-reduce instead of FSDP weight gathers.
                if _fits(dims[1], mesh, data_ax):
                    assign(1, data_ax)
                if _fits(dims[2], mesh, model_ax):
                    assign(2, model_ax)
                return P(*spec)
            if _fits(dims[0], mesh, model_ax):
                assign(0, model_ax)       # expert parallelism
            elif _fits(dims[2], mesh, model_ax):
                assign(2, model_ax)       # fall back to hidden TP
        else:               # dense (d, f)
            if _fits(dims[1], mesh, model_ax):
                assign(1, model_ax)
    elif leaf == "w_down":
        if len(dims) == 3:  # (E, f, d)
            from repro.runtime.flags import feature
            if feature("moe2d") and not _fits(dims[0], mesh, model_ax):
                if _fits(dims[1], mesh, model_ax):
                    assign(1, model_ax)
                if _fits(dims[2], mesh, data_ax):
                    assign(2, data_ax)
                return P(*spec)
            if _fits(dims[0], mesh, model_ax):
                assign(0, model_ax)
            elif _fits(dims[1], mesh, model_ax):
                assign(1, model_ax)
        else:               # (f, d)
            if _fits(dims[0], mesh, model_ax):
                assign(0, model_ax)
    elif leaf in ("w_in",):
        if _fits(dims[1], mesh, model_ax):
            assign(1, model_ax)
    elif leaf in ("w_out",):
        if _fits(dims[0], mesh, model_ax):
            assign(0, model_ax)
    elif leaf in ("w_z", "w_x"):      # (d, d_inner)
        if _fits(dims[1], mesh, model_ax):
            assign(1, model_ax)
    elif leaf in ("w_B", "w_C", "w_dt"):
        if _fits(dims[1], mesh, model_ax):
            assign(1, model_ax)
    elif leaf == "conv_w":            # (W, conv_dim)
        if _fits(dims[1], mesh, model_ax):
            assign(1, model_ax)
    elif leaf == "vision_proj":
        if _fits(dims[1], mesh, model_ax):
            assign(1, model_ax)
    # norms, biases, router, A_log, D, dt_bias, conv_b, gate_norm: replicated

    # ---- FSDP pass: shard one more (unassigned, divisible) dim on data ----
    if data_ax is not None:
        itemsize = 2  # bf16 dominant
        sharded = any(s is not None for s in spec)
        model_shards = _size(mesh, model_ax) if sharded else 1
        per_shard = int(np.prod(shape)) * itemsize // max(model_shards, 1)
        if per_shard > FSDP_THRESHOLD_BYTES:
            # biggest unassigned divisible dim (excluding stack dim)
            cands = [(dims[i], i) for i in range(len(dims))
                     if spec[off + i] is None and _fits(dims[i], mesh, data_ax)]
            if cands:
                _, best = max(cands)
                assign(best, data_ax)
    return P(*spec)


def param_shardings(params, mesh):
    """Pytree of NamedSharding matching ``params``."""
    def leaf_spec(path, leaf):
        return NamedSharding(mesh, param_spec(_path_str(path),
                                              np.shape(leaf), mesh))
    return jax.tree_util.tree_map_with_path(leaf_spec, params)


# ---------------------------------------------------------------------------
# input shardings
# ---------------------------------------------------------------------------

def batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def token_spec(mesh, batch_size, extra_dims=1, leading=0):
    """(batch, seq...) arrays: shard batch when divisible."""
    b_ax = batch_axes(mesh)
    ax = b_ax if b_ax and batch_size % _size(mesh, b_ax) == 0 else None
    return P(*([None] * leading + [ax] + [None] * extra_dims))


def attn_cache_spec(mesh, ndim, batch_size, seq_len) -> P:
    """(..., B, S, Kv, hd): batch on data axes when divisible, sequence on
    the remaining axes (context parallelism) — the KV cache is the decode
    memory hog, so we spread it over every available axis."""
    b_ax = batch_axes(mesh)
    model_ax = "model" if "model" in mesh.axis_names else None
    spec = [None] * ndim
    b_i, s_i = ndim - 4, ndim - 3
    seq_axes = []
    if b_ax and batch_size % _size(mesh, b_ax) == 0:
        spec[b_i] = b_ax
    else:
        seq_axes.extend(b_ax)
    if model_ax:
        seq_axes.append(model_ax)
    seq_axes = tuple(seq_axes)
    if seq_axes and seq_len % _size(mesh, seq_axes) == 0:
        spec[s_i] = seq_axes
    return P(*spec)


DEFAULT_PARTITIONER = register_partitioner("default")
# The shipped architecture families share one per-leaf rule set (leaf
# NAMES are the contract: wq/wk/wv/wo, w_in/w_out, experts, mamba
# projections), so their partitioners alias the same rules under
# distinct, fingerprint-stable names.
for _arch in ("transformer", "mamba2", "moe"):
    register_partitioner(_arch)
del _arch


def mamba_cache_spec(mesh, leaf_name, ndim, batch_size, head_count) -> P:
    """ssm state (..., B, H, P, N) or conv state (..., B, W, C)."""
    b_ax = batch_axes(mesh)
    model_ax = "model" if "model" in mesh.axis_names else None
    base = 4 if leaf_name == "ssm" else 3
    off = ndim - base
    spec = [None] * ndim
    if b_ax and batch_size % _size(mesh, b_ax) == 0:
        spec[off] = b_ax
    if (model_ax and leaf_name == "ssm"
            and head_count % _size(mesh, model_ax) == 0):
        spec[off + 1] = model_ax
    return P(*spec)
