"""Parameter / input sharding rules for the production meshes.

Strategy (baseline; the §Perf loop iterates on it):
- tensor parallelism on the ``model`` axis: FFN hidden dim, attention
  heads (falling back to head_dim, then the contraction dim when head
  counts don't divide), MoE experts (expert parallelism when E >= axis),
  vocab for embed/lm_head;
- FSDP on the ``data`` axis for any leaf whose per-model-shard footprint
  exceeds a threshold (weights are all-gathered layer-by-layer under the
  scan, so the live working set stays one layer);
- batch on (``pod``, ``data``); long-context decode (batch=1) shards the
  KV-cache *sequence* dim instead (context parallelism).

All rules respect divisibility: an axis that does not divide the dim is
dropped (replicated) rather than unevenly sharded.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

FSDP_THRESHOLD_BYTES = 32 * 1024 * 1024


def init_distributed(coordinator: str, num_processes: int,
                     process_id: int) -> None:
    """Join (or found) a multi-process JAX runtime, cross-host-collective
    ready.

    Must run BEFORE any other JAX call: on CPU backends the default
    collective implementation cannot execute multi-process computations
    at all ("Multiprocess computations aren't implemented on the CPU
    backend"), so this selects the gloo transport FIRST — config flags
    only take effect before backend initialization — and then calls
    ``jax.distributed.initialize``. After it returns, ``jax.devices()``
    spans every process (each host contributes its local devices, in
    process order), so the engine's 1-D "clients" mesh — whose block
    runner specs have been process-count agnostic since the mesh PR —
    picks up cross-host shards with no further changes.

    coordinator:   "host:port" of process 0's coordination service.
    num_processes: total process count in the job.
    process_id:    this process's rank in [0, num_processes).
    """
    if not 0 <= process_id < num_processes:
        raise ValueError(f"process_id={process_id} out of range for "
                         f"num_processes={num_processes}")
    if num_processes > 1:
        try:
            jax.config.update("jax_cpu_collectives_implementation",
                              "gloo")
        except (AttributeError, ValueError):
            pass        # older jaxlib: flag absent; TPU/GPU don't need it
        jax.distributed.initialize(coordinator_address=coordinator,
                                   num_processes=num_processes,
                                   process_id=process_id)


def shard_map_compat(f, *, mesh, in_specs, out_specs, manual_axes_names):
    """Version-portable shard_map: manual over `manual_axes_names`, GSPMD
    auto over every other mesh axis.

    Newer JAX exposes ``jax.shard_map(..., axis_names=...)`` (manual axes
    named directly); older releases only have
    ``jax.experimental.shard_map.shard_map(..., auto=...)`` (auto axes
    named, i.e. the complement). Resolve whichever exists.

    Shared by the pod-client mode (repro.core.federated, manual over
    "pod") and the round engine's client-sharded block runner
    (repro.core.engine, manual over "clients").
    """
    manual = frozenset(manual_axes_names)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False,
                             axis_names=set(manual))
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - manual
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False, auto=auto)


def _axes(mesh):
    names = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in names)
    return batch, ("model" if "model" in names else None)


def _size(mesh, ax):
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        s = 1
        for a in ax:
            s *= mesh.shape[a]
        return s
    return mesh.shape[ax]


def _fits(dim, mesh, ax):
    return ax is not None and dim % _size(mesh, ax) == 0


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


_BASE_RANK = {
    "embed": 2, "lm_head": 2, "vision_proj": 2, "final_norm": 1,
    "wq": 3, "wk": 3, "wv": 3, "wo": 3,
    "router": 2, "w_in": 2, "w_out": 2, "b_in": 1, "b_out": 1,
    "w_z": 2, "w_x": 2, "w_B": 2, "w_C": 2, "w_dt": 2,
    "dt_bias": 1, "A_log": 1, "D": 1, "conv_w": 2, "conv_b": 1,
    "gate_norm": 1, "norm1": 1, "norm2": 1, "norm_x": 1,
}


def _base_rank(path: str, leaf: str) -> int:
    if leaf in ("w_gate", "w_up"):
        return 3 if "/moe/" in "/" + path + "/" and "shared" not in path else 2
    if leaf == "w_down":
        return 3 if "/moe/" in "/" + path + "/" and "shared" not in path else 2
    if leaf == "w_out" and "mamba" in path:
        return 2
    return _BASE_RANK.get(leaf, 2)


def param_spec(path: str, shape: Tuple[int, ...], mesh) -> P:
    """Sharding rule for one parameter leaf."""
    batch_ax, model_ax = _axes(mesh)
    data_ax = "data" if "data" in mesh.axis_names else None
    leaf_name = path.rsplit("/", 1)[-1]
    base = _base_rank(path, leaf_name)
    if len(shape) < base:  # malformed/unknown leaf: replicate
        return P(*([None] * len(shape)))
    off = len(shape) - base  # scan stacks carry leading group dims
    dims = list(shape[off:])
    spec = [None] * len(shape)
    leaf = leaf_name

    def assign(rel_idx, ax):
        spec[off + rel_idx] = ax

    if len(dims) == 0 or model_ax is None:
        pass
    elif leaf == "embed":
        if _fits(dims[0], mesh, model_ax):
            assign(0, model_ax)  # vocab
    elif leaf == "lm_head":
        if _fits(dims[1], mesh, model_ax):
            assign(1, model_ax)  # vocab
    elif leaf in ("wq", "wk", "wv"):
        # (d, N, hd): heads -> head_dim -> contraction fallback
        if _fits(dims[1], mesh, model_ax):
            assign(1, model_ax)
        elif _fits(dims[2], mesh, model_ax):
            assign(2, model_ax)
        elif _fits(dims[0], mesh, model_ax):
            assign(0, model_ax)
    elif leaf == "wo":
        # (N, hd, d)
        if _fits(dims[0], mesh, model_ax):
            assign(0, model_ax)
        elif _fits(dims[1], mesh, model_ax):
            assign(1, model_ax)
        elif _fits(dims[2], mesh, model_ax):
            assign(2, model_ax)
    elif leaf in ("w_gate", "w_up"):
        if len(dims) == 3:  # MoE experts (E, d, f)
            from repro.runtime.flags import feature
            if feature("moe2d") and not _fits(dims[0], mesh, model_ax):
                # §Perf lever: stationary 2D sharding (d->data, f->model):
                # activations all-reduce instead of FSDP weight gathers.
                if _fits(dims[1], mesh, data_ax):
                    assign(1, data_ax)
                if _fits(dims[2], mesh, model_ax):
                    assign(2, model_ax)
                return P(*spec)
            if _fits(dims[0], mesh, model_ax):
                assign(0, model_ax)       # expert parallelism
            elif _fits(dims[2], mesh, model_ax):
                assign(2, model_ax)       # fall back to hidden TP
        else:               # dense (d, f)
            if _fits(dims[1], mesh, model_ax):
                assign(1, model_ax)
    elif leaf == "w_down":
        if len(dims) == 3:  # (E, f, d)
            from repro.runtime.flags import feature
            if feature("moe2d") and not _fits(dims[0], mesh, model_ax):
                if _fits(dims[1], mesh, model_ax):
                    assign(1, model_ax)
                if _fits(dims[2], mesh, data_ax):
                    assign(2, data_ax)
                return P(*spec)
            if _fits(dims[0], mesh, model_ax):
                assign(0, model_ax)
            elif _fits(dims[1], mesh, model_ax):
                assign(1, model_ax)
        else:               # (f, d)
            if _fits(dims[0], mesh, model_ax):
                assign(0, model_ax)
    elif leaf in ("w_in",):
        if _fits(dims[1], mesh, model_ax):
            assign(1, model_ax)
    elif leaf in ("w_out",):
        if _fits(dims[0], mesh, model_ax):
            assign(0, model_ax)
    elif leaf in ("w_z", "w_x"):      # (d, d_inner)
        if _fits(dims[1], mesh, model_ax):
            assign(1, model_ax)
    elif leaf in ("w_B", "w_C", "w_dt"):
        if _fits(dims[1], mesh, model_ax):
            assign(1, model_ax)
    elif leaf == "conv_w":            # (W, conv_dim)
        if _fits(dims[1], mesh, model_ax):
            assign(1, model_ax)
    elif leaf == "vision_proj":
        if _fits(dims[1], mesh, model_ax):
            assign(1, model_ax)
    # norms, biases, router, A_log, D, dt_bias, conv_b, gate_norm: replicated

    # ---- FSDP pass: shard one more (unassigned, divisible) dim on data ----
    if data_ax is not None:
        itemsize = 2  # bf16 dominant
        sharded = any(s is not None for s in spec)
        model_shards = _size(mesh, model_ax) if sharded else 1
        per_shard = int(np.prod(shape)) * itemsize // max(model_shards, 1)
        if per_shard > FSDP_THRESHOLD_BYTES:
            # biggest unassigned divisible dim (excluding stack dim)
            cands = [(dims[i], i) for i in range(len(dims))
                     if spec[off + i] is None and _fits(dims[i], mesh, data_ax)]
            if cands:
                _, best = max(cands)
                assign(best, data_ax)
    return P(*spec)


def param_shardings(params, mesh):
    """Pytree of NamedSharding matching ``params``."""
    def leaf_spec(path, leaf):
        return NamedSharding(mesh, param_spec(_path_str(path),
                                              np.shape(leaf), mesh))
    return jax.tree_util.tree_map_with_path(leaf_spec, params)


# ---------------------------------------------------------------------------
# input shardings
# ---------------------------------------------------------------------------

def batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def token_spec(mesh, batch_size, extra_dims=1, leading=0):
    """(batch, seq...) arrays: shard batch when divisible."""
    b_ax = batch_axes(mesh)
    ax = b_ax if b_ax and batch_size % _size(mesh, b_ax) == 0 else None
    return P(*([None] * leading + [ax] + [None] * extra_dims))


def attn_cache_spec(mesh, ndim, batch_size, seq_len) -> P:
    """(..., B, S, Kv, hd): batch on data axes when divisible, sequence on
    the remaining axes (context parallelism) — the KV cache is the decode
    memory hog, so we spread it over every available axis."""
    b_ax = batch_axes(mesh)
    model_ax = "model" if "model" in mesh.axis_names else None
    spec = [None] * ndim
    b_i, s_i = ndim - 4, ndim - 3
    seq_axes = []
    if b_ax and batch_size % _size(mesh, b_ax) == 0:
        spec[b_i] = b_ax
    else:
        seq_axes.extend(b_ax)
    if model_ax:
        seq_axes.append(model_ax)
    seq_axes = tuple(seq_axes)
    if seq_axes and seq_len % _size(mesh, seq_axes) == 0:
        spec[s_i] = seq_axes
    return P(*spec)


def mamba_cache_spec(mesh, leaf_name, ndim, batch_size, head_count) -> P:
    """ssm state (..., B, H, P, N) or conv state (..., B, W, C)."""
    b_ax = batch_axes(mesh)
    model_ax = "model" if "model" in mesh.axis_names else None
    base = 4 if leaf_name == "ssm" else 3
    off = ndim - base
    spec = [None] * ndim
    if b_ax and batch_size % _size(mesh, b_ax) == 0:
        spec[off] = b_ax
    if (model_ax and leaf_name == "ssm"
            and head_count % _size(mesh, model_ax) == 0):
        spec[off + 1] = model_ax
    return P(*spec)
