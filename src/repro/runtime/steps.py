"""Step builders: the paper's technique (TinyReptile round) as the
production train step, plus joint-training baseline, prefill, and decode.

``make_meta_train_step`` is TinyReptile at mesh scale:
  - the inner loop is a lax.scan of K streaming SGD steps (the paper's
    online learning: one microbatch per step, discarded immediately);
  - the client cohort is the data-parallel section of the mesh, so each
    inner step's gradient is the cohort all-reduce (batched-Reptile
    semantics, paper Fig. 2);
  - the outer update is the Reptile interpolation phi <- phi + a(phi_hat - phi).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.runtime.shardctx import shard


def make_meta_train_step(model, *, beta: float = 0.01, alpha: float = 0.5,
                         use_pallas: bool = False) -> Callable:
    """TinyReptile round. batch: {"tokens": (K, mb, S), "labels": ...}.

    Returns (new_phi, metrics). K = inner stream length (paper: one SGD
    step per arriving sample; here one per arriving microbatch).
    """
    def loss_of(phi_hat, micro):
        return model.loss_fn(phi_hat, micro)

    def step(phi, batch, alpha=alpha):
        # alpha may be a traced scalar (annealed server rate) — one compile
        def inner(phi_hat, micro):
            loss, g = jax.value_and_grad(loss_of)(phi_hat, micro)
            phi_hat = jax.tree.map(
                lambda p, gg: (p.astype(jnp.float32)
                               - beta * gg.astype(jnp.float32)).astype(p.dtype),
                phi_hat, g)
            return phi_hat, loss

        from repro.runtime.flags import probe_mode
        if probe_mode():
            k = jax.tree.leaves(batch)[0].shape[0]
            phi_hat, losses = phi, []
            for i in range(k):
                micro = jax.tree.map(lambda a: a[i], batch)
                phi_hat, l = inner(phi_hat, micro)
                losses.append(l)
            losses = jnp.stack(losses)
        else:
            phi_hat, losses = jax.lax.scan(inner, phi, batch)
        if use_pallas:
            from repro.kernels import ops as kops
            new_phi = jax.tree.map(
                lambda p, ph: kops.meta_update(p, ph, alpha), phi, phi_hat)
        else:
            new_phi = jax.tree.map(
                lambda p, ph: (p.astype(jnp.float32) + alpha
                               * (ph.astype(jnp.float32)
                                  - p.astype(jnp.float32))).astype(p.dtype),
                phi, phi_hat)
        return new_phi, {"loss": losses.mean(), "inner_first": losses[0],
                         "inner_last": losses[-1]}

    return step


def make_joint_train_step(model, optimizer, schedule) -> Callable:
    """Baseline joint training (the transfer-learning / FedAVG-objective
    regime the paper compares against): one optimizer step per batch."""
    def step(params, opt_state, opt_step, batch):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        lr = schedule(opt_step)
        new_params, new_state = optimizer.update(grads, opt_state, params, lr)
        return new_params, new_state, opt_step + 1, {"loss": loss, "lr": lr}
    return step


def make_prefill_step(model) -> Callable:
    def step(params, batch):
        return model.prefill_fn(params, batch)
    return step


def make_decode_step(model) -> Callable:
    def step(params, batch):
        return model.decode_fn(params, batch)
    return step


def microbatch(batch: Dict[str, Any], k: int) -> Dict[str, Any]:
    """Reshape (B, ...) arrays to (k, B//k, ...) inner-stream microbatches."""
    def r(x):
        b = x.shape[0]
        return x.reshape(k, b // k, *x.shape[1:])
    return jax.tree.map(r, batch)
