"""Step builders: the paper's technique (TinyReptile round) as the
production train step, plus joint-training baseline, prefill, and decode.

``make_meta_train_step`` is TinyReptile at mesh scale — COHORT mode: the
data-parallel section of the mesh acts as one composite client. The
round body is built from the federated engine's building blocks
(repro.core.engine):
  - ``streaming_sgd``: a lax.scan of K streaming SGD steps (the paper's
    online learning: one microbatch per step, discarded immediately);
    each inner step's gradient is the cohort all-reduce
    (batched-Reptile semantics, paper Fig. 2);
  - ``meta_interpolate``: the Reptile server update
    phi <- phi + a (phi_hat - phi), Pallas-fused where available.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Iterator

import jax
import jax.numpy as jnp

from repro.core.engine import meta_interpolate, streaming_sgd
from repro.core.pipeline import prefetch_items
from repro.runtime.shardctx import shard


def make_meta_train_step(model, *, beta: float = 0.01, alpha: float = 0.5,
                         use_pallas: bool = False) -> Callable:
    """TinyReptile round. batch: {"tokens": (K, mb, S), "labels": ...}.

    Returns (new_phi, metrics). K = inner stream length (paper: one SGD
    step per arriving sample; here one per arriving microbatch).
    """
    def loss_of(phi_hat, micro):
        return model.loss_fn(phi_hat, micro)

    def step(phi, batch, alpha=alpha):
        # alpha may be a traced scalar (annealed server rate) — one compile
        phi_hat, losses = streaming_sgd(loss_of, phi, batch, beta)
        new_phi = meta_interpolate(phi, phi_hat, alpha,
                                   use_pallas=use_pallas)
        return new_phi, {"loss": losses.mean(), "inner_first": losses[0],
                         "inner_last": losses[-1]}

    return step


def make_joint_train_step(model, optimizer, schedule) -> Callable:
    """Baseline joint training (the transfer-learning / FedAVG-objective
    regime the paper compares against): one optimizer step per batch."""
    def step(params, opt_state, opt_step, batch):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        lr = schedule(opt_step)
        new_params, new_state = optimizer.update(grads, opt_state, params, lr)
        return new_params, new_state, opt_step + 1, {"loss": loss, "lr": lr}
    return step


def make_prefill_step(model) -> Callable:
    def step(params, batch):
        return model.prefill_fn(params, batch)
    return step


def make_decode_step(model) -> Callable:
    def step(params, batch):
        return model.decode_fn(params, batch)
    return step


def microbatch(batch: Dict[str, Any], k: int) -> Dict[str, Any]:
    """Reshape (B, ...) arrays to (k, B//k, ...) inner-stream microbatches."""
    def r(x):
        b = x.shape[0]
        return x.reshape(k, b // k, *x.shape[1:])
    return jax.tree.map(r, batch)


def prefetch_batches(make_batch: Callable[[int], Any], num_batches: int,
                     depth: int = 2) -> Iterator[Any]:
    """Yield ``make_batch(i)`` for ``i in range(num_batches)``, staged by a
    background thread so host batch building + H2D copy for step N+1 hide
    behind device compute on step N (the engine's round pipeline, reused
    for launcher-scale training loops).

    ``make_batch`` is called strictly in index order on ONE thread, so a
    seeded host RNG consumed inside it draws exactly the synchronous
    sequence — ``depth=0`` falls back to inline calls with identical
    numerics. Beware that ``jax.default_device`` is thread-local: pin
    device placement explicitly inside ``make_batch`` (e.g.
    ``jax.device_put(..., device)``) if it matters.
    """
    return prefetch_items(make_batch, num_batches, depth=depth)
