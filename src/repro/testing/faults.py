"""Fault-injection harness for the preemption-safety layer.

Simulates the ways a federated run actually dies and the ways its
checkpoint directory actually rots, so tests can pin the recovery
contract (repro.checkpoint + run_federated(ckpt_dir=..., resume=True)):

- :func:`crash_at_round` — deterministic in-process preemption: raise
  right after the first durable snapshot at/past a given round (pair
  with ``ckpt_async=False`` for an exact crash point);
- :func:`announce_snapshots` + :func:`kill_after_snapshot` — REAL
  preemption: a subprocess child prints a marker per durable snapshot,
  the parent SIGKILLs it mid-flight (possibly mid-block or mid-write —
  resume must fall back to the newest valid snapshot);
- :func:`truncate_file` (torn write), :func:`flip_bytes` (corrupted
  leaves under an intact size), :func:`make_stale_latest` (pointer to a
  nonexistent payload) — checkpoint-directory rot that restore must
  detect via checksums and degrade around with a warning.
"""
from __future__ import annotations

import contextlib
import os
import signal
import subprocess
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.checkpoint import ckpt as _ckpt

#: stdout marker printed by announce_snapshots after each durable write
SNAPSHOT_TAG = "SNAPSHOT"


class SimulatedPreemption(Exception):
    """The 'kill' raised by :func:`crash_at_round` — catch it exactly
    (never via a broad handler) in tests."""


@contextlib.contextmanager
def crash_at_round(round_threshold: int):
    """While active, raise :class:`SimulatedPreemption` right after the
    FIRST durable snapshot with ``step >= round_threshold`` (payload +
    manifest + LATEST already on disk, so a resume from that very
    snapshot must succeed). With the engine's default async writer the
    raise lands on the writer thread and surfaces at the next
    submit/close; pass ``ckpt_async=False`` for a deterministic
    main-thread crash point."""
    prev = _ckpt._post_save_hook

    def hook(step: int) -> None:
        if prev is not None:
            prev(step)
        if step >= round_threshold:
            raise SimulatedPreemption(
                f"simulated preemption after snapshot {step}")

    _ckpt._post_save_hook = hook
    try:
        yield
    finally:
        _ckpt._post_save_hook = prev


@contextlib.contextmanager
def announce_snapshots(tag: str = SNAPSHOT_TAG):
    """While active, print ``'<tag> <step>'`` (flushed) after each
    durable snapshot — the stdout marker :func:`kill_after_snapshot`
    watches for from the parent process."""
    prev = _ckpt._post_save_hook

    def hook(step: int) -> None:
        if prev is not None:
            prev(step)
        print(f"{tag} {step}", flush=True)

    _ckpt._post_save_hook = hook
    try:
        yield
    finally:
        _ckpt._post_save_hook = prev


def kill_after_snapshot(cmd: List[str], n: int = 1, *,
                        marker: str = SNAPSHOT_TAG, env=None, cwd=None,
                        timeout: float = 300.0,
                        sig=signal.SIGKILL) -> Tuple[Optional[int], str]:
    """Run ``cmd`` and SIGKILL it right after its n-th ``marker`` stdout
    line — a real preemption at an ARBITRARY execution point (the child
    may die inside a block, mid-device-transfer, or mid-write; only the
    announced snapshots are guaranteed durable). Returns
    ``(returncode, collected stdout)``; the return code is the signal's
    negative on a successful kill."""
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True, env=env,
                            cwd=cwd)
    seen, lines = 0, []
    deadline = time.monotonic() + timeout
    try:
        for line in proc.stdout:
            lines.append(line)
            if marker in line:
                seen += 1
                if seen >= n:
                    proc.send_signal(sig)
                    break
            if time.monotonic() > deadline:
                proc.kill()
                raise TimeoutError(
                    f"{marker} seen {seen}/{n} times within {timeout}s:\n"
                    + "".join(lines[-50:]))
        proc.wait(timeout=60)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()
    return proc.returncode, "".join(lines)


def truncate_file(path: str, keep_fraction: float = 0.5,
                  keep_bytes: Optional[int] = None) -> int:
    """Torn write: chop ``path`` to a prefix (default half its bytes).
    Returns the bytes kept."""
    size = os.path.getsize(path)
    keep = (keep_bytes if keep_bytes is not None
            else max(1, int(size * keep_fraction)))
    keep = min(keep, size)
    with open(path, "rb+") as f:
        f.truncate(keep)
    return keep


def flip_bytes(path: str, offset: Optional[int] = None, count: int = 8,
               seed: int = 0) -> int:
    """Corrupt ``count`` bytes in place (XOR 0xFF) WITHOUT changing the
    file size — the failure mode only a content checksum catches.
    Returns the corrupted offset."""
    size = os.path.getsize(path)
    if offset is None:
        rng = np.random.default_rng(seed)
        offset = int(rng.integers(max(1, size - count)))
    with open(path, "rb+") as f:
        f.seek(offset)
        data = f.read(count)
        f.seek(offset)
        f.write(bytes(b ^ 0xFF for b in data))
    return offset


def make_stale_latest(directory: str,
                      name: str = "ckpt_99999999.npz") -> None:
    """Point the LATEST marker at a payload that does not exist (a
    crash between payload write and pointer update, or a pruned file)."""
    with open(os.path.join(directory, "LATEST"), "w") as f:
        f.write(name)
