"""Test-support utilities shipped with the library (importable from
tests AND from subprocess children): fault injection for the
preemption-safety layer lives in repro.testing.faults."""
