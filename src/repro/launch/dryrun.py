import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks device count on first init.

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_arch, get_shape, ALL_ARCHS, SHAPES  # noqa: E402
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,  # noqa: E402
                               make_production_mesh)
from repro.launch.specs import input_specs, K_INNER  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.runtime import steps as steps_lib  # noqa: E402
from repro.runtime.shardctx import mesh_context  # noqa: E402

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def skip_reason(cfg, shape):
    if shape.name == "long_500k":
        if cfg.name == "whisper-tiny":
            return "enc-dec decoder (448-pos design); 500k decode meaningless"
        if not cfg.supports_long_context():
            return "pure full-attention arch; no sub-quadratic variant"
    return None


def parse_collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op, per kind."""
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(.*?)\s+(" + "|".join(COLLECTIVES) + r")\(", line)
        if not m:
            continue
        lhs, kind = m.group(1), m.group(2)
        if "-start" in line and kind + "-start" not in line:
            pass
        nbytes = 0
        for dt, dims in shape_re.findall(lhs):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] += nbytes
        counts[kind] += 1
    # ignore the paired *-done ops (they repeat the shape): heuristic — the
    # async pairs appear as kind-start/kind-done custom calls in some
    # lowerings; plain HLO here uses synchronous ops, so no dedup needed.
    return out, counts


def build_step_and_args(cfg, shape, mesh, step_kind):
    model = build_model(cfg)
    params, batch = input_specs(cfg, shape, mesh)
    if shape.kind == "train":
        if step_kind == "joint":
            from repro.optim import adamw, constant
            opt = adamw()
            step = steps_lib.make_joint_train_step(model, opt, constant(1e-4))
            opt_state = jax.eval_shape(lambda p: opt.init(p), params)
            opt_step = jax.ShapeDtypeStruct((), np.int32)
            # flatten microbatch dim for joint baseline: (K*mb, S)
            def flat(s):
                return jax.ShapeDtypeStruct((s.shape[0] * s.shape[1],)
                                            + s.shape[2:], s.dtype)
            jbatch = jax.tree.map(flat, batch)
            return step, (params, opt_state, opt_step, jbatch)
        step = steps_lib.make_meta_train_step(model)
        return step, (params, batch)
    if shape.kind == "prefill":
        return steps_lib.make_prefill_step(model), (params, batch)
    return steps_lib.make_decode_step(model), (params, batch)


def _probe_period(cfg):
    """Layer-count granularity for cost probes."""
    if cfg.family == "hybrid":
        return max(cfg.hybrid_attn_every, 1)
    from repro.models.transformer import find_period, layer_specs
    return find_period(layer_specs(cfg))


def _compile_cost(cfg, shape, mesh, step_kind, k_inner=None):
    """Probe compile (unrolled) -> dict of numeric costs.

    Probes run in UNIFORM f32 and report bytes/2: the CPU backend inserts
    f32 conversion buffers around bf16 dots (a TPU MXU would not), so a
    bf16 probe overstates HBM traffic; an all-f32 program has no converts
    and is byte-for-byte 2x an ideal bf16 one. FLOP counts are unaffected.
    """
    import dataclasses
    from repro.launch import specs as specs_mod
    from repro.runtime.flags import probe_scope
    cfg = dataclasses.replace(cfg, dtype="float32")
    with probe_scope(True), mesh_context(mesh):
        model = build_model(cfg)
        if shape.kind == "train":
            params, batch = (specs_mod.param_specs(cfg, mesh),
                             specs_mod.train_batch_specs(
                                 cfg, shape, mesh,
                                 k_inner=k_inner or specs_mod.K_INNER))
            step = steps_lib.make_meta_train_step(model)
        elif shape.kind == "prefill":
            params, batch = specs_mod.input_specs(cfg, shape, mesh)
            step = steps_lib.make_prefill_step(model)
        else:
            params, batch = specs_mod.input_specs(cfg, shape, mesh)
            step = steps_lib.make_decode_step(model)
        compiled = jax.jit(step).lower(params, batch).compile()
    out = {}
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    out["flops"] = float(cost.get("flops", 0.0))
    out["bytes"] = float(cost.get("bytes accessed", 0.0)) / 2  # f32 -> bf16
    cb, cc = parse_collective_bytes(compiled.as_text())
    for k in COLLECTIVES:
        out[f"coll_bytes/{k}"] = float(cb[k]) / 2               # f32 -> bf16
        out[f"coll_count/{k}"] = float(cc[k])
    return out


def probe_costs(cfg, shape, mesh, step_kind):
    """Extrapolate exact per-step costs from unrolled probe compiles.

    Model: cost(L, K) = K * (a + b*L) + m   (train; K = inner stream)
           cost(L)    = c0 + b*L            (prefill / decode)
    """
    import dataclasses
    from repro.launch.specs import K_INNER
    p = _probe_period(cfg)
    L_full = cfg.num_layers
    L1, L2 = p, 2 * p
    if L_full <= L2:  # tiny model: probe exactly
        c = _compile_cost(dataclasses.replace(cfg, num_layers=L_full),
                          shape, mesh, step_kind,
                          k_inner=1 if shape.kind == "train" else None)
        if shape.kind != "train":
            return c, {"probes": [L_full]}
        c2 = _compile_cost(dataclasses.replace(cfg, num_layers=L_full),
                           shape, mesh, step_kind, k_inner=2)
        full = {k: c[k] + (c2[k] - c[k]) * (K_INNER - 1) for k in c}
        return full, {"probes": [(L_full, 1), (L_full, 2)]}
    cfg1 = dataclasses.replace(cfg, num_layers=L1)
    cfg2 = dataclasses.replace(cfg, num_layers=L2)
    if shape.kind == "train":
        p11 = _compile_cost(cfg1, shape, mesh, step_kind, k_inner=1)
        p21 = _compile_cost(cfg2, shape, mesh, step_kind, k_inner=1)
        p12 = _compile_cost(cfg1, shape, mesh, step_kind, k_inner=2)
        full = {}
        for k in p11:
            b = (p21[k] - p11[k]) / (L2 - L1)    # per-layer (at K=1)
            inner1 = p12[k] - p11[k]             # one extra K = a + b*L1
            a = inner1 - b * L1
            m = p11[k] - (a + b * L1)            # K-independent overhead
            full[k] = K_INNER * (a + b * L_full) + m
        return full, {"probes": [(L1, 1), (L2, 1), (L1, 2)]}
    c1 = _compile_cost(cfg1, shape, mesh, step_kind)
    c2 = _compile_cost(cfg2, shape, mesh, step_kind)
    full = {}
    for k in c1:
        b = (c2[k] - c1[k]) / (L2 - L1)
        full[k] = c1[k] + b * (L_full - L1)
    return full, {"probes": [L1, L2]}


def refine_memory(cfg, shape, mesh, step_kind, full_cost):
    """Flash-adjusted memory term for train/prefill cells.

    The probe path materializes S^2 score buffers that the production
    blockwise-flash path keeps in VMEM. Extract the S^2 bytes component
    empirically (probes at S, S/2, S/4; exact quadratic fit) and replace
    it with the flash HBM floor: K/V re-read once per Q block,
    c_flash = B_local * 2(K,V) * width * 2B / q_block per layer, with a
    3x factor on train for the flash backward re-reads.
    """
    import dataclasses
    p = _probe_period(cfg)
    cfgp = dataclasses.replace(cfg, num_layers=p)
    ss = [shape.seq_len // 4, shape.seq_len // 2, shape.seq_len]
    ts = []
    for s in ss:
        shp = dataclasses.replace(shape, seq_len=s)
        c = _compile_cost(cfgp, shp, mesh, step_kind,
                          k_inner=1 if shape.kind == "train" else None)
        ts.append(c["bytes"])
    x1, x2, x3 = ss
    t1, t2, t3 = ts
    slope12 = (t2 - t1) / (x2 - x1)
    slope13 = (t3 - t1) / (x3 - x1)
    c_quad = (slope13 - slope12) / (x3 - x2)

    # analytic flash S^2 coefficient (per probe scope: p layers, K=1)
    from repro.runtime.flags import feature
    model_size = mesh.shape.get("model", 1)
    data_size = (mesh.shape.get("data", 1)
                 * mesh.shape.get("pod", 1))
    if shape.kind == "train":
        from repro.launch.specs import K_INNER
        b_local = max(shape.global_batch // K_INNER // data_size, 1)
    else:
        b_local = max(shape.global_batch // data_size, 1)
    if feature("gqa_flat") and cfg.num_heads % model_size == 0:
        width = (cfg.num_heads // model_size) * cfg.resolved_head_dim
    else:  # grouped path: Kv replicated when Kv < model axis
        kv_local = (cfg.num_kv_heads // model_size
                    if cfg.num_kv_heads % model_size == 0
                    else cfg.num_kv_heads)
        width = kv_local * cfg.resolved_head_dim
    n_attn = sum(1 for k, _ in layer_specs_probe(cfgp) if k != "mamba")
    bwd = 3.0 if shape.kind == "train" else 1.0
    q_block = 512
    c_flash = n_attn * b_local * 2 * width * 2 * bwd / q_block

    scale = (cfg.num_layers / p) * (K_INNER if shape.kind == "train" else 1)
    s2 = shape.seq_len ** 2
    adjusted = full_cost["bytes"] - max(c_quad - c_flash, 0.0) * s2 * scale
    return {
        "bytes_flash_adjusted": adjusted,
        "c_quad_probe": c_quad,
        "c_flash_analytic": c_flash,
        "probe_seqs": ss,
    }


def layer_specs_probe(cfg):
    from repro.models.transformer import layer_specs
    return layer_specs(cfg)


def dryrun(arch: str, shape_name: str, *, multi_pod: bool = False,
           step_kind: str = "meta", donate: bool = True,
           refine: bool = False):
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    reason = skip_reason(cfg, shape)
    result = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
              "step": step_kind if shape.kind == "train" else shape.kind,
              "mesh": "2x16x16" if multi_pod else "16x16"}
    if reason:
        result["status"] = "SKIP"
        result["reason"] = reason
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    with mesh_context(mesh):
        step, args = build_step_and_args(cfg, shape, mesh, step_kind)
        if not donate:
            donate_argnums = ()
        elif shape.kind == "train":
            donate_argnums = (0,)  # phi donated to new phi
        elif shape.kind == "decode":
            donate_argnums = (1,)  # cache donated to new cache
        else:
            donate_argnums = ()
        jitted = jax.jit(step, donate_argnums=donate_argnums)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    try:
        mem = compiled.memory_analysis()
        result["memory"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:  # pragma: no cover
        result["memory"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        result["cost"] = {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float)) and
                          k in ("flops", "bytes accessed",
                                "bytes accessed output", "optimal_seconds")}
    except Exception as e:  # pragma: no cover
        result["cost"] = {"error": str(e)}

    hlo = compiled.as_text()
    coll_bytes, coll_counts = parse_collective_bytes(hlo)
    result["collective_bytes_scanbody"] = coll_bytes
    result["collective_counts_scanbody"] = coll_counts
    result["hlo_lines"] = hlo.count("\n")

    # --- exact per-step costs via unrolled probe extrapolation ---
    # (cost_analysis counts while-loop bodies once; probes unroll)
    try:
        full_cost, probe_meta = probe_costs(cfg, shape, mesh, step_kind)
        result["probe"] = probe_meta
        result["probe_cost"] = full_cost
        flops = full_cost["flops"]
        bytes_acc = full_cost["bytes"]
        coll_bytes = {k: full_cost[f"coll_bytes/{k}"] for k in COLLECTIVES}
        result["collective_bytes"] = coll_bytes
        result["collective_counts"] = {
            k: full_cost[f"coll_count/{k}"] for k in COLLECTIVES}
    except Exception as e:
        result["probe_error"] = f"{type(e).__name__}: {e}"
        flops = result.get("cost", {}).get("flops", 0.0)
        bytes_acc = result.get("cost", {}).get("bytes accessed", 0.0)
        result["collective_bytes"] = coll_bytes
    coll_total = float(sum(coll_bytes.values()))
    links = 4  # 2D/3D torus: ~4 usable ICI links per chip (v5e)
    result["roofline"] = {
        "chips": chips,
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": bytes_acc / HBM_BW,
        "collective_s": coll_total / (links * ICI_BW),
    }
    result["roofline"]["dominant"] = max(
        (("compute_s", result["roofline"]["compute_s"]),
         ("memory_s", result["roofline"]["memory_s"]),
         ("collective_s", result["roofline"]["collective_s"])),
        key=lambda kv: kv[1])[0]

    # useful-FLOPs ratio: MODEL_FLOPS = 6*N_active*D for train (fwd+bwd),
    # 2*N_active*D for inference, per chip.
    n_active = cfg.active_param_count()
    tokens = shape.seq_len * shape.global_batch
    if shape.kind == "decode":
        tokens = shape.global_batch  # one token per sequence
    mult = 6 if shape.kind == "train" else 2
    # the K inner microbatches together consume the global batch once
    model_flops_global = mult * n_active * tokens
    result["model_flops_per_chip"] = model_flops_global / chips
    if flops:
        result["useful_ratio"] = result["model_flops_per_chip"] / flops

    if refine and shape.kind in ("train", "prefill"):
        try:
            ref = refine_memory(cfg, shape, mesh, step_kind,
                                {"bytes": bytes_acc})
            result["refine"] = {k: v for k, v in ref.items()}
            result["roofline"]["memory_s_flash"] = (
                ref["bytes_flash_adjusted"] / HBM_BW)
        except Exception as e:
            result["refine_error"] = f"{type(e).__name__}: {e}"
    result["timing"] = {"lower_s": round(t_lower, 1),
                        "compile_s": round(t_compile, 1)}
    result["status"] = "OK"
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ALL_ARCHS))
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--step", default="meta", choices=["meta", "joint"])
    ap.add_argument("--out", default=None, help="write JSON here")
    ap.add_argument("--opt", default="",
                    help="comma list of perf levers: "
                         "gqa_flat,banded,moe2d,ringkv")
    ap.add_argument("--refine", action="store_true",
                    help="flash-adjusted memory term (extra seq probes)")
    args = ap.parse_args()
    if args.opt:
        from repro.runtime.flags import set_features_from_env_string
        set_features_from_env_string(args.opt)
    res = dryrun(args.arch, args.shape, multi_pod=args.multi_pod,
                 step_kind=args.step, refine=args.refine)
    if args.opt:
        res["opt"] = args.opt
    text = json.dumps(res, indent=2)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    if res["status"] not in ("OK", "SKIP"):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
