"""ShapeDtypeStruct input specs for every (arch x input-shape) pair —
weak-type-correct, shardable, zero allocation."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.configs.shapes import InputShape
from repro.models import build_model
from repro.runtime import sharding as shrules

K_INNER = 4  # TinyReptile inner-stream length per round at mesh scale


def _sds(shape, dtype, mesh=None, spec=None):
    s = jax.ShapeDtypeStruct(shape, dtype)
    if mesh is not None and spec is not None:
        s = jax.ShapeDtypeStruct(shape, dtype,
                                 sharding=NamedSharding(mesh, spec))
    return s


def param_specs(cfg: ArchConfig, mesh):
    """Abstract params with production shardings attached."""
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    shardings = shrules.param_shardings(shapes, mesh)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


def train_batch_specs(cfg: ArchConfig, shape: InputShape, mesh,
                      k_inner: int = K_INNER) -> Dict[str, Any]:
    """Meta-train batch: (K, mb, S) token streams."""
    mb = shape.global_batch // k_inner
    seq = shape.seq_len
    tok_spec = shrules.token_spec(mesh, mb, extra_dims=1, leading=1)
    batch = {}
    text_len = seq
    if cfg.frontend == "vision":
        text_len = seq - cfg.frontend_tokens
        batch["patch_embeds"] = _sds(
            (k_inner, mb, cfg.frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype), mesh,
            shrules.token_spec(mesh, mb, extra_dims=2, leading=1))
    if cfg.family == "audio":
        batch["frames"] = _sds(
            (k_inner, mb, cfg.encoder_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype), mesh,
            shrules.token_spec(mesh, mb, extra_dims=2, leading=1))
    batch["tokens"] = _sds((k_inner, mb, text_len), jnp.int32, mesh, tok_spec)
    batch["labels"] = _sds((k_inner, mb, text_len), jnp.int32, mesh, tok_spec)
    return batch


def prefill_batch_specs(cfg: ArchConfig, shape: InputShape, mesh):
    B, seq = shape.global_batch, shape.seq_len
    tok_spec = shrules.token_spec(mesh, B, extra_dims=1)
    batch = {}
    text_len = seq
    if cfg.frontend == "vision":
        text_len = seq - cfg.frontend_tokens
        batch["patch_embeds"] = _sds((B, cfg.frontend_tokens, cfg.d_model),
                                     jnp.dtype(cfg.dtype), mesh,
                                     shrules.token_spec(mesh, B, extra_dims=2))
    if cfg.family == "audio":
        batch["frames"] = _sds((B, cfg.encoder_tokens, cfg.d_model),
                               jnp.dtype(cfg.dtype), mesh,
                               shrules.token_spec(mesh, B, extra_dims=2))
    batch["tokens"] = _sds((B, text_len), jnp.int32, mesh, tok_spec)
    return batch


def decode_batch_specs(cfg: ArchConfig, shape: InputShape, mesh):
    """Decode step: one new token against a seq_len KV cache."""
    B, seq = shape.global_batch, shape.seq_len
    model = build_model(cfg)
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(B, seq, dtype=jnp.dtype(cfg.dtype)))

    def cache_sharding(path, leaf):
        p = shrules._path_str(path)
        leaf_name = p.rsplit("/", 1)[-1]
        if leaf_name in ("conv", "ssm"):
            base = 4 if leaf_name == "ssm" else 3
            off = len(leaf.shape) - base
            nh = leaf.shape[off + 1] if leaf_name == "ssm" else 0
            spec = shrules.mamba_cache_spec(mesh, leaf_name, len(leaf.shape),
                                            B, nh)
        else:  # attention k/v (self or cross): (..., B, S, Kv, hd)
            spec = shrules.attn_cache_spec(mesh, len(leaf.shape), B,
                                           leaf.shape[len(leaf.shape) - 3])
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))

    cache = jax.tree_util.tree_map_with_path(cache_sharding, cache_shapes)
    return {
        "tokens": _sds((B, 1), jnp.int32, mesh,
                       shrules.token_spec(mesh, B, extra_dims=1)),
        "cache": cache,
        "cache_len": _sds((), jnp.int32, mesh, P()),
    }


def input_specs(cfg: ArchConfig, shape: InputShape, mesh):
    """The full (params, batch) spec pair for the step kind of ``shape``."""
    params = param_specs(cfg, mesh)
    if shape.kind == "train":
        return params, train_batch_specs(cfg, shape, mesh)
    if shape.kind == "prefill":
        return params, prefill_batch_specs(cfg, shape, mesh)
    return params, decode_batch_specs(cfg, shape, mesh)
