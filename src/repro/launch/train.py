"""Training launcher: federated meta-training (TinyReptile rounds) of any
--arch over heterogeneous synthetic LM clients, with checkpointing.

The fleet is persistent (one ``LMClientStream`` per client id).
``--participation`` thins check-ins i.i.d.; ``--availability
diurnal|markov`` replaces that with a structured check-in process over
the fleet (rounds where nobody is available are idle: no step, no
transport). ``--buffer-size K`` makes the server FedBuff-style async:
each round's client delta lands in a buffer that is applied only every
K arrivals, staleness-discounted (1/sqrt(1+tau)) — the launcher-scale
mirror of the round engine's ``BufferedAggregation``.

On this CPU container use --reduced (the full configs are dry-run only):

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --rounds 20 --seq 64 --batch 8 --k-inner 4

``--mesh data --devices N`` shards the fused round over a 1-D data mesh
(batch split across N devices, model GSPMD-sharded by the
repro.runtime.sharding rules); ``--mesh pod`` instead makes every
device ONE federated pod client (repro.core.federated pod-client mode:
inner SGD per pod, one cross-pod all-reduce per round). Both work on
CPU under XLA_FLAGS=--xla_force_host_platform_device_count=N. On a
real TPU pod the same entrypoint runs the full config under
make_production_mesh() with the sharding rules from
repro.runtime.sharding.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import ALL_ARCHS, get_arch
from repro.core.engine import CommChannel, meta_interpolate, streaming_sgd
from repro.core.pipeline import PartialParticipation, single_device_of
from repro.core.pool import (DiurnalAvailability, MarkovAvailability,
                             default_staleness_weight)
from repro.data import LMClientStream
from repro.models import build_model
from repro.optim.schedules import linear_anneal
from repro.runtime.steps import (make_meta_train_step, microbatch,
                                 prefetch_batches)


def fraction_arg(s: str) -> float:
    """argparse type: a fraction in (0, 1] — rejected AT PARSE TIME with
    a clear message instead of failing deep inside schedule planning."""
    try:
        v = float(s)
    except ValueError:
        raise argparse.ArgumentTypeError(f"expected a number, got {s!r}")
    if not 0.0 < v <= 1.0:
        raise argparse.ArgumentTypeError(
            f"must be a fraction in (0, 1], got {v}")
    return v


def positive_int_arg(s: str) -> int:
    try:
        v = int(s)
    except ValueError:
        raise argparse.ArgumentTypeError(f"expected an integer, got {s!r}")
    if v < 1:
        raise argparse.ArgumentTypeError(f"must be >= 1, got {v}")
    return v


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ALL_ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--k-inner", type=int, default=4)
    ap.add_argument("--beta", type=float, default=0.02)
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--pool-size", type=positive_int_arg, default=None,
                    help="size of the persistent client fleet (overrides "
                         "--clients; every client keeps its own data "
                         "stream across check-ins)")
    ap.add_argument("--participation", type=fraction_arg, default=1.0,
                    help="fraction of the client fleet that checks in "
                         "each round (a PartialParticipation schedule "
                         "over the pool); each round's training client "
                         "is drawn among that round's participants; "
                         "must be in (0, 1]")
    ap.add_argument("--availability", default="iid",
                    choices=("iid", "diurnal", "markov"),
                    help="structured check-in process over the fleet "
                         "(diurnal sine / two-state Markov); rounds "
                         "where nobody is available are idle")
    ap.add_argument("--buffer-size", type=positive_int_arg, default=None,
                    help="FedBuff-style async server: apply buffered "
                         "client deltas only every K arrivals, "
                         "staleness-discounted")
    ap.add_argument("--devices", type=positive_int_arg, default=None,
                    help="use the first N jax devices (default: all "
                         "when --mesh is set; CPU runs force host "
                         "devices via XLA_FLAGS="
                         "--xla_force_host_platform_device_count)")
    ap.add_argument("--mesh", default="none",
                    choices=("none", "data", "pod"),
                    help="shard the round across devices: 'data' runs "
                         "the fused cohort step on a 1-D data mesh "
                         "(batch split, GSPMD-sharded model); 'pod' "
                         "treats each device as one federated pod "
                         "client (repro.core.federated pod-client "
                         "mode: inner SGD per pod, one cross-pod "
                         "all-reduce per round); 'none' (default) "
                         "stays single-device")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.availability != "iid" and args.participation < 1.0:
        ap.error("--availability replaces the i.i.d. --participation "
                 "schedule; pass one or the other")
    if args.mesh == "pod" and args.buffer_size:
        ap.error("--mesh pod runs the fused pod-client round; FedBuff "
                 "buffering (--buffer-size) needs the split inner/flush "
                 "step — pass one or the other")
    if args.devices is not None and args.mesh == "none":
        ap.error("--devices only applies with --mesh data|pod")
    return args


def main():
    args = parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    phi = model.init(jax.random.PRNGKey(args.seed))
    start_round = 0
    if args.resume and args.ckpt_dir:
        try:
            phi, start_round, _ = restore_checkpoint(args.ckpt_dir, phi)
            print(f"resumed from round {start_round}")
        except FileNotFoundError:
            pass

    fleet = args.pool_size or args.clients
    clients = [LMClientStream(cfg.vocab_size, cid) for cid in range(fleet)]
    alpha_sched = linear_anneal(args.alpha, args.rounds, floor=args.alpha * 0.1)
    rng = np.random.default_rng(args.seed)

    # device-availability schedule over the persistent fleet: with
    # --participation < 1 only a subset checks in each round (i.i.d.);
    # --availability swaps that for a diurnal/Markov process whose
    # troughs can leave a round with NOBODY available (idle round).
    # The round's training client is drawn among the participants.
    # Transport is billed per non-idle round at the paper's fp32
    # accounting.
    checkin = None
    # bill the full trajectory on resume (the old absolute-round
    # formula), minus any pre-resume idle rounds under --availability
    billed_rounds = start_round
    if args.availability != "iid":
        proc = (DiurnalAvailability(period=24)
                if args.availability == "diurnal" else MarkovAvailability())
        full = np.asarray(proc.availability(rng, 0, args.rounds, fleet),
                          bool)
        billed_rounds = int(full[:start_round].any(axis=1).sum())
        checkin = full[start_round:]
    elif args.participation < 1.0:
        checkin = PartialParticipation(args.participation).plan_schedule(
            rng, start_round, args.rounds, fleet,
            args.k_inner)["participation"]
    channel = CommChannel()
    round_bill = 2 * channel.payload_bytes(phi)     # downlink + uplink

    # --mesh builds the device mesh the round runs on: 'data' shards the
    # batch (GSPMD shards the model via repro.runtime.sharding rules),
    # 'pod' makes every device one federated pod client
    # (repro.core.federated pod-client mode). shardctx.mesh_context is
    # entered for the whole loop so the model's internal constraints
    # resolve at trace time; batch staging below device_puts with the
    # matching NamedSharding instead of a bare single-device put.
    mesh = None
    batch_sharding = None
    if args.mesh != "none":
        from jax.sharding import Mesh, NamedSharding, PartitionSpec
        devs = jax.devices()
        n = args.devices or len(devs)
        if n > len(devs):
            raise SystemExit(f"--devices {n}: only {len(devs)} devices "
                             f"visible (force host devices via XLA_FLAGS)")
        if args.mesh == "data":
            mesh = Mesh(np.array(devs[:n]), ("data",))
            batch_axis = "data"
        else:
            mesh = Mesh(np.array(devs[:n]).reshape(n, 1), ("pod", "data"))
            batch_axis = "pod"
        mb = args.batch // args.k_inner
        if mb % n:
            raise SystemExit(f"--mesh {args.mesh}: the per-step "
                             f"microbatch ({mb} = --batch/--k-inner) "
                             f"must divide over {n} devices")

        def batch_sharding(leaf_ndim):
            return NamedSharding(mesh, PartitionSpec(
                *([None, batch_axis] + [None] * (leaf_ndim - 2))))

        phi = jax.device_put(phi, NamedSharding(mesh, PartitionSpec()))

    from contextlib import ExitStack
    from repro.runtime.shardctx import mesh_context
    stack = ExitStack()
    if mesh is not None:
        stack.enter_context(mesh_context(mesh))

    if args.mesh == "pod":
        from repro.core.federated import make_pod_client_meta_step
        step = jax.jit(make_pod_client_meta_step(model, mesh,
                                                 beta=args.beta,
                                                 alpha=args.alpha),
                       donate_argnums=(0,))
    else:
        step = jax.jit(make_meta_train_step(model, beta=args.beta,
                                            alpha=args.alpha),
                       donate_argnums=(0,))
    # FedBuff mode splits the fused round: the inner stream runs
    # immediately, the server interpolation is deferred to the flush
    # (phi is NOT donated — the delta needs it)
    inner = jax.jit(lambda p, b: streaming_sgd(model.loss_fn, p, b,
                                               args.beta))
    buffer = []                 # (round, delta) pairs awaiting a flush
    flushes = 0

    def flush_buffer(phi, flush_rnd, alpha_t):
        """Apply the buffered deltas, staleness-discounted and
        normalized, as one meta step. Also called to DRAIN the buffer
        before checkpoints and at run end — pending updates must not be
        silently dropped (a resume would otherwise lose up to
        buffer_size - 1 rounds of client work)."""
        taus = jnp.asarray([float(flush_rnd - r) for r, _ in buffer])
        ws = default_staleness_weight(taus)
        ws = ws / ws.sum()
        mean_delta = jax.tree.map(
            lambda *ds: sum(w * d for w, d in zip(ws, ds)),
            *[d for _, d in buffer])
        phi_hat = jax.tree.map(jnp.add, phi, mean_delta)
        buffer.clear()
        return meta_interpolate(phi, phi_hat, alpha_t, use_pallas=False)

    device = single_device_of(phi)      # staging target for the prefetcher

    def make_round_batch(i):
        # TinyReptile serial schema: ONE client per round. Runs on the
        # prefetch thread, strictly in round order, so the seeded rng
        # draws exactly the synchronous sequence while batch building +
        # device staging for round N+1 hide behind the step on round N.
        rnd = start_round + i
        if checkin is None:
            client = clients[int(rng.integers(len(clients)))]
        else:
            avail = np.flatnonzero(checkin[i])
            if len(avail) == 0:
                return rnd, None, float(alpha_sched(rnd)), None
            client = clients[int(avail[rng.integers(len(avail))])]
        raw = client.batch(rng, args.batch, args.seq)
        batch = {}
        if cfg.frontend == "vision":
            batch["patch_embeds"] = np.asarray(
                rng.normal(size=(args.batch, cfg.frontend_tokens,
                                 cfg.d_model)), np.float32)
        if cfg.family == "audio":
            batch["frames"] = np.asarray(
                rng.normal(size=(args.batch, cfg.encoder_tokens,
                                 cfg.d_model)), np.float32)
        batch["tokens"] = raw["tokens"]
        batch["labels"] = raw["labels"]
        batch = microbatch(batch, args.k_inner)
        if batch_sharding is not None:
            # mesh staging: split the microbatch dim across the mesh's
            # batch axis instead of a bare single-device put
            batch = jax.device_put(batch, jax.tree.map(
                lambda a: batch_sharding(np.asarray(a).ndim), batch))
        else:
            batch = jax.device_put(batch, device)
        return rnd, client.zipf_a, float(alpha_sched(rnd)), batch

    staged = prefetch_batches(make_round_batch, args.rounds - start_round)
    for rnd, zipf_a, alpha_t, batch in staged:
        t0 = time.time()
        if batch is None:                   # availability trough: idle
            print(json.dumps({"round": rnd, "idle": True,
                              "alpha": alpha_t}), flush=True)
            continue
        if args.buffer_size:
            phi_hat, losses = inner(phi, batch)
            buffer.append((rnd, jax.tree.map(jnp.subtract, phi_hat, phi)))
            metrics = {"loss": losses.mean(), "inner_first": losses[0],
                       "inner_last": losses[-1]}
            if len(buffer) >= args.buffer_size:
                phi = flush_buffer(phi, rnd, alpha_t)
                flushes += 1
        else:
            phi, metrics = step(phi, batch, jnp.float32(alpha_t))
        billed_rounds += 1
        comm_bytes = billed_rounds * round_bill
        row = {"round": rnd, "client": zipf_a,
               "loss": float(metrics["loss"]),
               "inner_first": float(metrics["inner_first"]),
               "inner_last": float(metrics["inner_last"]),
               "alpha": alpha_t, "comm_mb": round(comm_bytes / 2**20, 2),
               "dt_s": round(time.time() - t0, 3)}
        if args.buffer_size:
            row["buffered"] = len(buffer)
            row["flushes"] = flushes
        print(json.dumps(row), flush=True)
        if args.ckpt_dir and (rnd + 1) % args.ckpt_every == 0:
            if buffer:                      # checkpoints see ALL updates
                phi = flush_buffer(phi, rnd, alpha_t)
                flushes += 1
            save_checkpoint(args.ckpt_dir, phi, rnd + 1,
                            extra={"arch": args.arch})
    if buffer:                              # drain the pending tail
        phi = flush_buffer(phi, buffer[-1][0], float(alpha_sched(
            buffer[-1][0])))
        flushes += 1
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, phi, args.rounds,
                        extra={"arch": args.arch})
    stack.close()


if __name__ == "__main__":
    main()
