"""Training launcher: federated meta-training (TinyReptile rounds) of any
--arch over heterogeneous synthetic LM clients, with checkpointing.

``--strategy reptile|fedavg|fedsgd|transfer|tifed`` switches to the
round engine (repro.core.run_federated) — by default on the paper's
sine workload; ``--arch transformer|mamba2|moe`` swaps in next-token
personalization of the family's reduced config over heterogeneous LM
clients. ``tifed`` runs TIFeD integer-only int8 local training with
native int8 uplink billing. ``--devices N`` (or ``--mesh clients:K``)
shards the client axis over a 1-D mesh; ``--mesh clients:K,model:M``
builds the 2-D (clients, model) mesh — cohort split K ways AND phi's
weight matrices split M ways per the family's ModelPartitioner.
Incompatible flag combos (e.g. ``--strategy transfer --buffer-size``,
``tifed`` with a model-sharded mesh) are rejected at parse time.

The fleet is persistent (one ``LMClientStream`` per client id).
``--participation`` thins check-ins i.i.d.; ``--availability
diurnal|markov`` replaces that with a structured check-in process over
the fleet (rounds where nobody is available are idle: no step, no
transport). ``--buffer-size K`` makes the server FedBuff-style async:
each round's client delta lands in a buffer that is applied only every
K arrivals, staleness-discounted (1/sqrt(1+tau)) — the launcher-scale
mirror of the round engine's ``BufferedAggregation``.

On this CPU container use --reduced (the full configs are dry-run only):

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --rounds 20 --seq 64 --batch 8 --k-inner 4

``--mesh data --devices N`` shards the fused round over a 1-D data mesh
(batch split across N devices, model GSPMD-sharded by the
repro.runtime.sharding rules); ``--mesh pod`` instead makes every
device ONE federated pod client (repro.core.federated pod-client mode:
inner SGD per pod, one cross-pod all-reduce per round). Both work on
CPU under XLA_FLAGS=--xla_force_host_platform_device_count=N. On a
real TPU pod the same entrypoint runs the full config under
make_production_mesh() with the sharding rules from
repro.runtime.sharding.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import ALL_ARCHS, get_arch
from repro.core.engine import CommChannel, meta_interpolate, streaming_sgd
from repro.core.pipeline import PartialParticipation, single_device_of
from repro.core.pool import (DiurnalAvailability, MarkovAvailability,
                             default_staleness_weight)
from repro.data import LMClientStream
from repro.models import build_model
from repro.optim.schedules import linear_anneal
from repro.runtime.sharding import init_distributed
from repro.runtime.steps import (make_meta_train_step, microbatch,
                                 prefetch_batches)


def fraction_arg(s: str) -> float:
    """argparse type: a fraction in (0, 1] — rejected AT PARSE TIME with
    a clear message instead of failing deep inside schedule planning."""
    try:
        v = float(s)
    except ValueError:
        raise argparse.ArgumentTypeError(f"expected a number, got {s!r}")
    if not 0.0 < v <= 1.0:
        raise argparse.ArgumentTypeError(
            f"must be a fraction in (0, 1], got {v}")
    return v


def positive_int_arg(s: str) -> int:
    try:
        v = int(s)
    except ValueError:
        raise argparse.ArgumentTypeError(f"expected an integer, got {s!r}")
    if v < 1:
        raise argparse.ArgumentTypeError(f"must be >= 1, got {v}")
    return v


ENGINE_STRATEGIES = ("reptile", "fedavg", "fedsgd", "transfer", "tifed")

#: engine-path --arch family keywords -> canonical arch configs (run
#: REDUCED there: the engine trains every cohort client per round, so
#: the full configs are far beyond this container); each family also
#: names a registered ModelPartitioner for --mesh clients:K,model:M
ARCH_FAMILIES = {"transformer": "tinyllama-1.1b",
                 "mamba2": "mamba2-130m",
                 "moe": "mixtral-8x22b"}


def mesh_arg(s: str):
    """argparse type for --mesh: the LM launcher keywords
    ('none'|'data'|'pod') pass through; an engine mesh spec
    'clients:K[,model:M]' parses to a {'clients': K[, 'model': M]}
    dict — rejected AT PARSE TIME on malformed axis names/extents."""
    if s in ("none", "data", "pod"):
        return s
    spec = {}
    for part in s.split(","):
        name, sep, extent = part.partition(":")
        if not sep or name not in ("clients", "model") or name in spec:
            raise argparse.ArgumentTypeError(
                f"expected 'none', 'data', 'pod', or "
                f"'clients:K[,model:M]', got {s!r}")
        try:
            v = int(extent)
        except ValueError:
            raise argparse.ArgumentTypeError(
                f"mesh axis extent must be an integer, got {extent!r}")
        if v < 1:
            raise argparse.ArgumentTypeError(
                f"mesh axis extent must be >= 1, got {v}")
        spec[name] = v
    if "clients" not in spec:
        raise argparse.ArgumentTypeError(
            f"an engine mesh spec needs a clients axis: "
            f"'clients:K[,model:M]', got {s!r}")
    return spec


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="tinyreptile",
                    choices=("tinyreptile",) + ENGINE_STRATEGIES,
                    help="'tinyreptile' (default) runs this LM launcher; "
                         "any other choice runs the round engine "
                         "(repro.core.run_federated) on the paper's sine "
                         "workload — 'tifed' is integer-only int8 local "
                         "training with native int8 uplinks")
    ap.add_argument("--arch",
                    choices=list(ALL_ARCHS) + sorted(ARCH_FAMILIES),
                    help="LM architecture. Canonical names "
                         "(tinyllama-1.1b, ...) run the tinyreptile LM "
                         "launcher; the family keywords "
                         "transformer|mamba2|moe ALSO work with engine "
                         "strategies (--strategy reptile|...), which "
                         "then meta-train the reduced config over "
                         "heterogeneous LM clients instead of the paper "
                         "sine MLP")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--k-inner", type=int, default=4)
    ap.add_argument("--beta", type=float, default=0.02)
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--pool-size", type=positive_int_arg, default=None,
                    help="size of the persistent client fleet (overrides "
                         "--clients; every client keeps its own data "
                         "stream across check-ins)")
    ap.add_argument("--pool-sampler", default="reference",
                    choices=("reference", "vectorized"),
                    help="client-identity sampler for --pool-size: "
                         "'reference' keeps one RNG per client on the "
                         "host (bit-for-bit legacy stream); "
                         "'vectorized' derives each check-in from a "
                         "counter array — O(cohort) host work and an "
                         "O(N) int32 footprint, the fleet-scale mode")
    ap.add_argument("--pool-residency", default="device",
                    choices=("device", "host"),
                    help="where --pool-size per-client state lives: "
                         "'device' keeps the full (N,) arrays resident; "
                         "'host' keeps them in host slabs and stages "
                         "only each round's cohort rows")
    ap.add_argument("--participation", type=fraction_arg, default=1.0,
                    help="fraction of the client fleet that checks in "
                         "each round (a PartialParticipation schedule "
                         "over the pool); each round's training client "
                         "is drawn among that round's participants; "
                         "must be in (0, 1]")
    ap.add_argument("--availability", default="iid",
                    choices=("iid", "diurnal", "markov"),
                    help="structured check-in process over the fleet "
                         "(diurnal sine / two-state Markov); rounds "
                         "where nobody is available are idle")
    ap.add_argument("--buffer-size", type=positive_int_arg, default=None,
                    help="FedBuff-style async server: apply buffered "
                         "client deltas only every K arrivals, "
                         "staleness-discounted")
    ap.add_argument("--devices", type=positive_int_arg, default=None,
                    help="use the first N jax devices (default: all "
                         "when --mesh is set; CPU runs force host "
                         "devices via XLA_FLAGS="
                         "--xla_force_host_platform_device_count)")
    ap.add_argument("--mesh", default="none", type=mesh_arg,
                    help="shard the round across devices: 'data' runs "
                         "the fused cohort step on a 1-D data mesh "
                         "(batch split, GSPMD-sharded model); 'pod' "
                         "treats each device as one federated pod "
                         "client (repro.core.federated pod-client "
                         "mode: inner SGD per pod, one cross-pod "
                         "all-reduce per round); 'clients:K[,model:M]' "
                         "runs an engine strategy on a 1-D client mesh "
                         "(K-way cohort split) or a 2-D (clients, "
                         "model) mesh (phi's weight matrices "
                         "additionally split M ways per the family's "
                         "ModelPartitioner); 'none' (default) stays "
                         "single-device")
    ap.add_argument("--coordinator", default=None,
                    help="jax.distributed coordinator address host:port "
                         "for multi-process runs; required with "
                         "--num-processes > 1 (every process passes the "
                         "SAME address) and meaningless without it")
    ap.add_argument("--num-processes", type=positive_int_arg, default=1,
                    help="total process count of a cross-host run; the "
                         "client mesh (--devices) then spans every "
                         "process's devices and each process stages its "
                         "local shard only")
    ap.add_argument("--process-id", type=int, default=0,
                    help="this process's rank in [0, --num-processes)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="snapshot directory: the LM launcher saves phi "
                         "every --ckpt-every rounds; engine strategies "
                         "snapshot the FULL round state (phi, pool "
                         "state, rng, bills) on a background thread and "
                         "resume bit-for-bit via --resume")
    ap.add_argument("--ckpt-every", type=positive_int_arg, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.num_processes > 1 and not args.coordinator:
        ap.error("--num-processes > 1 is a cross-host run; pass the "
                 "shared --coordinator host:port")
    if args.coordinator and args.num_processes == 1:
        ap.error("--coordinator only applies with --num-processes > 1")
    if not 0 <= args.process_id < args.num_processes:
        ap.error(f"--process-id {args.process_id} out of range for "
                 f"--num-processes {args.num_processes}")
    if args.num_processes > 1 and args.strategy not in ENGINE_STRATEGIES:
        ap.error("multi-process runs drive the round engine; pass an "
                 f"engine --strategy ({'|'.join(ENGINE_STRATEGIES)})")
    if args.num_processes > 1:
        # must precede the first jax.devices() call below: after
        # initialize, the device list spans every process in the run
        init_distributed(args.coordinator, args.num_processes,
                         args.process_id)
    if args.resume and not args.ckpt_dir:
        ap.error("--resume restores from --ckpt-dir; pass both")
    if args.availability != "iid" and args.participation < 1.0:
        ap.error("--availability replaces the i.i.d. --participation "
                 "schedule; pass one or the other")
    if args.mesh == "pod" and args.buffer_size:
        ap.error("--mesh pod runs the fused pod-client round; FedBuff "
                 "buffering (--buffer-size) needs the split inner/flush "
                 "step — pass one or the other")
    # incompatible flag combos are rejected HERE, not deep inside the
    # engine (the --participation precedent from PR 4)
    if args.strategy == "tinyreptile":
        if args.arch is None:
            ap.error("--arch is required for the tinyreptile LM launcher "
                     "(engine strategies --strategy "
                     f"{'|'.join(ENGINE_STRATEGIES)} default to the "
                     "paper sine workload instead)")
        if isinstance(args.mesh, dict):
            ap.error("--mesh clients:K[,model:M] drives the round "
                     "engine; pass an engine --strategy "
                     f"({'|'.join(ENGINE_STRATEGIES)})")
        if args.devices is not None and args.mesh == "none":
            ap.error("--devices only applies with --mesh data|pod (or "
                     "with an engine --strategy, where it sizes the "
                     "client mesh)")
        # family keyword -> the canonical config it names
        args.arch = ARCH_FAMILIES.get(args.arch, args.arch)
        return args
    if args.arch is not None and args.arch not in ARCH_FAMILIES:
        ap.error(f"--strategy {args.strategy} meta-trains a reduced LM "
                 f"family (--arch {'|'.join(sorted(ARCH_FAMILIES))}) or, "
                 f"without --arch, the paper sine MLP; the canonical "
                 f"config {args.arch!r} runs the tinyreptile LM launcher")
    if args.arch is not None and args.strategy == "tifed":
        ap.error("--strategy tifed runs TIFeD integer-only training on "
                 "the paper's ReLU sine net; the LM families are fp32 — "
                 "drop --arch")
    if args.mesh in ("data", "pod"):
        ap.error(f"--strategy {args.strategy} shards the client axis "
                 f"via --devices N or --mesh clients:K[,model:M]; "
                 f"--mesh data|pod belongs to the LM launcher")
    if isinstance(args.mesh, dict):
        spec = ",".join(f"{k}:{v}" for k, v in args.mesh.items())
        if args.devices is not None:
            ap.error(f"--mesh {spec} already sizes the client mesh; "
                     f"drop --devices")
        if "model" in args.mesh and args.strategy == "tifed":
            ap.error("--strategy tifed uplinks NATIVE int8 trees whose "
                     "quantization grids need each parameter tensor "
                     "whole on every device; a model-sharded mesh "
                     "splits them — use --mesh clients:K (no model "
                     "axis)")
        need = args.mesh["clients"] * args.mesh.get("model", 1)
        if need > len(jax.devices()):
            ap.error(f"--mesh {spec} needs {need} devices; only "
                     f"{len(jax.devices())} visible (force host devices "
                     f"via XLA_FLAGS)")
    if args.strategy == "transfer" and args.buffer_size:
        ap.error("--strategy transfer uplinks raw client batches "
                 "(uplink_ref='none'); the FedBuff buffer stages "
                 "phi-shaped updates and cannot hold them — drop "
                 "--buffer-size")
    if args.buffer_size and args.pool_size is None:
        ap.error("--buffer-size (FedBuff) needs persistent clients to "
                 "be stale against on the engine path: pass "
                 "--pool-size N too")
    if args.availability != "iid" and args.pool_size is None:
        ap.error("--availability needs a persistent fleet on the engine "
                 "path: pass --pool-size N")
    if args.pool_size is None and (args.pool_sampler != "reference"
                                   or args.pool_residency != "device"):
        ap.error("--pool-sampler/--pool-residency configure the "
                 "persistent fleet: pass --pool-size N")
    if args.pool_size is not None and args.pool_size < args.clients:
        ap.error(f"--pool-size {args.pool_size} cannot seat a cohort of "
                 f"--clients {args.clients} (identities are unique "
                 f"within a round)")
    if args.devices is not None and args.devices > len(jax.devices()):
        ap.error(f"--devices {args.devices}: only {len(jax.devices())} "
                 f"devices visible (force host devices via XLA_FLAGS)")
    return args


def run_engine_strategy(args):
    """--strategy reptile|fedavg|fedsgd|transfer|tifed: one round-engine
    run (repro.core.run_federated) on the paper's sine workload, with
    the launcher's fleet flags mapped onto the engine's plugins
    (--pool-size -> ClientPool, --participation/--availability ->
    SamplingPolicy, --buffer-size -> BufferedAggregation, --devices or
    --mesh clients:K[,model:M] -> client / client-model mesh). tifed
    runs integer-only local training and bills its native int8 uplinks;
    everything else is the fp32 engine path. --arch
    transformer|mamba2|moe swaps the sine workload for next-token
    personalization of the family's REDUCED config over heterogeneous
    LM clients (LmTaskDistribution); with a model axis on the mesh, phi
    is sharded per the family's registered ModelPartitioner.
    --ckpt-dir arms the engine's round-state snapshotter (background
    writer, every --ckpt-every rounds) and --resume continues a
    preempted run bit-for-bit — including past the original --rounds
    horizon. Prints one summary JSON row."""
    import functools

    from repro.configs.paper_models import SINE_MLP
    from repro.core import (BufferedAggregation, ClientPool, run_federated)
    from repro.core.strategies import (FedAvgStrategy, FedSGDStrategy,
                                       ReptileStrategy, TifedStrategy,
                                       TransferStrategy)
    from repro.data import LmTaskDistribution, SineTasks, lm_loss
    from repro.models.paper_nets import (init_paper_model, paper_model_loss,
                                         relu_mlp_loss)
    from repro.runtime.sharding import client_model_mesh, partitioner_for

    if args.arch is not None:
        # family keyword -> the canonical config, reduced for the
        # engine's every-client-every-round cost profile
        cfg = get_arch(ARCH_FAMILIES[args.arch]).reduced()
        model = build_model(cfg)
        loss = lm_loss(model)
        dist = LmTaskDistribution(cfg.vocab_size, args.seq)
        params = model.init(jax.random.PRNGKey(args.seed))
        support = args.batch
        eval_kwargs = dict(num_tasks=2, support=4, k_steps=4, lr=0.01,
                           query=8)
    else:
        loss = functools.partial(paper_model_loss, SINE_MLP)
        dist = SineTasks()
        params = init_paper_model(SINE_MLP, jax.random.PRNGKey(args.seed))
        support = 32
        # eval finetune rate: the tanh paper net takes 0.02; tifed's
        # ReLU net diverges there at k_steps 16 — 0.005 is safe
        eval_kwargs = dict(num_tasks=5, support=10, k_steps=16,
                           lr=0.005 if args.strategy == "tifed" else 0.02,
                           query=20)
    mesh = args.devices
    partitioner = None
    if isinstance(args.mesh, dict):
        if "model" in args.mesh:
            mesh = client_model_mesh(args.mesh["clients"],
                                     args.mesh["model"])
            # the family's registered partitioner; the sine MLP takes
            # the default matrix-sharding rules
            partitioner = partitioner_for(args.arch or "default")
        else:
            mesh = args.mesh["clients"]     # 1-D client mesh
    strategy = {
        "reptile": lambda: ReptileStrategy(loss, epochs=8),
        "fedavg": lambda: FedAvgStrategy(loss, epochs=8),
        "fedsgd": lambda: FedSGDStrategy(loss),
        "transfer": lambda: TransferStrategy(loss),
        "tifed": lambda: TifedStrategy(relu_mlp_loss, epochs=8),
    }[args.strategy]()
    channel = (CommChannel("int8", quantize=False)
               if args.strategy == "tifed" else CommChannel())
    pool = (ClientPool(dist, args.pool_size, seed=args.seed,
                       sampler=args.pool_sampler,
                       residency=args.pool_residency)
            if args.pool_size else None)
    if args.availability == "diurnal":
        sampling = DiurnalAvailability(period=24,
                                       sampler=args.pool_sampler)
    elif args.availability == "markov":
        sampling = MarkovAvailability(sampler=args.pool_sampler)
    elif args.participation < 1.0:
        sampling = PartialParticipation(args.participation,
                                        sampler=args.pool_sampler)
    else:
        sampling = None
    buffered = (BufferedAggregation(args.buffer_size)
                if args.buffer_size else None)
    t0 = time.time()
    out = run_federated(
        params, dist, strategy, rounds=args.rounds,
        clients_per_round=args.clients, alpha=args.alpha, beta=args.beta,
        support=support, seed=args.seed, eval_every=args.rounds,
        eval_kwargs=eval_kwargs,
        channel=channel, sampling=sampling, pool=pool, buffered=buffered,
        mesh=mesh, partitioner=partitioner, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, resume=args.resume)
    jax.block_until_ready(jax.tree.leaves(out["params"])[0])
    row = {"strategy": args.strategy, "rounds": args.rounds,
           "clients": args.clients, "dt_s": round(time.time() - t0, 3)}
    if args.arch is not None:
        row["arch"] = args.arch
    if isinstance(args.mesh, dict):
        row["mesh"] = ",".join(f"{k}:{v}" for k, v in args.mesh.items())
    if out["history"]:
        row["query_loss"] = round(float(out["history"][-1]["query_loss"]),
                                  4)
    if "comm_bytes" in out:
        row["comm_mb"] = round(out["comm_bytes"] / 2 ** 20, 3)
    print(json.dumps(row), flush=True)


def main():
    args = parse_args()
    if args.strategy != "tinyreptile":
        return run_engine_strategy(args)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    phi = model.init(jax.random.PRNGKey(args.seed))
    start_round = 0
    if args.resume and args.ckpt_dir:
        try:
            phi, start_round, _ = restore_checkpoint(args.ckpt_dir, phi)
            print(f"resumed from round {start_round}")
        except FileNotFoundError:
            pass

    fleet = args.pool_size or args.clients
    clients = [LMClientStream(cfg.vocab_size, cid) for cid in range(fleet)]
    alpha_sched = linear_anneal(args.alpha, args.rounds, floor=args.alpha * 0.1)
    rng = np.random.default_rng(args.seed)

    # device-availability schedule over the persistent fleet: with
    # --participation < 1 only a subset checks in each round (i.i.d.);
    # --availability swaps that for a diurnal/Markov process whose
    # troughs can leave a round with NOBODY available (idle round).
    # The round's training client is drawn among the participants.
    # Transport is billed per non-idle round at the paper's fp32
    # accounting.
    checkin = None
    # bill the full trajectory on resume (the old absolute-round
    # formula), minus any pre-resume idle rounds under --availability
    billed_rounds = start_round
    if args.availability != "iid":
        proc = (DiurnalAvailability(period=24)
                if args.availability == "diurnal" else MarkovAvailability())
        full = np.asarray(proc.availability(rng, 0, args.rounds, fleet),
                          bool)
        billed_rounds = int(full[:start_round].any(axis=1).sum())
        checkin = full[start_round:]
    elif args.participation < 1.0:
        checkin = PartialParticipation(args.participation).plan_schedule(
            rng, start_round, args.rounds, fleet,
            args.k_inner)["participation"]
    channel = CommChannel()
    round_bill = 2 * channel.payload_bytes(phi)     # downlink + uplink

    # --mesh builds the device mesh the round runs on: 'data' shards the
    # batch (GSPMD shards the model via repro.runtime.sharding rules),
    # 'pod' makes every device one federated pod client
    # (repro.core.federated pod-client mode). shardctx.mesh_context is
    # entered for the whole loop so the model's internal constraints
    # resolve at trace time; batch staging below device_puts with the
    # matching NamedSharding instead of a bare single-device put.
    mesh = None
    batch_sharding = None
    if args.mesh != "none":
        from jax.sharding import Mesh, NamedSharding, PartitionSpec
        devs = jax.devices()
        n = args.devices or len(devs)
        if n > len(devs):
            raise SystemExit(f"--devices {n}: only {len(devs)} devices "
                             f"visible (force host devices via XLA_FLAGS)")
        if args.mesh == "data":
            mesh = Mesh(np.array(devs[:n]), ("data",))
            batch_axis = "data"
        else:
            mesh = Mesh(np.array(devs[:n]).reshape(n, 1), ("pod", "data"))
            batch_axis = "pod"
        mb = args.batch // args.k_inner
        if mb % n:
            raise SystemExit(f"--mesh {args.mesh}: the per-step "
                             f"microbatch ({mb} = --batch/--k-inner) "
                             f"must divide over {n} devices")

        def batch_sharding(leaf_ndim):
            return NamedSharding(mesh, PartitionSpec(
                *([None, batch_axis] + [None] * (leaf_ndim - 2))))

        phi = jax.device_put(phi, NamedSharding(mesh, PartitionSpec()))

    from contextlib import ExitStack
    from repro.runtime.shardctx import mesh_context
    stack = ExitStack()
    if mesh is not None:
        stack.enter_context(mesh_context(mesh))

    if args.mesh == "pod":
        from repro.core.federated import make_pod_client_meta_step
        step = jax.jit(make_pod_client_meta_step(model, mesh,
                                                 beta=args.beta,
                                                 alpha=args.alpha),
                       donate_argnums=(0,))
    else:
        step = jax.jit(make_meta_train_step(model, beta=args.beta,
                                            alpha=args.alpha),
                       donate_argnums=(0,))
    # FedBuff mode splits the fused round: the inner stream runs
    # immediately, the server interpolation is deferred to the flush
    # (phi is NOT donated — the delta needs it)
    inner = jax.jit(lambda p, b: streaming_sgd(model.loss_fn, p, b,
                                               args.beta))
    buffer = []                 # (round, delta) pairs awaiting a flush
    flushes = 0

    def flush_buffer(phi, flush_rnd, alpha_t):
        """Apply the buffered deltas, staleness-discounted and
        normalized, as one meta step. Also called to DRAIN the buffer
        before checkpoints and at run end — pending updates must not be
        silently dropped (a resume would otherwise lose up to
        buffer_size - 1 rounds of client work)."""
        taus = jnp.asarray([float(flush_rnd - r) for r, _ in buffer])
        ws = default_staleness_weight(taus)
        ws = ws / ws.sum()
        mean_delta = jax.tree.map(
            lambda *ds: sum(w * d for w, d in zip(ws, ds)),
            *[d for _, d in buffer])
        phi_hat = jax.tree.map(jnp.add, phi, mean_delta)
        buffer.clear()
        return meta_interpolate(phi, phi_hat, alpha_t, use_pallas=False)

    device = single_device_of(phi)      # staging target for the prefetcher

    def make_round_batch(i):
        # TinyReptile serial schema: ONE client per round. Runs on the
        # prefetch thread, strictly in round order, so the seeded rng
        # draws exactly the synchronous sequence while batch building +
        # device staging for round N+1 hide behind the step on round N.
        rnd = start_round + i
        if checkin is None:
            client = clients[int(rng.integers(len(clients)))]
        else:
            avail = np.flatnonzero(checkin[i])
            if len(avail) == 0:
                return rnd, None, float(alpha_sched(rnd)), None
            client = clients[int(avail[rng.integers(len(avail))])]
        raw = client.batch(rng, args.batch, args.seq)
        batch = {}
        if cfg.frontend == "vision":
            batch["patch_embeds"] = np.asarray(
                rng.normal(size=(args.batch, cfg.frontend_tokens,
                                 cfg.d_model)), np.float32)
        if cfg.family == "audio":
            batch["frames"] = np.asarray(
                rng.normal(size=(args.batch, cfg.encoder_tokens,
                                 cfg.d_model)), np.float32)
        batch["tokens"] = raw["tokens"]
        batch["labels"] = raw["labels"]
        batch = microbatch(batch, args.k_inner)
        if batch_sharding is not None:
            # mesh staging: split the microbatch dim across the mesh's
            # batch axis instead of a bare single-device put
            batch = jax.device_put(batch, jax.tree.map(
                lambda a: batch_sharding(np.asarray(a).ndim), batch))
        else:
            batch = jax.device_put(batch, device)
        return rnd, client.zipf_a, float(alpha_sched(rnd)), batch

    staged = prefetch_batches(make_round_batch, args.rounds - start_round)
    for rnd, zipf_a, alpha_t, batch in staged:
        t0 = time.time()
        if batch is None:                   # availability trough: idle
            print(json.dumps({"round": rnd, "idle": True,
                              "alpha": alpha_t}), flush=True)
            continue
        if args.buffer_size:
            phi_hat, losses = inner(phi, batch)
            buffer.append((rnd, jax.tree.map(jnp.subtract, phi_hat, phi)))
            metrics = {"loss": losses.mean(), "inner_first": losses[0],
                       "inner_last": losses[-1]}
            if len(buffer) >= args.buffer_size:
                phi = flush_buffer(phi, rnd, alpha_t)
                flushes += 1
        else:
            phi, metrics = step(phi, batch, jnp.float32(alpha_t))
        billed_rounds += 1
        comm_bytes = billed_rounds * round_bill
        row = {"round": rnd, "client": zipf_a,
               "loss": float(metrics["loss"]),
               "inner_first": float(metrics["inner_first"]),
               "inner_last": float(metrics["inner_last"]),
               "alpha": alpha_t, "comm_mb": round(comm_bytes / 2**20, 2),
               "dt_s": round(time.time() - t0, 3)}
        if args.buffer_size:
            row["buffered"] = len(buffer)
            row["flushes"] = flushes
        print(json.dumps(row), flush=True)
        if args.ckpt_dir and (rnd + 1) % args.ckpt_every == 0:
            if buffer:                      # checkpoints see ALL updates
                phi = flush_buffer(phi, rnd, alpha_t)
                flushes += 1
            save_checkpoint(args.ckpt_dir, phi, rnd + 1,
                            extra={"arch": args.arch})
    if buffer:                              # drain the pending tail
        phi = flush_buffer(phi, buffer[-1][0], float(alpha_sched(
            buffer[-1][0])))
        flushes += 1
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, phi, args.rounds,
                        extra={"arch": args.arch})
    stack.close()


if __name__ == "__main__":
    main()
