"""Training launcher: federated meta-training (TinyReptile rounds) of any
--arch over heterogeneous synthetic LM clients, with checkpointing.

On this CPU container use --reduced (the full configs are dry-run only):

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --rounds 20 --seq 64 --batch 8 --k-inner 4

On a real TPU pod the same entrypoint runs the full config under
make_production_mesh() with the sharding rules from repro.runtime.sharding.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import ALL_ARCHS, get_arch
from repro.core.pipeline import single_device_of
from repro.data import LMClientStream
from repro.models import build_model
from repro.optim.schedules import linear_anneal
from repro.runtime.steps import (make_meta_train_step, microbatch,
                                 prefetch_batches)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ALL_ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--k-inner", type=int, default=4)
    ap.add_argument("--beta", type=float, default=0.02)
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    phi = model.init(jax.random.PRNGKey(args.seed))
    start_round = 0
    if args.resume and args.ckpt_dir:
        try:
            phi, start_round, _ = restore_checkpoint(args.ckpt_dir, phi)
            print(f"resumed from round {start_round}")
        except FileNotFoundError:
            pass

    clients = [LMClientStream(cfg.vocab_size, cid)
               for cid in range(args.clients)]
    alpha_sched = linear_anneal(args.alpha, args.rounds, floor=args.alpha * 0.1)
    rng = np.random.default_rng(args.seed)

    step = jax.jit(make_meta_train_step(model, beta=args.beta,
                                        alpha=args.alpha),
                   donate_argnums=(0,))
    device = single_device_of(phi)      # staging target for the prefetcher

    def make_round_batch(i):
        # TinyReptile serial schema: ONE client per round. Runs on the
        # prefetch thread, strictly in round order, so the seeded rng
        # draws exactly the synchronous sequence while batch building +
        # device staging for round N+1 hide behind the step on round N.
        rnd = start_round + i
        client = clients[int(rng.integers(len(clients)))]
        raw = client.batch(rng, args.batch, args.seq)
        batch = {}
        if cfg.frontend == "vision":
            batch["patch_embeds"] = np.asarray(
                rng.normal(size=(args.batch, cfg.frontend_tokens,
                                 cfg.d_model)), np.float32)
        if cfg.family == "audio":
            batch["frames"] = np.asarray(
                rng.normal(size=(args.batch, cfg.encoder_tokens,
                                 cfg.d_model)), np.float32)
        batch["tokens"] = raw["tokens"]
        batch["labels"] = raw["labels"]
        batch = jax.device_put(microbatch(batch, args.k_inner), device)
        return rnd, client.zipf_a, float(alpha_sched(rnd)), batch

    staged = prefetch_batches(make_round_batch, args.rounds - start_round)
    for rnd, zipf_a, alpha_t, batch in staged:
        t0 = time.time()
        phi, metrics = step(phi, batch, jnp.float32(alpha_t))
        print(json.dumps({
            "round": rnd, "client": zipf_a,
            "loss": float(metrics["loss"]),
            "inner_first": float(metrics["inner_first"]),
            "inner_last": float(metrics["inner_last"]),
            "alpha": alpha_t, "dt_s": round(time.time() - t0, 3)}),
            flush=True)
        if args.ckpt_dir and (rnd + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, phi, rnd + 1,
                            extra={"arch": args.arch})
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, phi, args.rounds,
                        extra={"arch": args.arch})


if __name__ == "__main__":
    main()
