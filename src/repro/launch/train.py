"""Training launcher: federated meta-training (TinyReptile rounds) of any
--arch over heterogeneous synthetic LM clients, with checkpointing.

On this CPU container use --reduced (the full configs are dry-run only):

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --reduced --rounds 20 --seq 64 --batch 8 --k-inner 4

On a real TPU pod the same entrypoint runs the full config under
make_production_mesh() with the sharding rules from repro.runtime.sharding.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import ALL_ARCHS, get_arch
from repro.core.engine import CommChannel
from repro.core.pipeline import PartialParticipation, single_device_of
from repro.data import LMClientStream
from repro.models import build_model
from repro.optim.schedules import linear_anneal
from repro.runtime.steps import (make_meta_train_step, microbatch,
                                 prefetch_batches)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ALL_ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--k-inner", type=int, default=4)
    ap.add_argument("--beta", type=float, default=0.02)
    ap.add_argument("--alpha", type=float, default=1.0)
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--participation", type=float, default=1.0,
                    help="fraction of the client fleet that checks in "
                         "each round (a PartialParticipation schedule "
                         "over the pool); each round's training client "
                         "is drawn among that round's participants")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    phi = model.init(jax.random.PRNGKey(args.seed))
    start_round = 0
    if args.resume and args.ckpt_dir:
        try:
            phi, start_round, _ = restore_checkpoint(args.ckpt_dir, phi)
            print(f"resumed from round {start_round}")
        except FileNotFoundError:
            pass

    clients = [LMClientStream(cfg.vocab_size, cid)
               for cid in range(args.clients)]
    alpha_sched = linear_anneal(args.alpha, args.rounds, floor=args.alpha * 0.1)
    rng = np.random.default_rng(args.seed)

    # device-availability schedule: with --participation < 1 only a
    # fleet subset checks in each round; the round's client is drawn
    # among the participants (the engine's ClientSchedule planning,
    # reused at launcher scale). Transport is billed per round at the
    # paper's fp32 accounting.
    checkin = None
    if not 0.0 < args.participation <= 1.0:
        raise SystemExit(f"--participation must be in (0, 1], got "
                         f"{args.participation}")
    if args.participation < 1.0:
        checkin = PartialParticipation(args.participation).plan_schedule(
            rng, start_round, args.rounds, args.clients,
            args.k_inner)["participation"]
    channel = CommChannel()
    round_bill = 2 * channel.payload_bytes(phi)     # downlink + uplink

    step = jax.jit(make_meta_train_step(model, beta=args.beta,
                                        alpha=args.alpha),
                   donate_argnums=(0,))
    device = single_device_of(phi)      # staging target for the prefetcher

    def make_round_batch(i):
        # TinyReptile serial schema: ONE client per round. Runs on the
        # prefetch thread, strictly in round order, so the seeded rng
        # draws exactly the synchronous sequence while batch building +
        # device staging for round N+1 hide behind the step on round N.
        rnd = start_round + i
        if checkin is None:
            client = clients[int(rng.integers(len(clients)))]
        else:
            avail = np.flatnonzero(checkin[i])
            client = clients[int(avail[rng.integers(len(avail))])]
        raw = client.batch(rng, args.batch, args.seq)
        batch = {}
        if cfg.frontend == "vision":
            batch["patch_embeds"] = np.asarray(
                rng.normal(size=(args.batch, cfg.frontend_tokens,
                                 cfg.d_model)), np.float32)
        if cfg.family == "audio":
            batch["frames"] = np.asarray(
                rng.normal(size=(args.batch, cfg.encoder_tokens,
                                 cfg.d_model)), np.float32)
        batch["tokens"] = raw["tokens"]
        batch["labels"] = raw["labels"]
        batch = jax.device_put(microbatch(batch, args.k_inner), device)
        return rnd, client.zipf_a, float(alpha_sched(rnd)), batch

    staged = prefetch_batches(make_round_batch, args.rounds - start_round)
    for rnd, zipf_a, alpha_t, batch in staged:
        t0 = time.time()
        phi, metrics = step(phi, batch, jnp.float32(alpha_t))
        # derived from the ABSOLUTE round so resumed runs keep billing
        # the full trajectory, not just the post-restore tail
        comm_bytes = (rnd + 1) * round_bill
        print(json.dumps({
            "round": rnd, "client": zipf_a,
            "loss": float(metrics["loss"]),
            "inner_first": float(metrics["inner_first"]),
            "inner_last": float(metrics["inner_last"]),
            "alpha": alpha_t, "comm_mb": round(comm_bytes / 2**20, 2),
            "dt_s": round(time.time() - t0, 3)}),
            flush=True)
        if args.ckpt_dir and (rnd + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, phi, rnd + 1,
                            extra={"arch": args.arch})
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, phi, args.rounds,
                        extra={"arch": args.arch})


if __name__ == "__main__":
    main()
