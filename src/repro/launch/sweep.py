"""Dry-run sweep driver: every (arch x shape x mesh) cell as an isolated
subprocess (fresh XLA device state, crash containment). Results land in
results/dryrun/<arch>__<shape>__<mesh>.json; existing results are skipped
unless --force.

Usage: PYTHONPATH=src python -m repro.launch.sweep [--multi-pod-only] ...
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = [
    "llama4-maverick-400b-a17b", "mamba2-130m", "mixtral-8x22b",
    "whisper-tiny", "tinyllama-1.1b", "glm4-9b", "zamba2-1.2b",
    "minicpm-2b", "paligemma-3b", "starcoder2-15b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def run_cell(arch, shape, multi_pod, outdir, timeout=3000):
    mesh = "2x16x16" if multi_pod else "16x16"
    out = os.path.join(outdir, f"{arch}__{shape}__{mesh}.json")
    if os.path.exists(out):
        return "cached"
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out", out]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    t0 = time.time()
    try:
        r = subprocess.run(cmd, capture_output=True, text=True,
                           timeout=timeout, env=env, cwd=os.getcwd())
    except subprocess.TimeoutExpired:
        with open(out, "w") as f:
            json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                       "status": "TIMEOUT", "timeout_s": timeout}, f)
        return "TIMEOUT"
    if r.returncode != 0:
        with open(out, "w") as f:
            json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                       "status": "ERROR",
                       "stderr": r.stderr[-4000:]}, f, indent=1)
        return "ERROR"
    with open(out) as f:
        return json.load(f).get("status", "?") + f" ({time.time()-t0:.0f}s)"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--archs", default=",".join(ARCHS))
    ap.add_argument("--shapes", default=",".join(SHAPES))
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    meshes = [m == "multi" for m in args.meshes.split(",")]
    total = ok = 0
    for multi in meshes:
        for arch in args.archs.split(","):
            for shape in args.shapes.split(","):
                total += 1
                status = run_cell(arch, shape, multi, args.outdir,
                                  args.timeout)
                mesh = "2x16x16" if multi else "16x16"
                print(f"[{total}] {arch:28s} {shape:12s} {mesh:8s} {status}",
                      flush=True)
                if "OK" in status or "SKIP" in status or status == "cached":
                    ok += 1
    print(f"done: {ok}/{total} ok")


if __name__ == "__main__":
    main()
