"""Serving launchers.

Two modes, picked by ``--mode`` with parse-time flag validation (flags
belonging to the other mode are rejected before any JAX work starts):

- ``decode`` (default, backward compatible): batched autoregressive LM
  decoding with a KV cache — fills a fixed batch of slots with prompts,
  prefills via teacher-forced decode steps, then decodes greedily.

      PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
          --reduced --requests 6 --batch 2 --max-new 8

- ``adapt``: the TinyReptile deployment loop — a continuous-batching
  `serving.AdaptationServer` over the sine-MLP meta-init sustains a
  ragged stream of client-adaptation requests (fp32 online-SGD or
  int8 TIFeD epochs) and reports requests/sec + latency percentiles.

      PYTHONPATH=src python -m repro.launch.serve --mode adapt \
          --strategy fp32 --requests 512 --slots 64 --k-max 10

  ``--ckpt-dir`` serves a `run_federated(ckpt_dir=...)` snapshot's phi
  (via `checkpoint.load_params`) instead of a fresh seeded init.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np


# flags that only make sense for one mode: (flag, argparse dest, default)
_DECODE_ONLY = (("--arch", "arch", None), ("--reduced", "reduced", False),
                ("--batch", "batch", 2), ("--prompt-len", "prompt_len", 8),
                ("--max-new", "max_new", 8), ("--cache-len", "cache_len", 64))
_ADAPT_ONLY = (("--strategy", "strategy", "fp32"), ("--slots", "slots", 64),
               ("--support", "support", 10), ("--k-max", "k_max", 10),
               ("--query", "query", 20),
               ("--steps-per-tick", "steps_per_tick", 5),
               ("--ckpt-dir", "ckpt_dir", None))


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("decode", "adapt"), default="decode")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    # decode-mode flags
    ap.add_argument("--arch", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=64)
    # adapt-mode flags
    ap.add_argument("--strategy", choices=("fp32", "tifed"), default="fp32")
    ap.add_argument("--slots", type=int, default=64)
    ap.add_argument("--support", type=int, default=10)
    ap.add_argument("--k-max", type=int, default=10)
    ap.add_argument("--query", type=int, default=20)
    ap.add_argument("--steps-per-tick", type=int, default=5)
    ap.add_argument("--ckpt-dir", default=None)
    return ap


def parse_args(argv=None) -> argparse.Namespace:
    """Parse + cross-validate BEFORE touching JAX: a decode flag on an
    adapt run (or vice versa) is a config mistake, not a silent
    default."""
    ap = build_parser()
    args = ap.parse_args(argv)
    wrong = _ADAPT_ONLY if args.mode == "decode" else _DECODE_ONLY
    for flag, dest, default in wrong:
        if getattr(args, dest) != default:
            ap.error(f"{flag} only applies with --mode "
                     f"{'adapt' if args.mode == 'decode' else 'decode'}")
    if args.requests < 1:
        ap.error(f"--requests must be >= 1, got {args.requests}")
    if args.mode == "decode":
        from repro.configs import ALL_ARCHS
        if args.arch is None:
            ap.error("--arch is required for --mode decode")
        if args.arch not in ALL_ARCHS:
            ap.error(f"--arch {args.arch!r} not in "
                     f"{sorted(ALL_ARCHS)}")
    else:
        if args.slots < 1:
            ap.error(f"--slots must be >= 1, got {args.slots}")
        if args.k_max < 1:
            ap.error(f"--k-max must be >= 1, got {args.k_max}")
        if args.steps_per_tick < 1:
            ap.error(f"--steps-per-tick must be >= 1, got "
                     f"{args.steps_per_tick}")
        if args.strategy == "fp32" and args.k_max > args.support:
            ap.error(f"--k-max {args.k_max} online steps need --support "
                     f">= k-max, got {args.support}")
        if args.strategy == "tifed" and args.support & (args.support - 1):
            ap.error(f"--support must be a power of two for tifed "
                     f"(bit-shift batch mean), got {args.support}")
    return args


def run_decode(args):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.models import build_model

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    decode = jax.jit(model.decode_fn, donate_argnums=())

    rng = np.random.default_rng(args.seed)
    queue = [rng.integers(0, cfg.vocab_size, size=args.prompt_len)
             for _ in range(args.requests)]
    done = []
    B = args.batch

    # NOTE: per-slot cache_len requires the batched cache variant; this
    # loop advances all slots in lockstep (same prompt length) — the
    # standard static-batching baseline. Continuous batching with
    # per-slot offsets is what --mode adapt does for the adaptation
    # workload.
    t_start = time.time()
    tokens_out = 0
    while queue:
        wave, queue = queue[:B], queue[B:]
        while len(wave) < B:
            wave.append(np.zeros(args.prompt_len, np.int64))  # pad slot
        cache = model.init_cache(B, args.cache_len)
        prompts = jnp.asarray(np.stack(wave), jnp.int32)
        # prefill via decode steps (teacher forcing)
        logits = None
        for t in range(args.prompt_len):
            batch = {"tokens": prompts[:, t:t + 1], "cache": cache,
                     "cache_len": jnp.int32(t)}
            logits, cache = decode(params, batch)
        outs = [[] for _ in range(B)]
        for t in range(args.max_new):
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            for i in range(B):
                outs[i].append(int(nxt[i]))
            batch = {"tokens": nxt[:, None], "cache": cache,
                     "cache_len": jnp.int32(args.prompt_len + t)}
            logits, cache = decode(params, batch)
            tokens_out += B
        done.extend(outs)
    dt = time.time() - t_start
    print(json.dumps({
        "arch": cfg.name, "requests": args.requests,
        "tokens_generated": tokens_out, "wall_s": round(dt, 2),
        "tok_per_s": round(tokens_out / dt, 1),
        "sample_output": done[0][:8]}, indent=1))


def run_adapt(args):
    import functools

    import jax

    from repro.configs.paper_models import SINE_MLP
    from repro.metering import MetricsTracker
    from repro.models.paper_nets import init_paper_model, paper_model_loss
    from repro.serving import AdaptationServer, Fp32Adapter, TifedAdapter

    phi = init_paper_model(SINE_MLP, jax.random.PRNGKey(args.seed))
    if args.strategy == "tifed":
        from repro.core.strategies import tifed_requantize
        phi = tifed_requantize(phi)
        adapter = TifedAdapter(support=args.support, k_max=args.k_max)
    else:
        adapter = Fp32Adapter(
            loss_fn=functools.partial(paper_model_loss, SINE_MLP))
    if args.ckpt_dir is not None:
        from repro.checkpoint import load_params
        phi = load_params(args.ckpt_dir, phi)

    tracker = MetricsTracker()
    server = AdaptationServer(phi, adapter, slots=args.slots,
                              k_max=args.k_max,
                              steps_per_tick=args.steps_per_tick,
                              metrics=tracker)
    rng = np.random.default_rng(args.seed)
    a = rng.uniform(0.1, 5.0, args.requests)
    b = rng.uniform(0.0, np.pi, args.requests)

    def submit(i):
        sx = rng.uniform(-5, 5, (args.support, 1)).astype(np.float32)
        qx = rng.uniform(-5, 5, (args.query, 1)).astype(np.float32)
        k = int(rng.integers(1, args.k_max + 1))
        server.submit(sx, np.float32(a[i] * np.sin(sx + b[i])),
                      qx, np.float32(a[i] * np.sin(qx + b[i])), k)

    submit(0)
    server.drain()                    # warm the (single) jit trace
    server.reset()
    t0 = time.perf_counter()
    for i in range(args.requests):
        submit(i)
    results = server.drain()
    dt = time.perf_counter() - t0
    print(json.dumps({
        "mode": "adapt", "strategy": args.strategy,
        "requests": len(results), "slots": args.slots,
        "k_max": args.k_max, "steps_per_tick": args.steps_per_tick,
        "wall_s": round(dt, 3),
        "req_per_s": round(len(results) / dt, 1),
        "ticks": server.ticks, "trace_count": server.trace_count,
        "latency_ms": {k: round(v, 3) for k, v in
                       tracker.percentiles("serve.latency_ms").items()},
        "mean_query_loss": round(
            float(np.mean([r.query_loss for r in results])), 5)},
        indent=1))


def main(argv=None):
    args = parse_args(argv)
    if args.mode == "decode":
        run_decode(args)
    else:
        run_adapt(args)


if __name__ == "__main__":
    main()
