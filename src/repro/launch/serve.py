"""Serving launcher: batched autoregressive decoding with a KV cache.

Simulates a request queue (static batching): fills a fixed batch of
slots with prompts, prefills each via teacher-forced decode steps, then
decodes new tokens greedily until each request hits its length; freed
slots are refilled from the queue.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --reduced --requests 6 --batch 2 --max-new 8
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, get_arch
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ALL_ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    decode = jax.jit(model.decode_fn, donate_argnums=())

    rng = np.random.default_rng(args.seed)
    queue = [rng.integers(0, cfg.vocab_size, size=args.prompt_len)
             for _ in range(args.requests)]
    done = []
    B = args.batch

    # NOTE: per-slot cache_len requires the batched cache variant; this
    # loop advances all slots in lockstep (same prompt length) — the
    # standard static-batching baseline. Continuous batching with per-slot
    # offsets is future work recorded in DESIGN.md.
    t_start = time.time()
    tokens_out = 0
    while queue:
        wave, queue = queue[:B], queue[B:]
        while len(wave) < B:
            wave.append(np.zeros(args.prompt_len, np.int64))  # pad slot
        cache = model.init_cache(B, args.cache_len)
        prompts = jnp.asarray(np.stack(wave), jnp.int32)
        # prefill via decode steps (teacher forcing)
        logits = None
        for t in range(args.prompt_len):
            batch = {"tokens": prompts[:, t:t + 1], "cache": cache,
                     "cache_len": jnp.int32(t)}
            logits, cache = decode(params, batch)
        outs = [[] for _ in range(B)]
        for t in range(args.max_new):
            nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            for i in range(B):
                outs[i].append(int(nxt[i]))
            batch = {"tokens": nxt[:, None], "cache": cache,
                     "cache_len": jnp.int32(args.prompt_len + t)}
            logits, cache = decode(params, batch)
            tokens_out += B
        done.extend(outs)
    dt = time.time() - t_start
    print(json.dumps({
        "arch": cfg.name, "requests": args.requests,
        "tokens_generated": tokens_out, "wall_s": round(dt, 2),
        "tok_per_s": round(tokens_out / dt, 1),
        "sample_output": done[0][:8]}, indent=1))


if __name__ == "__main__":
    main()
