"""Describe an architecture: config, param counts, layer pattern, and the
production sharding plan (per-leaf PartitionSpec + per-chip bytes) without
touching device state (AbstractMesh).

  PYTHONPATH=src python -m repro.launch.describe --arch mixtral-8x22b
  PYTHONPATH=src python -m repro.launch.describe --arch zamba2-1.2b --params
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np
from jax.sharding import AbstractMesh

from repro.configs import ALL_ARCHS, SHAPES, get_arch
from repro.models import build_model
from repro.models.transformer import find_period, layer_specs
from repro.runtime.sharding import _path_str, _size, param_spec


def describe(arch: str, show_params: bool, multi_pod: bool):
    cfg = get_arch(arch)
    mesh = (AbstractMesh((2, 16, 16), ("pod", "data", "model")) if multi_pod
            else AbstractMesh((16, 16), ("data", "model")))
    print(f"# {cfg.name}  [{cfg.family}]  ({cfg.source})")
    for f in dataclasses.fields(cfg):
        v = getattr(cfg, f.name)
        if v not in (0, None, False, "") and f.name not in ("name", "family",
                                                            "source"):
            print(f"  {f.name:18s} = {v}")
    specs = layer_specs(cfg)
    p = find_period(specs)
    kinds = "".join({"attn": "A", "moe": "M", "mamba": "s",
                     "shared_attn": "S"}[k] for k, _ in specs)
    print(f"  layer pattern      = {kinds[:80]}{'...' if len(kinds) > 80 else ''}"
          f"  (period {p}, {len(specs)} applications)")
    print(f"  params (analytic)  = {cfg.param_count():,} "
          f"(active/token: {cfg.active_param_count():,})")
    print(f"  long-context OK    = {cfg.supports_long_context()}")

    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    leaves = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total_bytes = 0
    max_chip = 0
    rows = []
    for path, leaf in leaves:
        pstr = _path_str(path)
        spec = param_spec(pstr, leaf.shape, mesh)
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        shards = 1
        for dim, ax in zip(leaf.shape, spec):
            if ax is not None:
                shards *= _size(mesh, ax)
        total_bytes += nbytes
        max_chip += nbytes // shards
        rows.append((nbytes // shards, pstr, leaf.shape, spec))
    print(f"  param bytes        = {total_bytes/1e9:.2f} GB total, "
          f"{max_chip/1e9:.3f} GB/chip under {dict(mesh.shape)}")
    if show_params:
        rows.sort(reverse=True)
        print(f"  {'bytes/chip':>12s}  {'leaf':40s} {'shape':24s} spec")
        for b, pstr, shape, spec in rows[:25]:
            print(f"  {b/1e6:10.1f}MB  {pstr[:40]:40s} "
                  f"{str(tuple(shape)):24s} {spec}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ALL_ARCHS))
    ap.add_argument("--params", action="store_true",
                    help="show the largest parameter leaves + specs")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(ALL_ARCHS)
    for a in archs:
        describe(a, args.params, args.multi_pod)
        print()


if __name__ == "__main__":
    main()
