"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16x16 = 256 TPU v5e chips
(data, model). Multi-pod: 2 pods x 256 = 512 chips (pod, data, model).
"""
from __future__ import annotations

import math

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devs)} "
            "(run under XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "as launch/dryrun.py does)")
    from jax.sharding import Mesh
    return Mesh(np.array(devs[:n]).reshape(shape), axes)


# TPU v5e hardware constants for the roofline model.
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
