"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD forward: quadratic attention-like term within chunks +
linear state recurrence across chunks (jax.lax.scan). O(1)-state decode
step. ngroups = 1 (B/C shared across heads), as in the released models.

The chunked scan is also implemented as a Pallas TPU kernel
(repro.kernels.ssd_scan); this jnp version is the oracle + XLA fallback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import normal_init, rms_norm
from repro.runtime.shardctx import shard


def mamba_dims(d_model, expand, head_dim, d_state):
    d_inner = expand * d_model
    nheads = d_inner // head_dim
    conv_dim = d_inner + 2 * d_state  # conv over [x, B, C], ngroups=1
    return d_inner, nheads, conv_dim


def init_mamba(key, d_model, d_state, head_dim, expand, conv_width, dtype):
    d_inner, nheads, conv_dim = mamba_dims(d_model, expand, head_dim, d_state)
    ks = jax.random.split(key, 8)
    return {
        "w_z": normal_init(ks[0], (d_model, d_inner), 1.0, dtype),
        "w_x": normal_init(ks[1], (d_model, d_inner), 1.0, dtype),
        "w_B": normal_init(ks[2], (d_model, d_state), 1.0, dtype),
        "w_C": normal_init(ks[3], (d_model, d_state), 1.0, dtype),
        "w_dt": normal_init(ks[4], (d_model, nheads), 1.0, dtype),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads, dtype=jnp.float32)),
        "D": jnp.ones((nheads,), jnp.float32),
        "conv_w": normal_init(ks[5], (conv_width, conv_dim), 1.0, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "gate_norm": jnp.zeros((d_inner,), dtype),
        "w_out": normal_init(ks[6], (d_inner, d_model), 1.0, dtype),
    }


def _causal_conv(xbc, conv_w, conv_b):
    """Depthwise causal conv via shifted adds. xbc: (B,S,C); conv_w: (W,C)."""
    W = conv_w.shape[0]
    out = xbc * conv_w[W - 1]
    for i in range(1, W):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, :-i or None][:, : xbc.shape[1]]
        out = out + shifted * conv_w[W - 1 - i]
    return out + conv_b


def segsum_exp(dA_cs):
    """exp(dA_cs[i] - dA_cs[j]) masked to i >= j. dA_cs: (..., L, h).

    The mask is applied INSIDE the exp (as -inf) — masking the overflowed
    exp afterwards leaves inf * 0 in the backward pass (NaN grads)."""
    L = dA_cs.shape[-2]
    diff = dA_cs[..., :, None, :] - dA_cs[..., None, :, :]   # (..., i, j, h)
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.exp(jnp.where(mask[..., None], diff, -1e30))


def ssd_chunked(x, dt, A, Bm, Cm, chunk, initial_state=None):
    """Chunked SSD scan.

    x: (b, s, h, p) values; dt: (b, s, h) step sizes (post-softplus);
    A: (h,) negative decay rates; Bm, Cm: (b, s, n) input/output maps
    (ngroups=1, broadcast over heads). Returns (y, final_state) with
    y: (b, s, h, p), state: (b, h, p, n).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S = x.shape[1]
    nc = S // chunk

    xd = (x * dt[..., None]).astype(jnp.float32)             # (b,S,h,p)
    dA = (dt * A).astype(jnp.float32)                        # (b,S,h) <= 0
    xc = xd.reshape(b, nc, chunk, h, p)
    dAc = dA.reshape(b, nc, chunk, h)
    Bc = Bm.reshape(b, nc, chunk, n).astype(jnp.float32)
    Cc = Cm.reshape(b, nc, chunk, n).astype(jnp.float32)
    dA_cs = jnp.cumsum(dAc, axis=2)                          # (b,nc,L,h)

    # --- intra-chunk (quadratic within chunk) ---
    Lmat = segsum_exp(dA_cs)                                 # (b,nc,L,L,h)
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)               # (b,nc,L,L)
    W = CB[..., None] * Lmat                                 # (b,nc,L,L,h)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", W, xc)

    # --- chunk boundary states ---
    decay_out = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)         # (b,nc,L,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc, decay_out, xc)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                # (b,nc,h)

    # --- inter-chunk recurrence ---
    def step(state, inp):
        st_c, dec_c = inp                                    # (b,h,p,n), (b,h)
        new = state * dec_c[:, :, None, None] + st_c
        return new, state                                    # emit PREVIOUS

    init = (jnp.zeros((b, h, p, n), jnp.float32)
            if initial_state is None else initial_state.astype(jnp.float32))
    from repro.runtime.flags import probe_mode
    if probe_mode():
        # unrolled recurrence for exact cost_analysis (probe compiles only)
        state = init
        prevs = []
        for c in range(nc):
            prevs.append(state)
            state = state * chunk_decay[:, c][:, :, None, None] + states[:, c]
        final_state = state
        prev_states = jnp.stack(prevs, axis=1)
    else:
        final_state, prev_states = jax.lax.scan(
            step, init,
            (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
        prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # (b,nc,h,p,n)

    # --- state -> output within chunk ---
    decay_in = jnp.exp(dA_cs)                                # (b,nc,L,h)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, prev_states, decay_in)

    y = (y_diag + y_off).reshape(b, S, h, p)[:, :s]
    return y.astype(x.dtype), final_state


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _ssd_pallas(chunk, interpret, x, dt, A, Bm, Cm):
    """Kernel forward for ``ssd_chunked_pallas``: exactly
    ``ssd_chunked``'s dt-scaling and chunk reshapes, laid out for the
    kernel's (B, H, nc) grid, zero initial state."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S = x.shape[1]
    nc = S // chunk

    xd = (x * dt[..., None]).astype(jnp.float32)             # (b,S,h,p)
    dA = (dt * A).astype(jnp.float32)                        # (b,S,h)
    xk = xd.reshape(b, nc, chunk, h, p).transpose(0, 3, 1, 2, 4)
    dAk = dA.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)
    Bk = Bm.reshape(b, nc, chunk, n).astype(jnp.float32)
    Ck = Cm.reshape(b, nc, chunk, n).astype(jnp.float32)
    # call-time import so tests can wrap/count the kernel entry point
    from repro.kernels.ssd_scan import ssd_scan
    y = ssd_scan(xk, dAk, Bk, Ck, interpret=interpret)       # f32
    y = y.transpose(0, 2, 3, 1, 4).reshape(b, S, h, p)[:, :s]
    return y.astype(x.dtype)


def _ssd_pallas_fwd(chunk, interpret, x, dt, A, Bm, Cm):
    return _ssd_pallas(chunk, interpret, x, dt, A, Bm, Cm), (x, dt, A,
                                                             Bm, Cm)


def _ssd_pallas_bwd(chunk, interpret, res, g):
    # backward through the jnp oracle (the same math the kernel
    # computes): pallas_call has no transpose rule, and the oracle's
    # VJP is exactly the kernel forward's derivative
    del interpret
    x, dt, A, Bm, Cm = res
    _, vjp = jax.vjp(
        lambda x, dt, A, Bm, Cm: ssd_chunked(x, dt, A, Bm, Cm, chunk)[0],
        x, dt, A, Bm, Cm)
    return vjp(g)


_ssd_pallas.defvjp(_ssd_pallas_fwd, _ssd_pallas_bwd)


def ssd_chunked_pallas(x, dt, A, Bm, Cm, chunk, *, interpret=None):
    """The chunked SSD scan routed through the Pallas kernel
    (repro.kernels.ssd_scan; interpret mode off-TPU). Forward runs the
    kernel — the inter-chunk state carried in VMEM scratch, never the
    (S, S) semiseparable matrix — and the backward pass differentiates
    the jnp oracle (``ssd_chunked``), which computes the same math.
    Returns y only; the train/prefill path discards the final state."""
    return _ssd_pallas(chunk, interpret, x, dt, A, Bm, Cm)


def mamba_block(params, x, *, d_state, head_dim, expand, conv_width, chunk,
                norm_eps=1e-5):
    """Full Mamba2 block forward (train/prefill). x: (B, S, d).

    The SSD scan runs the jnp oracle by default; the ``ssd_pallas``
    feature flag (repro.runtime.flags) routes it through the Pallas
    kernel — the federated LM hot path's compute kernel."""
    B, S, d = x.shape
    d_inner, nheads, conv_dim = mamba_dims(d, expand, head_dim, d_state)
    z = x @ params["w_z"]
    xin = x @ params["w_x"]
    Bm = x @ params["w_B"]
    Cm = x @ params["w_C"]
    dt_raw = x @ params["w_dt"]

    xbc = jnp.concatenate([xin, Bm, Cm], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"], params["conv_b"]))
    xin, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    xh = xin.reshape(B, S, nheads, head_dim)
    from repro.runtime.flags import feature
    if feature("ssd_pallas"):
        y = ssd_chunked_pallas(xh, dt, A, Bm, Cm, chunk)
    else:
        y, _ = ssd_chunked(xh, dt, A, Bm, Cm, chunk)
    y = y + xh * params["D"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(B, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], norm_eps)
    return y @ params["w_out"]


def mamba_decode_block(params, x, conv_state, ssm_state, *, d_state,
                       head_dim, expand, conv_width, norm_eps=1e-5):
    """One-token decode. x: (B, 1, d); conv_state: (B, W-1, conv_dim);
    ssm_state: (B, h, p, n). Returns (y, conv_state, ssm_state)."""
    B, _, d = x.shape
    d_inner, nheads, conv_dim = mamba_dims(d, expand, head_dim, d_state)
    z = x @ params["w_z"]
    xin = x @ params["w_x"]
    Bm = x @ params["w_B"]
    Cm = x @ params["w_C"]
    dt_raw = x @ params["w_dt"]

    xbc = jnp.concatenate([xin, Bm, Cm], axis=-1)            # (B,1,conv_dim)
    window = jnp.concatenate([conv_state, xbc], axis=1)      # (B,W,conv_dim)
    new_conv_state = window[:, 1:]
    conv_out = (window * params["conv_w"][None]).sum(axis=1, keepdims=True)
    xbc = jax.nn.silu(conv_out + params["conv_b"])
    xin, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,1,h)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt[:, 0] * A)                                # (B,h)
    xh = xin.reshape(B, nheads, head_dim).astype(jnp.float32)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0], Bm[:, 0].astype(jnp.float32), xh)
    new_ssm_state = ssm_state * dA[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32), new_ssm_state)
    y = y + xh * params["D"][None, :, None]
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], norm_eps)
    return y @ params["w_out"], new_conv_state, new_ssm_state
