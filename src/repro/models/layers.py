"""Shared layer primitives: norms, MLPs, embeddings, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def normal_init(key, shape, scale, dtype):
    fan_in = shape[0] if len(shape) >= 2 else max(int(np.prod(shape)), 1)
    std = scale / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def rms_norm(x, weight, eps):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def init_mlp(key, d_model, d_ff, act, dtype):
    """SwiGLU (silu) or plain 2-layer (gelu) MLP params."""
    ks = jax.random.split(key, 3)
    if act == "silu":
        return {
            "w_gate": normal_init(ks[0], (d_model, d_ff), 1.0, dtype),
            "w_up": normal_init(ks[1], (d_model, d_ff), 1.0, dtype),
            "w_down": normal_init(ks[2], (d_ff, d_model), 1.0, dtype),
        }
    return {
        "w_in": normal_init(ks[0], (d_model, d_ff), 1.0, dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": normal_init(ks[1], (d_ff, d_model), 1.0, dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def mlp(params, x, act):
    if act == "silu":
        g = jax.nn.silu(x @ params["w_gate"])
        return (g * (x @ params["w_up"])) @ params["w_down"]
    h = jax.nn.gelu(x @ params["w_in"] + params["b_in"])
    return h @ params["w_out"] + params["b_out"]


def mlp_flops(d_model, d_ff, act, tokens):
    n = 3 if act == "silu" else 2
    return 2 * n * d_model * d_ff * tokens
