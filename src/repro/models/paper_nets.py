"""The paper's own models (Table I): a 1,153-param sine MLP and small
conv classifiers, as pure-JAX pytree models — these are the faithful
reproduction substrate that the core/ algorithms train on MCU-class
problems."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_models import PaperModelConfig


def init_paper_model(cfg: PaperModelConfig, key) -> Dict[str, Any]:
    if cfg.kind == "mlp":
        dims = (int(np.prod(cfg.input_shape)),) + cfg.hidden + (cfg.num_outputs,)
        params = {}
        ks = jax.random.split(key, len(dims) - 1)
        for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
            params[f"w{i}"] = (jax.random.normal(ks[i], (din, dout))
                               * np.sqrt(2.0 / din)).astype(jnp.float32)
            params[f"b{i}"] = jnp.zeros((dout,), jnp.float32)
        return params
    # conv: 3x3 stride-2 blocks + linear head
    params = {}
    ks = jax.random.split(key, len(cfg.channels) + 1)
    cin = cfg.input_shape[-1]
    h, w = cfg.input_shape[0], cfg.input_shape[1]
    for i, cout in enumerate(cfg.channels):
        fan = 9 * cin
        params[f"conv{i}"] = (jax.random.normal(ks[i], (3, 3, cin, cout))
                              * np.sqrt(2.0 / fan)).astype(jnp.float32)
        params[f"cb{i}"] = jnp.zeros((cout,), jnp.float32)
        cin = cout
        h, w = (h + 1) // 2, (w + 1) // 2
    flat = h * w * cin
    params["head_w"] = (jax.random.normal(ks[-1], (flat, cfg.num_outputs))
                        * np.sqrt(1.0 / flat)).astype(jnp.float32)
    params["head_b"] = jnp.zeros((cfg.num_outputs,), jnp.float32)
    return params


def paper_model_apply(cfg: PaperModelConfig, params, x):
    """x: (B, *input_shape) -> (B, num_outputs)."""
    if cfg.kind == "mlp":
        h = x.reshape(x.shape[0], -1)
        n = len(cfg.hidden) + 1
        for i in range(n):
            h = h @ params[f"w{i}"] + params[f"b{i}"]
            if i < n - 1:
                h = jnp.tanh(h)  # paper's sine net uses smooth nonlinearity
        return h
    h = x
    for i in range(len(cfg.channels)):
        h = jax.lax.conv_general_dilated(
            h, params[f"conv{i}"], window_strides=(2, 2), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        h = jax.nn.relu(h + params[f"cb{i}"])
    h = h.reshape(h.shape[0], -1)
    return h @ params["head_w"] + params["head_b"]


def paper_model_loss(cfg: PaperModelConfig, params, batch):
    """batch: {"x": (B, ...), "y": (B,) or (B,1)}."""
    pred = paper_model_apply(cfg, params, batch["x"])
    if cfg.loss == "mse":
        return jnp.mean(jnp.square(pred - batch["y"].reshape(pred.shape)))
    labels = batch["y"].astype(jnp.int32).reshape(-1)
    logp = jax.nn.log_softmax(pred, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def relu_mlp_apply(params, x):
    """ReLU forward on the {w*, b*} MLP pytree — the network TIFeD's
    integer arithmetic actually computes (ReLU's zero/identity branches
    are exact on the int8 grid; the paper net's tanh is not), used by
    the fp32 eval finetune of tifed runs. x: (B, ...) -> (B, dout)."""
    h = x.reshape(x.shape[0], -1)
    n = sum(1 for k in params if k.startswith("w"))
    for i in range(n):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            h = jnp.maximum(h, 0.0)
    return h


def relu_mlp_loss(params, batch):
    """MSE on the ReLU MLP (engine loss_fn signature)."""
    pred = relu_mlp_apply(params, batch["x"])
    return jnp.mean(jnp.square(pred - batch["y"].reshape(pred.shape)))


def paper_model_accuracy(cfg: PaperModelConfig, params, batch):
    pred = paper_model_apply(cfg, params, batch["x"])
    return jnp.mean((jnp.argmax(pred, -1) == batch["y"].reshape(-1)))


def param_count(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
