"""Model assembly: decoder LMs (dense / MoE / SSM / hybrid / VLM) and the
whisper encoder-decoder, built from an ArchConfig.

Homogeneous layer stacks use scan-over-layers (params stacked over
pattern groups) to keep HLO compact; small / irregular stacks (whisper,
zamba2 hybrid) use python loops.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, MAMBA, MOE, SHARED_ATTN, ArchConfig
from repro.models import attention as attn_lib
from repro.models import mamba2 as mamba_lib
from repro.models import moe as moe_lib
from repro.models.layers import init_mlp, mlp, normal_init, rms_norm
from repro.runtime.shardctx import shard

AUX_LOSS_WEIGHT = 0.01
LABEL_IGNORE = -1


# ---------------------------------------------------------------------------
# layer spec / scan-pattern machinery
# ---------------------------------------------------------------------------

def layer_specs(cfg: ArchConfig) -> List[Tuple[str, int]]:
    """Per-application (kind, window) list, including SHARED_ATTN entries."""
    specs = []
    attn_idx = 0  # index among attention layers, for global_attn_every
    for kind in cfg.block_kinds():
        if kind in (ATTN, MOE):
            window = cfg.sliding_window
            if cfg.global_attn_every and (attn_idx + 1) % cfg.global_attn_every == 0:
                window = 0  # periodic global layer (llama4 iRoPE)
            attn_idx += 1
            specs.append((kind, window))
        elif kind == SHARED_ATTN:
            specs.append((SHARED_ATTN, cfg.sliding_window))
        else:
            specs.append((MAMBA, 0))
    return specs


def find_period(specs: List[Tuple[str, int]]) -> int:
    L = len(specs)
    for p in range(1, L + 1):
        if L % p == 0 and specs == specs[:p] * (L // p):
            return p
    return L


def _sinusoidal(positions, d_model):
    """positions: (S,) or (B,S) -> (..., d_model) float32."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# per-block init / apply
# ---------------------------------------------------------------------------

def _init_block(cfg: ArchConfig, kind: str, key, *, cross: bool) -> Dict[str, Any]:
    d, dtype = cfg.d_model, jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    if kind == MAMBA:
        return {
            "norm1": jnp.zeros((d,), dtype),
            "mamba": mamba_lib.init_mamba(
                ks[0], d, cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_expand,
                cfg.ssm_conv_width, dtype),
        }
    p = {
        "norm1": jnp.zeros((d,), dtype),
        "attn": attn_lib.init_attention(ks[0], d, cfg.num_heads,
                                        cfg.num_kv_heads, hd, dtype),
        "norm2": jnp.zeros((d,), dtype),
    }
    if cross:
        p["norm_x"] = jnp.zeros((d,), dtype)
        p["cross"] = attn_lib.init_attention(ks[1], d, cfg.num_heads,
                                             cfg.num_kv_heads, hd, dtype)
    if kind == MOE:
        p["moe"] = moe_lib.init_moe(ks[2], d, cfg.d_ff, cfg.num_experts,
                                    cfg.shared_expert, dtype)
    else:
        p["mlp"] = init_mlp(ks[2], d, cfg.d_ff, cfg.act, dtype)
    return p


def _apply_block(cfg: ArchConfig, kind: str, window: int, bp, x, *,
                 positions=None, enc_out=None, use_rope=True):
    """Forward one block (train/prefill). Returns (x, aux)."""
    eps = cfg.norm_eps
    aux = jnp.zeros((), jnp.float32)
    if kind == MAMBA:
        h = mamba_lib.mamba_block(
            bp["mamba"], rms_norm(x, bp["norm1"], eps),
            d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
            expand=cfg.ssm_expand, conv_width=cfg.ssm_conv_width,
            chunk=cfg.ssm_chunk, norm_eps=eps)
        return x + h, aux
    h = attn_lib.attention_block(
        bp["attn"], rms_norm(x, bp["norm1"], eps),
        num_kv_heads=cfg.num_kv_heads, rope_theta=cfg.rope_theta,
        causal=True, window=window, positions=positions, use_rope=use_rope)
    h = shard(h, "batch", None, None)
    x = x + h
    if "cross" in bp:
        c = attn_lib.attention_block(
            bp["cross"], rms_norm(x, bp["norm_x"], eps),
            num_kv_heads=cfg.num_kv_heads, rope_theta=cfg.rope_theta,
            causal=False, kv_x=enc_out, use_rope=False)
        x = x + c
    y_in = rms_norm(x, bp["norm2"], eps)
    if kind == MOE:
        y, aux = moe_lib.moe_block(bp["moe"], y_in,
                                   experts_per_token=cfg.experts_per_token)
    else:
        y = mlp(bp["mlp"], y_in, cfg.act)
    y = shard(y, "batch", None, None)
    return x + y, aux


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _attn_cache(cfg, batch, seq_len, dtype, stack: int = 0):
    hd = cfg.resolved_head_dim
    kv = cfg.num_kv_heads
    lead = (stack,) if stack else ()
    shape = lead + (batch, seq_len, kv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _mamba_cache(cfg, batch, dtype, stack: int = 0):
    d_inner, nheads, conv_dim = mamba_lib.mamba_dims(
        cfg.d_model, cfg.ssm_expand, cfg.ssm_head_dim, cfg.ssm_state)
    lead = (stack,) if stack else ()
    return {
        "conv": jnp.zeros(lead + (batch, cfg.ssm_conv_width - 1, conv_dim),
                          dtype),
        "ssm": jnp.zeros(lead + (batch, nheads, cfg.ssm_head_dim,
                                 cfg.ssm_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# the Model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Model:
    cfg: ArchConfig

    # ----- structure ------------------------------------------------------
    @property
    def specs(self):
        return layer_specs(self.cfg)

    @property
    def is_hybrid(self):
        return self.cfg.family == "hybrid"

    @property
    def is_encdec(self):
        return self.cfg.encoder_layers > 0

    @property
    def use_scan(self):
        from repro.runtime.flags import probe_mode
        if probe_mode():
            return False  # unrolled for exact cost_analysis
        if self.is_hybrid or self.is_encdec:
            return False
        p = find_period(self.specs)
        return len(self.specs) // p >= 4

    # ----- init -----------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        keys = iter(jax.random.split(key, 4 * len(self.specs) + 16))
        params: Dict[str, Any] = {
            "embed": normal_init(next(keys), (cfg.vocab_size, cfg.d_model),
                                 1.0, dtype),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = normal_init(
                next(keys), (cfg.d_model, cfg.vocab_size), 1.0, dtype)

        specs = self.specs
        cross = self.is_encdec
        if self.is_hybrid:
            k = cfg.hybrid_attn_every
            n_full, r = divmod(cfg.num_layers, k)
            params["shared_block"] = _init_block(cfg, ATTN, next(keys),
                                                 cross=False)
            stacks = []
            for pos in range(k):
                blocks = [_init_block(cfg, MAMBA, next(keys), cross=False)
                          for _ in range(n_full)]
                stacks.append(jax.tree.map(lambda *xs: jnp.stack(xs),
                                           *blocks))
            params["layers"] = stacks            # scan over n_full groups
            params["tail"] = [_init_block(cfg, MAMBA, next(keys), cross=False)
                              for _ in range(r)]
        elif self.use_scan:
            p = find_period(specs)
            n_groups = len(specs) // p
            stacks = []
            for pos in range(p):
                kind = specs[pos][0]
                blocks = [_init_block(cfg, kind, next(keys), cross=cross)
                          for _ in range(n_groups)]
                stacks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *blocks))
            params["layers"] = stacks
        else:
            params["layers"] = [
                _init_block(cfg, kind, next(keys), cross=cross)
                for kind, _ in specs]

        if self.is_encdec:
            enc_blocks = [_init_block(cfg, ATTN, next(keys), cross=False)
                          for _ in range(cfg.encoder_layers)]
            params["encoder"] = {
                "layers": enc_blocks,
                "final_norm": jnp.zeros((cfg.d_model,), dtype),
            }
        if cfg.frontend == "vision":
            # stub projector: patch embeddings arrive at d_model already;
            # a learned affine keeps the projector a real (tiny) substrate.
            params["vision_proj"] = normal_init(
                next(keys), (cfg.d_model, cfg.d_model), 1.0, dtype)
        return params

    # ----- shared forward pieces ------------------------------------------
    def _encode(self, params, frames):
        """Whisper encoder over precomputed frame embeddings (stub frontend)."""
        cfg = self.cfg
        S = frames.shape[1]
        x = frames + _sinusoidal(jnp.arange(S), cfg.d_model).astype(frames.dtype)
        for bp in params["encoder"]["layers"]:
            h = attn_lib.attention_block(
                bp["attn"], rms_norm(x, bp["norm1"], cfg.norm_eps),
                num_kv_heads=cfg.num_kv_heads, rope_theta=cfg.rope_theta,
                causal=False, use_rope=False)
            x = x + h
            x = x + mlp(bp["mlp"], rms_norm(x, bp["norm2"], cfg.norm_eps),
                        cfg.act)
        return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)

    def _embed_inputs(self, params, batch):
        """Token (+frontend) embedding. Returns (x, enc_out, text_offset)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = params["embed"][tokens]
        x = shard(x, "batch", None, None)
        enc_out = None
        offset = 0
        if cfg.frontend == "vision" and "patch_embeds" in batch:
            patches = batch["patch_embeds"].astype(x.dtype) @ params["vision_proj"]
            x = jnp.concatenate([patches, x], axis=1)
            offset = patches.shape[1]
        if self.is_encdec:
            enc_out = self._encode(params, batch["frames"])
            x = x + _sinusoidal(
                jnp.arange(x.shape[1]), cfg.d_model).astype(x.dtype)
        return x, enc_out, offset

    def _backbone(self, params, x, *, enc_out=None):
        """Run all blocks. Returns (hidden, aux_loss)."""
        cfg = self.cfg
        specs = self.specs
        use_rope = not self.is_encdec
        positions = jnp.arange(x.shape[1])

        if self.is_hybrid:
            from repro.runtime.flags import probe_mode
            k = cfg.hybrid_attn_every
            n_full, r = divmod(cfg.num_layers, k)
            shared = params["shared_block"]

            def group_body(carry, group_params):
                h, aux = carry
                # the weight-SHARED transformer block precedes each group
                h, a = _apply_block(cfg, ATTN, cfg.sliding_window, shared,
                                    h, positions=positions)
                for pos in range(k):
                    h, _ = _apply_block(cfg, MAMBA, 0, group_params[pos], h)
                return (h, aux + a), None

            carry = (x, jnp.zeros((), jnp.float32))
            if probe_mode():
                for g in range(n_full):
                    gp = [jax.tree.map(lambda a, i=g: a[i], s)
                          for s in params["layers"]]
                    carry, _ = group_body(carry, gp)
            else:
                body = jax.checkpoint(group_body, prevent_cse=False)
                carry, _ = jax.lax.scan(body, carry, params["layers"])
            x, aux = carry
            if r:
                x, a = _apply_block(cfg, ATTN, cfg.sliding_window, shared,
                                    x, positions=positions)
                aux = aux + a
                for bp in params["tail"]:
                    x, _ = _apply_block(cfg, MAMBA, 0, bp, x)
            return x, aux

        if self.use_scan:
            p = find_period(specs)
            pattern = specs[:p]

            def body(carry, group_params):
                h, aux = carry
                for pos, (kind, window) in enumerate(pattern):
                    h, a = _apply_block(cfg, kind, window, group_params[pos],
                                        h, positions=positions,
                                        use_rope=use_rope)
                    aux = aux + a
                return (h, aux), None

            body = jax.checkpoint(body, prevent_cse=False)
            (x, aux), _ = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)), params["layers"])
            return x, aux

        aux = jnp.zeros((), jnp.float32)
        for bp, (kind, window) in zip(params["layers"], specs):
            x, a = _apply_block(cfg, kind, window, bp, x,
                                positions=positions, enc_out=enc_out,
                                use_rope=use_rope)
            aux = aux + a
        return x, aux

    def _lm_head(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    # ----- training loss ---------------------------------------------------
    def loss_fn(self, params, batch):
        """Mean next-token cross-entropy (+ MoE aux)."""
        cfg = self.cfg
        x, enc_out, offset = self._embed_inputs(params, batch)
        x, aux = self._backbone(params, x, enc_out=enc_out)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        if offset:
            x = x[:, offset:]
        labels = batch["labels"]
        loss = chunked_cross_entropy(x, self._lm_head(params), labels)
        return loss + AUX_LOSS_WEIGHT * aux

    # ----- prefill ----------------------------------------------------------
    def prefill_fn(self, params, batch):
        """Returns (last-token logits, populated attention KV caches).

        Caches are rebuilt by re-projecting K/V per layer (python loop over
        specs when not scanning; for scanned stacks, a scan emitting ys).
        For simplicity and HLO compactness the prefill path recomputes the
        backbone and extracts caches via a dedicated pass.
        """
        cfg = self.cfg
        x, enc_out, offset = self._embed_inputs(params, batch)
        x, _ = self._backbone(params, x, enc_out=enc_out)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        last = x[:, -1:]
        logits = (last @ self._lm_head(params)).astype(jnp.float32)
        return logits

    # ----- decode -----------------------------------------------------------
    def init_cache(self, batch_size, seq_len, dtype=None):
        """Decode cache. Scanned stacks get caches stacked over groups
        (written via scan ys — no O(L^2) copies); loop archs get per-layer
        lists (updated by element — no copies at all)."""
        cfg = self.cfg
        dtype = dtype or jnp.dtype(cfg.dtype)
        specs = self.specs
        from repro.runtime.flags import feature

        def eff_seq(window):
            if feature("ringkv") and window:
                return min(seq_len, window)   # ring buffer = the window
            return seq_len

        if self.is_hybrid:
            k = cfg.hybrid_attn_every
            n_full, r = divmod(cfg.num_layers, k)
            cache = {
                "group_attn": _attn_cache(cfg, batch_size,
                                          eff_seq(cfg.sliding_window),
                                          dtype, stack=n_full),
                "group_mamba": [_mamba_cache(cfg, batch_size, dtype,
                                             stack=n_full)
                                for _ in range(k)],
            }
            if r:
                cache["tail_attn"] = _attn_cache(cfg, batch_size,
                                                 eff_seq(cfg.sliding_window),
                                                 dtype)
                cache["tail_mamba"] = [_mamba_cache(cfg, batch_size, dtype)
                                       for _ in range(r)]
            return cache
        if self.use_scan:
            p = find_period(specs)
            n_groups = len(specs) // p
            layers = []
            for kind, window in specs[:p]:
                if kind == MAMBA:
                    layers.append(_mamba_cache(cfg, batch_size, dtype,
                                               stack=n_groups))
                else:
                    layers.append(_attn_cache(cfg, batch_size,
                                              eff_seq(window), dtype,
                                              stack=n_groups))
            return {"layers": layers}
        layers = []
        for kind, window in specs:
            if kind == MAMBA:
                layers.append(_mamba_cache(cfg, batch_size, dtype))
            else:
                layers.append(_attn_cache(cfg, batch_size, eff_seq(window),
                                          dtype))
        cache = {"layers": layers}
        if self.is_encdec:
            hd = cfg.resolved_head_dim
            n = len(specs)
            cache["cross"] = [
                {"k": jnp.zeros((batch_size, cfg.encoder_tokens,
                                 cfg.num_kv_heads, hd), dtype),
                 "v": jnp.zeros((batch_size, cfg.encoder_tokens,
                                 cfg.num_kv_heads, hd), dtype)}
                for _ in range(n)]
        return cache

    def _decode_block(self, kind, window, bp, x, cache_entry, cache_len,
                      cross_entry=None):
        """Apply one decode block. Returns (x, new_cache_entry)."""
        cfg = self.cfg
        eps = cfg.norm_eps
        if kind == MAMBA:
            h = rms_norm(x, bp["norm1"], eps)
            out, conv_new, ssm_new = mamba_lib.mamba_decode_block(
                bp["mamba"], h, cache_entry["conv"], cache_entry["ssm"],
                d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
                expand=cfg.ssm_expand, conv_width=cfg.ssm_conv_width,
                norm_eps=eps)
            return x + out, {"conv": conv_new, "ssm": ssm_new}
        h = rms_norm(x, bp["norm1"], eps)
        out, k_new, v_new = attn_lib.decode_attention_block(
            bp["attn"], h, cache_entry["k"], cache_entry["v"], cache_len,
            rope_theta=cfg.rope_theta, window=window,
            use_rope=not self.is_encdec)
        x = x + out
        if "cross" in bp and cross_entry is not None:
            q = jnp.einsum("bsd,dnh->bsnh",
                           rms_norm(x, bp["norm_x"], eps), bp["cross"]["wq"])
            c = attn_lib.decode_attention(q, cross_entry["k"],
                                          cross_entry["v"],
                                          cfg.encoder_tokens)
            x = x + jnp.einsum("bsnh,nhd->bsd", c, bp["cross"]["wo"])
        y_in = rms_norm(x, bp["norm2"], eps)
        if kind == MOE:
            y, _ = moe_lib.moe_block(bp["moe"], y_in,
                                     experts_per_token=cfg.experts_per_token)
        else:
            y = mlp(bp["mlp"], y_in, cfg.act)
        return x + y, {"k": k_new, "v": v_new}

    def decode_fn(self, params, batch):
        """One decode step. batch: tokens (B,1), cache, cache_len (scalar).

        Returns (logits (B,1,V) fp32, new cache).
        """
        cfg = self.cfg
        eps = cfg.norm_eps
        tokens, cache, cache_len = (batch["tokens"], batch["cache"],
                                    batch["cache_len"])
        x = params["embed"][tokens]
        if self.is_encdec:
            pos = jnp.full((tokens.shape[0], 1), cache_len)
            x = x + _sinusoidal(pos, cfg.d_model).astype(x.dtype)
        specs = self.specs

        if self.is_hybrid:
            from repro.runtime.flags import probe_mode
            k = cfg.hybrid_attn_every
            n_full, r = divmod(cfg.num_layers, k)
            shared = params["shared_block"]
            window = cfg.sliding_window

            def group_body(h, xs):
                group_params, gattn, gmamba = xs
                h, attn_entry = self._decode_block(
                    ATTN, window, shared, h, gattn, cache_len)
                new_m = []
                for pos in range(k):
                    h, e = self._decode_block(
                        MAMBA, 0, group_params[pos], h, gmamba[pos],
                        cache_len)
                    new_m.append(e)
                return h, (attn_entry, new_m)

            xs = (params["layers"], cache["group_attn"],
                  cache["group_mamba"])
            if probe_mode():
                new_attn, new_mamba = [], []
                for g in range(n_full):
                    gxs = jax.tree.map(lambda a, i=g: a[i], xs)
                    x, (ae, me) = group_body(x, gxs)
                    new_attn.append(ae)
                    new_mamba.append(me)
                new_attn = jax.tree.map(lambda *v: jnp.stack(v), *new_attn)
                new_mamba = jax.tree.map(lambda *v: jnp.stack(v), *new_mamba)
            else:
                x, (new_attn, new_mamba) = jax.lax.scan(group_body, x, xs)
            new_cache = {"group_attn": new_attn, "group_mamba": new_mamba}
            if r:
                x, te = self._decode_block(ATTN, window, shared, x,
                                           cache["tail_attn"], cache_len)
                new_cache["tail_attn"] = te
                new_tail = []
                for pos in range(r):
                    x, e = self._decode_block(
                        MAMBA, 0, params["tail"][pos], x,
                        cache["tail_mamba"][pos], cache_len)
                    new_tail.append(e)
                new_cache["tail_mamba"] = new_tail
            x = rms_norm(x, params["final_norm"], eps)
            logits = (x @ self._lm_head(params)).astype(jnp.float32)
            return logits, new_cache

        if self.use_scan:
            p = find_period(specs)
            pattern = specs[:p]

            def body(h, xs):
                group_params, group_cache = xs
                new_entries = []
                for pos, (kind, window) in enumerate(pattern):
                    h, entry = self._decode_block(
                        kind, window, group_params[pos], h,
                        group_cache[pos], cache_len)
                    new_entries.append(entry)
                return h, new_entries

            x, new_layers = jax.lax.scan(
                body, x, (params["layers"], cache["layers"]))
            new_cache = {"layers": new_layers}
        else:
            new_layers = list(cache["layers"])
            for li, (kind, window) in enumerate(specs):
                bp = (params["shared_block"] if kind == SHARED_ATTN else
                      self._layer_params(params, li, kind))
                cross_entry = (cache["cross"][li]
                               if self.is_encdec else None)
                x, new_layers[li] = self._decode_block(
                    kind, window, bp, x, cache["layers"][li], cache_len,
                    cross_entry=cross_entry)
            new_cache = dict(cache)
            new_cache["layers"] = new_layers

        x = rms_norm(x, params["final_norm"], eps)
        logits = (x @ self._lm_head(params)).astype(jnp.float32)
        return logits, new_cache

    # ----- helpers ----------------------------------------------------------
    def _layer_params(self, params, layer_idx, kind):
        """Fetch per-layer params regardless of storage layout."""
        specs = self.specs
        if self.is_hybrid:
            mi = sum(1 for k, _ in specs[:layer_idx] if k == MAMBA)
            k = self.cfg.hybrid_attn_every
            group, pos = divmod(mi, k)
            n_full = self.cfg.num_layers // k
            if group >= n_full:
                return params["tail"][mi - n_full * k]
            return jax.tree.map(lambda a: a[group], params["layers"][pos])
        if self.use_scan:
            p = find_period(specs)
            group, pos = divmod(layer_idx, p)
            return jax.tree.map(lambda a: a[group], params["layers"][pos])
        return params["layers"][layer_idx]


def chunked_cross_entropy(x, lm_head, labels, chunk=1024):
    """Memory-efficient CE: scan over sequence chunks, recompute logits in
    the backward pass (jax.checkpoint). x: (B,S,d); labels: (B,S)."""
    from repro.runtime.flags import probe_mode
    B, S, d = x.shape
    chunk = S if probe_mode() else min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)),
                         constant_values=LABEL_IGNORE)
    nch = x.shape[1] // chunk
    xs = x.reshape(B, nch, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, nch, chunk).transpose(1, 0, 2)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(carry, inp):
        total, count = carry
        xc, lc = inp
        logits = (xc @ lm_head).astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        safe = jnp.maximum(lc, 0)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        valid = (lc != LABEL_IGNORE)
        nll = jnp.where(valid, logz - gold, 0.0)
        return (total + nll.sum(), count + valid.sum()), None

    (total, count), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (xs, ls))
    return total / jnp.maximum(count, 1)


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
