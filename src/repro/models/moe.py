"""Mixture-of-Experts block: top-k token-choice routing with sort-based
capacity dispatch (Megablocks-style grouping expressed in XLA-friendly
gather/scatter), expert-parallel weights, load-balance aux loss, optional
shared expert (Llama-4)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import init_mlp, mlp, normal_init
from repro.runtime.shardctx import shard


def init_moe(key, d_model, d_ff, num_experts, shared_expert, dtype):
    ks = jax.random.split(key, 5)
    p = {
        "router": normal_init(ks[0], (d_model, num_experts), 1.0, jnp.float32),
        "w_gate": normal_init(ks[1], (num_experts, d_model, d_ff), 1.0, dtype),
        "w_up": normal_init(ks[2], (num_experts, d_model, d_ff), 1.0, dtype),
        "w_down": normal_init(ks[3], (num_experts, d_ff, d_model), 1.0, dtype),
    }
    if shared_expert:
        p["shared"] = init_mlp(ks[4], d_model, d_ff, "silu", dtype)
    return p


def capacity(num_tokens, k, num_experts, factor=1.25):
    c = int(math.ceil(num_tokens * k / num_experts * factor))
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_block(params, x, *, experts_per_token, capacity_factor=1.25):
    """x: (B, S, d) -> (y, aux_loss).

    With the ``moelocal`` lever the whole dispatch pipeline (router,
    top-k, sort, gather, scatter) runs per data-shard token GROUP with a
    leading group dim sharded on the batch axes — otherwise GSPMD
    replicates the global argsort/gather chain on every chip. Capacity is
    then per-group (standard expert-parallel semantics)."""
    from repro.runtime.flags import feature
    from repro.runtime.shardctx import current_mesh, resolve_axis, _axis_size
    if feature("moelocal"):
        mesh = current_mesh()
        groups = 1
        if mesh is not None:
            ax = resolve_axis("batch", mesh)
            g = _axis_size(mesh, ax)
            if (x.shape[0] * x.shape[1]) % g == 0:
                groups = g
        if groups > 1:
            B, S, d = x.shape
            xg = x.reshape(groups, B * S // groups, 1, d)
            xg = shard(xg, "batch", None, None, None)
            y, aux = jax.vmap(
                lambda xs: _moe_dispatch(params, xs,
                                         experts_per_token=experts_per_token,
                                         capacity_factor=capacity_factor,
                                         local=True))(xg)
            y = shard(y, "batch", None, None, None)
            return y.reshape(B, S, d), aux.mean()
    return _moe_dispatch(params, x, experts_per_token=experts_per_token,
                         capacity_factor=capacity_factor)


def _moe_dispatch(params, x, *, experts_per_token, capacity_factor=1.25,
                  local=False):
    B, S, d = x.shape
    E = params["w_gate"].shape[0]
    k = experts_per_token
    T = B * S
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ params["router"])      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                        # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance aux loss (Switch/Mixtral style) ----
    me = probs.mean(axis=0)                                    # mean router prob
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (T * k))                                         # token fraction
    aux = E * jnp.sum(me * ce)

    # ---- sort-based capacity dispatch ----
    C = capacity(T, k, E, capacity_factor)
    e_flat = idx.reshape(-1)                                   # (T*k,)
    g_flat = gate.reshape(-1)
    tok_flat = jnp.arange(T * k, dtype=jnp.int32) // k
    order = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[order]
    tok_sorted = tok_flat[order]
    g_sorted = g_flat[order]
    counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k, dtype=jnp.int32) - starts[e_sorted]
    keep = pos < C
    slot = jnp.where(keep, e_sorted * C + pos, E * C)          # overflow -> drop

    from repro.runtime.flags import feature
    ex_in = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(xf[tok_sorted])
    ex_in = ex_in[: E * C].reshape(E, C, d)
    if local:
        pass  # constraints applied on the vmapped group dim by the caller
    elif feature("moe2d"):
        ex_in = shard(ex_in, None, None, "fsdp")   # contract d per-shard
    else:
        ex_in = shard(ex_in, "expert", None, None)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ex_in, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", ex_in, params["w_up"])
    y_e = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    if local:
        pass
    elif feature("moe2d"):
        # keep the OUTPUT d-sharded: the f-contraction all-reduces tiny
        # (E,C,d/16) activations instead of all-gathering w_down's d dim
        y_e = shard(y_e, None, None, "fsdp")
    else:
        y_e = shard(y_e, "expert", None, None)

    y_pad = jnp.concatenate(
        [y_e.reshape(E * C, d), jnp.zeros((1, d), y_e.dtype)], axis=0)
    y_sorted = y_pad[jnp.where(keep, slot, E * C)]
    contrib = y_sorted * jnp.where(keep, g_sorted, 0.0)[:, None].astype(y_sorted.dtype)
    y = jnp.zeros((T, d), x.dtype).at[tok_sorted].add(contrib)
    y = y.reshape(B, S, d)

    if "shared" in params:
        y = y + mlp(params["shared"], x, "silu")
    return y, aux
