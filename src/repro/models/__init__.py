from repro.models.transformer import Model, build_model  # noqa: F401
