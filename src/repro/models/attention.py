"""GQA attention: RoPE, sliding window, blockwise-flash prefill/train path
(pure-JAX online softmax over KV blocks), and KV-cache decode path.

The blockwise path is the XLA fallback; on real TPU the decode hot-spot
dispatches to ``repro.kernels.flash_decode`` (validated in interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import normal_init
from repro.runtime.flags import feature, probe_mode
from repro.runtime.shardctx import shard

NEG_INF = -1e30


def init_attention(key, d_model, num_heads, num_kv_heads, head_dim, dtype,
                   cross=False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": normal_init(ks[0], (d_model, num_heads, head_dim), 1.0, dtype),
        "wk": normal_init(ks[1], (d_model, num_kv_heads, head_dim), 1.0, dtype),
        "wv": normal_init(ks[2], (d_model, num_kv_heads, head_dim), 1.0, dtype),
        "wo": normal_init(ks[3], (num_heads, head_dim, d_model), 1.0, dtype),
    }
    return p


def apply_rope(x, positions, theta):
    """x: (B, S, N, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, :, None].astype(jnp.float32) * freqs  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _block_mask(qpos, kpos, causal, window):
    """qpos: (qb,), kpos: (kb,) -> (qb, kb) validity."""
    valid = kpos[None, :] >= 0
    if causal:
        valid &= kpos[None, :] <= qpos[:, None]
    if window:
        valid &= kpos[None, :] > qpos[:, None] - window
    return valid


def _banded_attention(q, k, v, *, window, scale, q_block=512):
    """§Perf lever: sliding-window attention that GATHERS only the KV band
    per Q block — cuts attention FLOPs from O(S^2) to O(S * window)
    (mixtral prefill_32k: 32768 -> ~4608 per row). Causal self-attention
    only (aligned q/kv)."""
    B, Sq, H, hd = q.shape
    Kv = k.shape[2]
    R = H // Kv
    qb = min(q_block, Sq)
    pad = (-Sq) % qb
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // qb
    band = (window // qb + 2) * qb          # covers (qs - window, qs + qb)
    band = min(band, k.shape[1])
    flat = feature("gqa_flat")

    def one_block(qi, q_blk):
        qs = qi * qb
        start = jnp.clip(qs + qb - band, 0, k.shape[1] - band)
        k_b = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
        v_b = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
        kpos = start + jnp.arange(band)
        qpos = qs + jnp.arange(qb)
        kv_, r_ = Kv, R
        if flat:
            k_b = shard(jnp.repeat(k_b, R, axis=2), "batch", None, "model",
                        None)
            v_b = shard(jnp.repeat(v_b, R, axis=2), "batch", None, "model",
                        None)
            kv_, r_ = H, 1
        qg = q_blk.reshape(B, qb, kv_, r_, hd) * jnp.asarray(scale, q.dtype)
        s = jnp.einsum("bqkrh,bskh->bkrqs", qg, k_b,
                       preferred_element_type=jnp.float32)
        valid = (kpos[None, :] <= qpos[:, None]) & \
                (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkrqs,bskh->bkrqh", p.astype(v_b.dtype), v_b,
                         preferred_element_type=jnp.float32)
        return out.transpose(0, 3, 1, 2, 4).reshape(B, qb, H, hd)

    qg_blocks = q.reshape(B, nq, qb, H, hd).transpose(1, 0, 2, 3, 4)
    if probe_mode():
        outs = [one_block(i, qg_blocks[i]) for i in range(nq)]
        out = jnp.stack(outs)
    else:
        _, out = jax.lax.scan(
            lambda c, xs: (c, one_block(xs[0], xs[1])), None,
            (jnp.arange(nq), qg_blocks))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, nq * qb, H, hd)
    return out[:, :Sq].astype(q.dtype)


def flash_attention(q, k, v, *, causal, window=0, q_positions=None,
                    kv_positions=None, q_block=512, kv_block=512):
    """Blockwise online-softmax attention.

    q: (B, Sq, H, hd); k, v: (B, Skv, Kv, hd). H = Kv * R (GQA).
    Returns (B, Sq, H, hd).
    """
    B, Sq, H, hd = q.shape
    Skv, Kv = k.shape[1], k.shape[2]
    R = H // Kv
    scale = hd ** -0.5

    if q_positions is None:
        q_positions = jnp.arange(Sq)
    if kv_positions is None:
        kv_positions = jnp.arange(Skv)

    if feature("banded") and window and causal and Skv > window:
        return _banded_attention(q, k, v, window=window, scale=scale,
                                 q_block=q_block)

    if feature("seqpar"):
        # one q block (the q dim is model-sharded by the caller); the
        # blockwise online softmax runs over KV only.
        q_block = Sq

    if feature("gqa_flat"):
        # §Perf lever: repeat K/V to H flat heads so the head dim shards
        # even when Kv < model-axis size (Kv-grouped einsums force score
        # replication there). K/V activation cost x R, but sharded /16.
        k = jnp.repeat(k, R, axis=2)
        v = jnp.repeat(v, R, axis=2)
        k = shard(k, "batch", None, "model", None)
        v = shard(v, "batch", None, "model", None)
        q = shard(q, "batch", None, "model", None)
        Kv, R = H, 1

    if probe_mode():
        # single-shot masked attention: identical matmul FLOPs to the
        # blockwise path, no while loops -> exact cost_analysis.
        qg = q.reshape(B, Sq, Kv, R, hd) * jnp.asarray(scale, q.dtype)
        s = jnp.einsum("bqkrh,bskh->bkrqs", qg, k,
                       preferred_element_type=jnp.float32)
        mask = _block_mask(q_positions, kv_positions, causal, window)
        s = jnp.where(mask[None, None, None, :, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkrqs,bskh->bkrqh", p.astype(v.dtype), v,
                         preferred_element_type=jnp.float32)
        return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)

    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    # pad to block multiples
    pq = (-Sq) % q_block
    pk = (-Skv) % kv_block
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pq), constant_values=-1)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pk), constant_values=-1)
    nq = q.shape[1] // q_block
    nk = k.shape[1] // kv_block

    qg = q.reshape(B, nq, q_block, Kv, R, hd).transpose(1, 0, 2, 3, 4, 5)
    qp = q_positions.reshape(nq, q_block)
    kg = k.reshape(B, nk, kv_block, Kv, hd).transpose(1, 0, 2, 3, 4)
    vg = v.reshape(B, nk, kv_block, Kv, hd).transpose(1, 0, 2, 3, 4)
    kp = kv_positions.reshape(nk, kv_block)

    def q_step(_, qx):
        q_i, qp_i = qx  # (B,qb,Kv,R,hd), (qb,)
        q_i = q_i * jnp.asarray(scale, q_i.dtype)

        def kv_step(carry, kx):
            m, l, acc = carry
            k_j, v_j, kp_j = kx
            # bf16 MXU matmul, fp32 accumulation
            s = jnp.einsum("bqkrh,bskh->bkrqs", q_i, k_j,
                           preferred_element_type=jnp.float32)
            mask = _block_mask(qp_i, kp_j, causal, window)
            s = jnp.where(mask[None, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkrqs,bskh->bkrqh", p.astype(v_j.dtype), v_j,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Kv, R, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Kv, R, q_block), jnp.float32)
        a0 = jnp.zeros((B, Kv, R, q_block, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kg, vg, kp))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.transpose(0, 3, 1, 2, 4)  # (B,qb,Kv,R,hd)

    _, outs = jax.lax.scan(q_step, None, (qg, qp))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * q_block, H, hd)
    return out[:, :Sq].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, window=0):
    """Single-token attention against a (possibly seq-sharded) KV cache.

    q: (B, 1, H, hd); k_cache/v_cache: (B, S, Kv, hd); cache_len: scalar or
    (B,) number of valid cache entries. New token attends to cache[:len].
    """
    B, _, H, hd = q.shape
    S, Kv = k_cache.shape[1], k_cache.shape[2]
    R = H // Kv
    scale = hd ** -0.5
    qg = q.reshape(B, Kv, R, hd) * jnp.asarray(scale, q.dtype)
    s = jnp.einsum("bkrh,bskh->bkrs", qg, k_cache,
                   preferred_element_type=jnp.float32)
    pos = jnp.arange(S)
    cache_len = jnp.asarray(cache_len)
    clen = cache_len if cache_len.ndim else cache_len[None].repeat(B)
    valid = pos[None, :] < clen[:, None]
    if window:
        valid &= pos[None, :] >= clen[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    out = jnp.einsum("bkrs,bskh->bkrh", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out / p.sum(axis=-1, keepdims=True)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def attention_block(params, x, *, num_kv_heads, rope_theta, causal=True,
                    window=0, positions=None, kv_x=None, use_rope=True):
    """Full attention sub-block (projections + flash). kv_x for cross-attn."""
    B, S, _ = x.shape
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", src, params["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", src, params["wv"])
    if positions is None:
        positions = jnp.arange(S)
    if use_rope and kv_x is None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    if feature("seqpar"):
        # sequence-parallel attention: shard QUERY rows over the model
        # axis (head-count agnostic); K/V replicate over model (small).
        q = shard(q, "batch", "model", None, None)
        k = shard(k, "batch", None, None, None)
        v = shard(v, "batch", None, None, None)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_positions=positions if kv_x is None else None,
                          kv_positions=positions if kv_x is None else None)
    if feature("seqpar"):
        out = shard(out, "batch", "model", None, None)
    return jnp.einsum("bsnh,nhd->bsd", out, params["wo"])


def decode_attention_block(params, x, k_cache, v_cache, cache_len, *,
                           rope_theta, window=0, use_rope=True,
                           update_cache=True):
    """Decode sub-block: project 1 token, append to cache, attend.

    With the ``ringkv`` lever active and a cache sized to the sliding
    window, the cache is a ring buffer: K carries RoPE from its true
    position, so scores stay correct and no window mask is needed —
    the ring structurally IS the window. Returns (out, new caches).
    """
    B = x.shape[0]
    S_cache = k_cache.shape[1]
    ring = feature("ringkv") and window and S_cache == window
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"])
    pos = jnp.full((B, 1), cache_len, dtype=jnp.int32)
    if use_rope:
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    write_at = jax.lax.rem(cache_len, S_cache) if ring else cache_len
    if update_cache:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), write_at, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), write_at, axis=1)
    if ring:
        valid = jnp.minimum(cache_len + 1, S_cache)
        out = decode_attention(q, k_cache, v_cache, valid, window=0)
    else:
        out = decode_attention(q, k_cache, v_cache, cache_len + 1,
                               window=window)
    out = jnp.einsum("bsnh,nhd->bsd", out, params["wo"])
    return out, k_cache, v_cache
