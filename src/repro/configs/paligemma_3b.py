"""PaliGemma-3B — gemma LM consuming SigLIP patch embeddings; the vision
tower + projector are a STUB (input_specs provides 256 patch embeddings).
[arXiv:2407.07726]"""
from repro.configs.base import ArchConfig, register

PALIGEMMA_3B = register(ArchConfig(
    name="paligemma-3b",
    family="vlm",
    source="arXiv:2407.07726",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,           # gemma-2b MQA
    d_ff=16384,
    vocab_size=257_216,
    head_dim=256,
    frontend="vision",
    frontend_tokens=256,      # 224px / 14 SigLIP patches
    act="gelu",
    tie_embeddings=True,
))
