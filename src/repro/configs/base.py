"""Architecture configuration system.

Every assigned architecture is expressed as an ``ArchConfig``. The full
configs are exercised only through the dry-run (ShapeDtypeStruct, no
allocation); CPU smoke tests use ``reduced()`` variants of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# Block kinds used by the transformer assembler.
ATTN = "attn"          # attention + MLP block (dense)
MOE = "moe"            # attention + MoE block
MAMBA = "mamba"        # Mamba2 (SSD) block
SHARED_ATTN = "shared_attn"  # weight-shared full transformer block (zamba2)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    source: str                 # citation (paper / model card)
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1          # every n-th block is MoE (llama4 interleaves)
    shared_expert: bool = False

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256        # SSD chunk length

    # --- hybrid (zamba2): shared transformer block every n mamba blocks ---
    hybrid_attn_every: int = 0

    # --- attention variants ---
    sliding_window: int = 0     # 0 = full causal attention
    global_attn_every: int = 0  # llama4 iRoPE: every n-th layer global

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_tokens: int = 0     # fixed frame count from the audio frontend

    # --- modality frontend stub ---
    frontend: Optional[str] = None   # None | "audio" | "vision"
    frontend_tokens: int = 0         # patch/frame embeddings prepended

    # --- misc ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"           # silu (SwiGLU) | gelu (plain MLP)
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def block_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kinds, in order."""
        kinds = []
        for i in range(self.num_layers):
            if self.family == "ssm":
                kinds.append(MAMBA)
            elif self.family == "hybrid":
                if self.hybrid_attn_every and i % self.hybrid_attn_every == 0:
                    kinds.append(SHARED_ATTN)
                kinds.append(MAMBA)
            elif self.num_experts > 0 and (i % self.moe_every) == self.moe_every - 1:
                kinds.append(MOE)
            else:
                kinds.append(ATTN)
        return tuple(kinds)

    def supports_long_context(self) -> bool:
        """Sub-quadratic attention available -> long_500k is runnable."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    def supports_decode(self) -> bool:
        return True  # all assigned archs are decoders or enc-dec

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.num_heads, 4)
        n_kv = min(self.num_kv_heads, n_heads)
        # keep the GQA ratio flavour: at least 1 kv head
        n_kv = max(1, min(n_kv, n_heads))
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=2,
            d_model=d_model,
            num_heads=n_heads,
            num_kv_heads=n_kv,
            head_dim=64,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            moe_every=1 if self.num_experts else self.moe_every,
            ssm_state=min(self.ssm_state, 32) if self.ssm_state else 0,
            ssm_chunk=32,
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_tokens=16 if self.encoder_tokens else 0,
            frontend_tokens=8 if self.frontend_tokens else 0,
            dtype="float32",
        )

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (matches the model builders)."""
        hd = self.resolved_head_dim
        d = self.d_model
        attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd + self.num_heads * hd * d
        if self.act == "silu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff + self.d_ff + d
        norms = 2 * d
        total = 0
        for kind in self.block_kinds():
            if kind == ATTN:
                total += attn + mlp + norms
            elif kind == MOE:
                router = d * self.num_experts
                experts = self.num_experts * 3 * d * self.d_ff
                shared = 3 * d * self.d_ff if self.shared_expert else 0
                total += attn + router + experts + shared + norms
            elif kind == MAMBA:
                total += self._mamba_params()
            elif kind == SHARED_ATTN:
                pass  # counted once below
        if self.family == "hybrid" and self.hybrid_attn_every:
            total += attn + mlp + norms  # single shared copy
        if self.encoder_layers:
            total += self.encoder_layers * (attn + mlp + norms)       # enc self-attn
            total += len(self.block_kinds()) * (attn + d)             # cross-attn per dec layer
        total += self.vocab_size * d                                  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d                              # lm head
        total += d                                                    # final norm
        return total

    def _mamba_params(self) -> int:
        d = self.d_model
        d_inner = self.ssm_expand * d
        nheads = d_inner // self.ssm_head_dim
        in_proj = d * (2 * d_inner + 2 * nheads * self.ssm_state + nheads)
        conv = self.ssm_conv_width * (d_inner + 2 * nheads * self.ssm_state)
        out = d_inner * d
        extra = 2 * nheads + d_inner  # A_log, D, dt_bias-ish + norm
        return in_proj + conv + out + extra + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.num_experts == 0:
            return self.param_count()
        full = self.param_count()
        n_moe = sum(1 for k in self.block_kinds() if k == MOE)
        dead = n_moe * (self.num_experts - self.experts_per_token) * 3 * self.d_model * self.d_ff
        return full - dead


_REGISTRY = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    # import side-effect registration
    from repro import configs as _c  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():
    from repro import configs as _c  # noqa: F401
    return sorted(_REGISTRY)
