"""Zamba2-1.2B — hybrid: Mamba2 backbone + a single weight-SHARED full
transformer block applied periodically. [arXiv:2411.15242]"""
from repro.configs.base import ArchConfig, register

ZAMBA2_1_2B = register(ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=38,            # mamba blocks
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,          # shared block is MHA
    d_ff=8192,
    vocab_size=32_000,
    head_dim=64,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    hybrid_attn_every=6,      # shared transformer block every 6 mamba blocks
    act="silu",
    tie_embeddings=True,
))
