"""Mamba2-130M — attention-free SSM with state-space duality (SSD).
[arXiv:2405.21060]"""
from repro.configs.base import ArchConfig, register

MAMBA2_130M = register(ArchConfig(
    name="mamba2-130m",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=24,
    d_model=768,
    num_heads=0,              # attention-free
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv_width=4,
    tie_embeddings=True,
))
