"""The paper's own three models (Table I, from the MLPerf Tiny benchmark).

These are the FAITHFUL reproduction targets: the federated meta-learning
experiments (Figs. 1-6, Tables II-IV) run on these, exactly as the paper
does. They are plain pytree models (not ArchConfig transformers).

| task                        | type            | params (paper) |
|-----------------------------|-----------------|----------------|
| Sine-wave example           | fully connected | 1,153          |
| Keywords spotting (4 cls)   | convolutional   | 19,812         |
| Omniglot (5 cls)            | convolutional   | 113,733        |
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class PaperModelConfig:
    name: str
    kind: str                    # "mlp" | "conv"
    input_shape: Tuple[int, ...]
    num_outputs: int
    hidden: Tuple[int, ...] = ()
    channels: Tuple[int, ...] = ()
    loss: str = "mse"            # "mse" | "xent"


# 1 -> 32 -> 32 -> 1 fully connected (paper Fig. 1): exactly 1,153 params.
SINE_MLP = PaperModelConfig(
    name="sine_mlp", kind="mlp", input_shape=(1,), num_outputs=1,
    hidden=(32, 32), loss="mse")

# Keywords spotting: 4-class audio classifier over MFCC maps (49x10x1,
# MLPerf-Tiny DS-CNN style). Channel widths chosen to land near the
# paper's 19,812 parameters (we hit 20,612; the paper does not publish
# the exact topology).
KWS_CONV = PaperModelConfig(
    name="kws_conv", kind="conv", input_shape=(49, 10, 1), num_outputs=4,
    channels=(32, 32, 32), loss="xent")

# Omniglot: 5-way classifier, the canonical Reptile 4xconv(stride2) net on
# 28x28x1 glyphs. 113,093 params vs the paper's 113,733 (head-size delta;
# topology not published).
OMNIGLOT_CONV = PaperModelConfig(
    name="omniglot_conv", kind="conv", input_shape=(28, 28, 1), num_outputs=5,
    channels=(64, 64, 64, 64), loss="xent")

PAPER_MODELS = {m.name: m for m in (SINE_MLP, KWS_CONV, OMNIGLOT_CONV)}
