"""StarCoder2-15B — dense code model, GQA, RoPE, 4k sliding window.
[arXiv:2402.19173]"""
from repro.configs.base import ArchConfig, register

STARCODER2_15B = register(ArchConfig(
    name="starcoder2-15b",
    family="dense",
    source="arXiv:2402.19173",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab_size=49_152,
    head_dim=128,
    sliding_window=4096,
    rope_theta=100_000.0,
    act="gelu",
))
