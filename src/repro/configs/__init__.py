"""Config registry: importing this package registers all assigned archs."""
from repro.configs.base import ArchConfig, get_arch, list_archs, register
from repro.configs.shapes import SHAPES, InputShape, get_shape
from repro.configs.paper_models import PAPER_MODELS, PaperModelConfig

# side-effect registration of the 10 assigned architectures
from repro.configs.llama4_maverick_400b_a17b import LLAMA4_MAVERICK
from repro.configs.mamba2_130m import MAMBA2_130M
from repro.configs.mixtral_8x22b import MIXTRAL_8X22B
from repro.configs.whisper_tiny import WHISPER_TINY
from repro.configs.tinyllama_1_1b import TINYLLAMA_1_1B
from repro.configs.glm4_9b import GLM4_9B
from repro.configs.zamba2_1_2b import ZAMBA2_1_2B
from repro.configs.minicpm_2b import MINICPM_2B
from repro.configs.paligemma_3b import PALIGEMMA_3B
from repro.configs.starcoder2_15b import STARCODER2_15B

ALL_ARCHS = (
    "llama4-maverick-400b-a17b", "mamba2-130m", "mixtral-8x22b",
    "whisper-tiny", "tinyllama-1.1b", "glm4-9b", "zamba2-1.2b",
    "minicpm-2b", "paligemma-3b", "starcoder2-15b",
)

__all__ = [
    "ArchConfig", "get_arch", "list_archs", "register", "SHAPES",
    "InputShape", "get_shape", "PAPER_MODELS", "PaperModelConfig",
    "ALL_ARCHS",
]
