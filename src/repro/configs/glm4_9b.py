"""GLM-4 9B — dense, RoPE, aggressive GQA (kv=2). [hf:THUDM/glm-4-9b]"""
from repro.configs.base import ArchConfig, register

GLM4_9B = register(ArchConfig(
    name="glm4-9b",
    family="dense",
    source="hf:THUDM/glm-4-9b",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=151_552,
    head_dim=128,
    rope_theta=10_000.0,
    act="silu",
))
