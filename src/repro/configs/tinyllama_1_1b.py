"""TinyLlama 1.1B — llama2-architecture dense model, GQA kv=4.
[arXiv:2401.02385]"""
from repro.configs.base import ArchConfig, register

TINYLLAMA_1_1B = register(ArchConfig(
    name="tinyllama-1.1b",
    family="dense",
    source="arXiv:2401.02385",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=5632,
    vocab_size=32_000,
    head_dim=64,
    rope_theta=10_000.0,
    act="silu",
))
