"""MiniCPM-2B — llama-like dense arch trained with the WSD schedule
(the schedule lives in repro.optim.schedules.wsd). [arXiv:2404.06395]"""
from repro.configs.base import ArchConfig, register

MINICPM_2B = register(ArchConfig(
    name="minicpm-2b",
    family="dense",
    source="arXiv:2404.06395",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122_753,
    head_dim=64,
    act="silu",
    tie_embeddings=True,
))
