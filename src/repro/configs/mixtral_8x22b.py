"""Mixtral 8x22B — 8 experts top-2 MoE, GQA, sliding-window attention.
[arXiv:2401.04088]"""
from repro.configs.base import ArchConfig, register

MIXTRAL_8X22B = register(ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    source="arXiv:2401.04088",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32_768,
    head_dim=128,
    num_experts=8,
    experts_per_token=2,
    moe_every=1,              # every block is MoE
    sliding_window=4096,
    rope_theta=1_000_000.0,
    act="silu",
))
