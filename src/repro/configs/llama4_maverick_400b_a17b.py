"""Llama-4 Maverick 400B-A17B — MoE, 128 experts top-1, interleaved MoE
layers, iRoPE-style chunked-local attention with periodic global layers.
[hf:meta-llama/Llama-4-Scout-17B-16E (family card); Maverick variant]"""
from repro.configs.base import ArchConfig, register

LLAMA4_MAVERICK = register(ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    head_dim=128,
    num_experts=128,
    experts_per_token=1,
    moe_every=2,              # Maverick interleaves dense / MoE blocks
    shared_expert=True,       # Llama-4 routed + shared expert
    sliding_window=8192,      # chunked local attention (iRoPE)
    global_attn_every=4,      # every 4th layer attends globally (NoPE)
    rope_theta=500_000.0,
    act="silu",
))
