"""Whisper-tiny — encoder-decoder audio model; mel+conv frontend is a STUB
(input_specs provides precomputed 1500-frame embeddings). [arXiv:2212.04356]"""
from repro.configs.base import ArchConfig, register

WHISPER_TINY = register(ArchConfig(
    name="whisper-tiny",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=4,             # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51_865,
    head_dim=64,
    encoder_layers=4,
    encoder_tokens=1500,      # 30 s of audio at 50 Hz after conv frontend
    frontend="audio",
    frontend_tokens=1500,
    act="gelu",
    tie_embeddings=True,
))
