"""Continuous-batching adaptation server over a meta-learned init.

The paper's deployment story: a NEW device checks in with a few support
samples, fine-tunes the broadcast phi for k steps, and is scored (or
scores itself) on its own query data. At fleet scale those check-ins
arrive as a ragged stream — every request has its own k — so the server
keeps a fixed set of B padded SLOTS on device and advances all of them
a few steps per jitted TICK (the engine's validity-mask idiom): retired
slots are refilled from a host FIFO between ticks by scattering fresh
rows with an out-of-range-drop index, never changing any shape, so the
whole serve loop is ONE jit trace per (adapter, slot-count, shapes)
config (`AdaptationServer.trace_count`, same observable as
`_BlockRunner.trace_count`).

phi rides the tick as a traced argument: swapping the init (say, a
`checkpoint.load_params` snapshot, or a newer phi mid-stream) reuses
the existing trace and executable.

Numerics: `offline_adapt` is the independently-jitted one-shot
reference — each request's served params/query loss are bit-for-bit
equal to the offline call at the same slot width (tests/test_serving.py
pins fp32 and int8; the int8 route is additionally exactly equal to the
engine's scalar TifedStrategy epochs).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class AdaptResult:
    """One retired request: its id, adapted-params query loss, how many
    adaptation steps it ran, and submit->retire wall latency. ``params``
    is the adapted fp32 pytree when the server runs with
    ``return_params=True`` (off by default: shipping params home every
    tick costs a device sync per slot row)."""
    rid: int
    query_loss: float
    steps: int
    latency_s: float
    params: Optional[Dict] = None


class _Pending:
    __slots__ = ("rid", "sx", "sy", "qx", "qy", "k", "t_submit")

    def __init__(self, rid, sx, sy, qx, qy, k, t_submit):
        self.rid, self.sx, self.sy = rid, sx, sy
        self.qx, self.qy, self.k = qx, qy, k
        self.t_submit = t_submit


def _bcast(mask, like):
    return mask.reshape(mask.shape + (1,) * (like.ndim - 1))


class AdaptationServer:
    """Serve a ragged stream of client-adaptation requests against one
    meta-learned init.

    - ``adapter``: a `serving.adapters` adapter (Fp32Adapter /
      TifedAdapter) — defines prepare / unit-step / query-loss math.
    - ``slots``: continuous-batching width B (vmap width of every tick).
    - ``k_max``: static per-request step budget bound (requests ask for
      any ``1 <= k <= k_max``).
    - ``steps_per_tick``: adaptation steps advanced per jitted tick —
      the batching/latency knob (small = fresher admission, large =
      fewer host round-trips).
    - ``metrics``: optional `metering.MetricsTracker`; admission,
      retirement latency/steps, and tick counts flow into it.

    Usage::

        server = AdaptationServer(phi, adapter, slots=64, k_max=10)
        server.submit(sx, sy, qx, qy, k=7)
        results = server.drain()       # list of AdaptResult

    Request/query shapes are fixed by the FIRST submitted request (the
    padded-slot state is allocated then); later requests must match.
    """

    def __init__(self, phi, adapter, *, slots: int, k_max: int,
                 steps_per_tick: int = 4, metrics=None,
                 return_params: bool = False):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if k_max < 1:
            raise ValueError(f"k_max must be >= 1, got {k_max}")
        if steps_per_tick < 1:
            raise ValueError(
                f"steps_per_tick must be >= 1, got {steps_per_tick}")
        self.adapter = adapter
        self.B = int(slots)
        self.k_max = int(k_max)
        self.steps_per_tick = int(steps_per_tick)
        self.metrics = metrics
        self.return_params = bool(return_params)
        self.trace_count = 0
        self.ticks = 0
        self._pack = adapter.pack_phi(phi)
        self._queue: collections.deque = collections.deque()
        self._inflight: Dict[int, _Pending] = {}
        self._free = list(range(self.B))      # ascending slot ids
        self._next_rid = 0
        self._state = None                    # allocated on first submit
        self._shapes = None
        self._jit_tick = jax.jit(self._tick_fn, donate_argnums=(1,))

    # -- device program ----------------------------------------------------
    def _tick_fn(self, pack, state, refill):
        self.trace_count += 1                 # runs at trace time only
        B = self.B
        ad = self.adapter
        idx = refill["idx"]                   # (B,) int32; idx == B drops
        fresh = jax.vmap(lambda sx, sy: ad.prepare(pack, sx, sy))(
            refill["sx"], refill["sy"])
        slots = jax.tree.map(
            lambda s, f: s.at[idx].set(f, mode="drop"),
            state["slots"], fresh)
        qx = state["qx"].at[idx].set(refill["qx"], mode="drop")
        qy = state["qy"].at[idx].set(refill["qy"], mode="drop")
        k = state["k"].at[idx].set(refill["k"], mode="drop")
        step = state["step"].at[idx].set(0, mode="drop")
        active = state["active"].at[idx].set(True, mode="drop")
        qloss = state["qloss"].at[idx].set(0.0, mode="drop")

        unit = jax.vmap(lambda s, t: ad.unit_step(pack, s, t))
        for _ in range(self.steps_per_tick):
            live = active & (step < k)
            new_slots, _ = unit(slots, step)
            slots = jax.tree.map(
                lambda n, o: jnp.where(_bcast(live, n), n, o),
                new_slots, slots)
            step = step + live.astype(jnp.int32)

        finished = active & (step >= k)
        ql = jax.vmap(lambda s, x, y: ad.query_loss(pack, s, x, y))(
            slots, qx, qy)
        qloss = jnp.where(finished, ql, qloss)
        active = active & ~finished
        new_state = {"slots": slots, "qx": qx, "qy": qy, "k": k,
                     "step": step, "active": active, "qloss": qloss}
        params = (jax.vmap(lambda s: ad.finish(pack, s))(slots)
                  if self.return_params else ())
        return new_state, finished, qloss, step, params

    def _alloc_state(self, req: _Pending):
        self._shapes = {"sx": req.sx.shape, "sy": req.sy.shape,
                        "qx": req.qx.shape, "qy": req.qy.shape}
        B = self.B
        sx0 = jnp.zeros((B,) + req.sx.shape, jnp.float32)
        sy0 = jnp.zeros((B,) + req.sy.shape, jnp.float32)
        slot_shapes = jax.eval_shape(
            jax.vmap(lambda sx, sy: self.adapter.prepare(
                self._pack, sx, sy)), sx0, sy0)
        self._state = {
            "slots": jax.tree.map(
                lambda a: jnp.zeros(a.shape, a.dtype), slot_shapes),
            "qx": jnp.zeros((B,) + req.qx.shape, jnp.float32),
            "qy": jnp.zeros((B,) + req.qy.shape, jnp.float32),
            "k": jnp.zeros((B,), jnp.int32),
            "step": jnp.zeros((B,), jnp.int32),
            "active": jnp.zeros((B,), bool),
            "qloss": jnp.zeros((B,), jnp.float32),
        }

    # -- host control loop -------------------------------------------------
    def submit(self, sx, sy, qx, qy, k: int) -> int:
        """Enqueue one adaptation request (FIFO). Returns its id."""
        sx = np.asarray(sx, np.float32)
        sy = np.asarray(sy, np.float32)
        qx = np.asarray(qx, np.float32)
        qy = np.asarray(qy, np.float32)
        k = int(k)
        if not 1 <= k <= self.k_max:
            raise ValueError(f"k={k} outside [1, {self.k_max}]")
        if k > sx.shape[0] and self.adapter.name == "fp32":
            raise ValueError(f"k={k} online steps need >= k support "
                             f"samples, got {sx.shape[0]}")
        if self._shapes is not None:
            for name, arr in (("sx", sx), ("sy", sy), ("qx", qx),
                              ("qy", qy)):
                if arr.shape != self._shapes[name]:
                    raise ValueError(
                        f"{name} shape {arr.shape} != server shape "
                        f"{self._shapes[name]} (fixed by first request)")
        rid = self._next_rid
        self._next_rid += 1
        req = _Pending(rid, sx, sy, qx, qy, k, time.monotonic())
        self._queue.append(req)
        if self.metrics is not None:
            self.metrics.on_admit(
                sx.nbytes + sy.nbytes + qx.nbytes + qy.nbytes)
        return rid

    def _build_refill(self):
        B = self.B
        sh = self._shapes
        refill = {
            "idx": np.full((B,), B, np.int32),
            "sx": np.zeros((B,) + sh["sx"], np.float32),
            "sy": np.zeros((B,) + sh["sy"], np.float32),
            "qx": np.zeros((B,) + sh["qx"], np.float32),
            "qy": np.zeros((B,) + sh["qy"], np.float32),
            "k": np.zeros((B,), np.int32),
        }
        n = 0
        while self._queue and self._free:
            req = self._queue.popleft()
            slot = self._free.pop(0)          # lowest free slot first
            refill["idx"][n] = slot
            refill["sx"][n] = req.sx
            refill["sy"][n] = req.sy
            refill["qx"][n] = req.qx
            refill["qy"][n] = req.qy
            refill["k"][n] = req.k
            self._inflight[slot] = req
            n += 1
        return refill

    def step(self) -> List[AdaptResult]:
        """Admit waiting requests into free slots, run ONE tick, retire
        finished slots. Returns this tick's retired results."""
        if not self._queue and not self._inflight:
            return []
        if self._state is None:
            self._alloc_state(self._queue[0])
        refill = self._build_refill()
        self._state, finished, qloss, step, params = self._jit_tick(
            self._pack, self._state, refill)
        self.ticks += 1
        if self.metrics is not None:
            self.metrics.on_tick()
        fin = np.asarray(finished)
        results: List[AdaptResult] = []
        if fin.any():
            ql = np.asarray(qloss)
            st = np.asarray(step)
            now = time.monotonic()
            for slot in np.nonzero(fin)[0]:
                slot = int(slot)
                req = self._inflight.pop(slot)
                self._free.append(slot)
                p = None
                if self.return_params:
                    p = jax.tree.map(lambda a: np.asarray(a[slot]),
                                     params)
                res = AdaptResult(rid=req.rid, query_loss=float(ql[slot]),
                                  steps=int(st[slot]),
                                  latency_s=now - req.t_submit, params=p)
                results.append(res)
                if self.metrics is not None:
                    self.metrics.on_retire(res.latency_s, res.steps)
            self._free.sort()
        return results

    def drain(self) -> List[AdaptResult]:
        """Tick until the queue and every slot are empty."""
        results: List[AdaptResult] = []
        while self._queue or self._inflight:
            results.extend(self.step())
        return results

    @property
    def idle(self) -> bool:
        return not self._queue and not self._inflight

    def set_params(self, phi) -> None:
        """Swap the served init. phi is a tick ARGUMENT, so this reuses
        the existing trace (trace_count stays put). Requires an idle
        server — in-flight requests must finish against their phi."""
        if not self.idle:
            raise RuntimeError("cannot swap phi with requests in flight")
        self._pack = self.adapter.pack_phi(phi)

    def reset(self) -> None:
        """Drop all queued work and re-zero the slot state (the jit
        trace and phi pack survive)."""
        self._queue.clear()
        self._inflight.clear()
        self._free = list(range(self.B))
        self.ticks = 0
        if self._state is not None:
            self._state = jax.tree.map(
                lambda a: jnp.zeros(a.shape, a.dtype), self._state)


def offline_adapt(phi, adapter, requests, *, slots: int,
                  k_max: int) -> List[Dict]:
    """One-shot reference adaptation: pack ``requests`` (dicts with
    sx/sy/qx/qy/k) FIFO into width-``slots`` groups and run each group's
    full k_max-step masked scan under ONE separately-jitted vmap. This
    is the parity oracle for `AdaptationServer` — same unit-step math,
    same slot width, independent trace — and the cheapest way to adapt
    a request set you already hold in memory.

    Returns one {"params", "query_loss", "steps"} dict per request, in
    submission order.
    """
    if not requests:
        return []
    pack = adapter.pack_phi(phi)
    B = int(slots)

    @jax.jit
    def run(pack, sx, sy, qx, qy, k, active):
        fresh = jax.vmap(lambda x, y: adapter.prepare(pack, x, y))(sx, sy)
        step = jnp.zeros((B,), jnp.int32)
        unit = jax.vmap(lambda s, t: adapter.unit_step(pack, s, t))
        slots_ = fresh
        for _ in range(k_max):
            live = active & (step < k)
            new_slots, _ = unit(slots_, step)
            slots_ = jax.tree.map(
                lambda n, o: jnp.where(_bcast(live, n), n, o),
                new_slots, slots_)
            step = step + live.astype(jnp.int32)
        ql = jax.vmap(lambda s, x, y: adapter.query_loss(pack, s, x, y))(
            slots_, qx, qy)
        params = jax.vmap(lambda s: adapter.finish(pack, s))(slots_)
        return params, ql, step

    out: List[Dict] = []
    for g0 in range(0, len(requests), B):
        group = requests[g0:g0 + B]
        pad = B - len(group)
        stack = {f: np.stack([np.asarray(r[f], np.float32)
                              for r in group] +
                             [np.zeros_like(np.asarray(group[0][f],
                                                       np.float32))] * pad)
                 for f in ("sx", "sy", "qx", "qy")}
        kv = np.asarray([r["k"] for r in group] + [0] * pad, np.int32)
        active = np.asarray([True] * len(group) + [False] * pad)
        params, ql, step = run(pack, stack["sx"], stack["sy"],
                               stack["qx"], stack["qy"], kv, active)
        ql = np.asarray(ql)
        step = np.asarray(step)
        for i in range(len(group)):
            out.append({
                "params": jax.tree.map(lambda a, i=i: np.asarray(a[i]),
                                       params),
                "query_loss": float(ql[i]),
                "steps": int(step[i])})
    return out
