from repro.serving.adapters import Fp32Adapter, TifedAdapter  # noqa: F401
from repro.serving.server import (AdaptationServer, AdaptResult,  # noqa: F401
                                  offline_adapt)
