"""Per-request adaptation routines behind the AdaptationServer.

An adapter defines the three pure functions the server vmaps across its
slots — everything else (admission, masking, retirement) is shared:

- ``prepare(phi_pack, sx, sy)``: one request's support set -> the slot
  pytree the unit step carries (params init + prepared support);
- ``unit_step(phi_pack, slot, step)``: ONE adaptation step at cursor
  ``step`` (an online-SGD sample step for fp32, a full int8 DFA epoch
  for tifed) -> (new slot, step loss);
- ``query_loss(phi_pack, slot, qx, qy)``: score the adapted params on
  the request's query set;
- ``finish(phi_pack, slot)``: slot -> the fp32 params pytree handed
  back to the client (dequantized for tifed).

``phi_pack = pack_phi(phi)`` is whatever adapter-specific device form
of the meta-learned init the tick consumes; it is passed as a traced
ARGUMENT to the server's jitted tick, so swapping phi (e.g. for a
checkpoint-loaded init) reuses the same trace.

Numerics contract (pinned in tests/test_serving.py): a served request
is bit-for-bit the one-shot vmapped offline adaptation at the same slot
width (`serving.offline_adapt`); the int8 route is additionally exactly
equal to the engine's scalar `TifedStrategy` epochs (integer-valued
fp32 math is vmap-width invariant), while the fp32 route matches the
scalar `finetune_online` API to ~1e-6 (vmap changes fp reduction
lowering — same contract as the engine's 1-vs-N-device parity).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies import (TIFED_ACT, TIFED_EX, TIFED_SERR,
                                   _tifed_constants)
from repro.kernels import ref as kref
from repro.models.paper_nets import relu_mlp_loss


def _default_use_pallas() -> bool:
    return jax.default_backend() == "tpu"


@dataclasses.dataclass(frozen=True)
class Fp32Adapter:
    """TinyReptile deployment loop: one SGD step per streamed support
    sample (`core.meta.finetune_online`'s exact update math), vmapped
    across slots. ``use_pallas`` routes the weight update through the
    fused `kernels/online_sgd.py` kernel (None = TPU only)."""
    loss_fn: Callable
    lr: float = 0.01
    use_pallas: Optional[bool] = None

    name = "fp32"

    def pack_phi(self, phi):
        return phi

    def prepare(self, phi, sx, sy):
        return {"params": phi, "sx": sx, "sy": sy}

    def unit_step(self, phi, slot, step):
        del phi
        i = jnp.clip(step, 0, slot["sx"].shape[0] - 1)
        x = jax.lax.dynamic_index_in_dim(slot["sx"], i, keepdims=False)
        y = jax.lax.dynamic_index_in_dim(slot["sy"], i, keepdims=False)
        batch = {"x": x[None], "y": y[None]}
        loss, g = jax.value_and_grad(self.loss_fn)(slot["params"], batch)
        use_pallas = (_default_use_pallas() if self.use_pallas is None
                      else self.use_pallas)
        if use_pallas:
            from repro.kernels import ops as kops
            params = kops.tree_online_sgd(slot["params"], g,
                                          jnp.float32(self.lr))
        else:
            params = jax.tree.map(lambda w, gg: w - self.lr * gg,
                                  slot["params"], g)
        return {**slot, "params": params}, loss

    def query_loss(self, phi, slot, qx, qy):
        del phi
        return self.loss_fn(slot["params"], {"x": qx, "y": qy})

    def finish(self, phi, slot):
        del phi
        return slot["params"]


@dataclasses.dataclass(frozen=True)
class TifedAdapter:
    """TIFeD int8 deployment loop: one adaptation step = one integer
    DFA epoch over the request's full support set (layer-cyclic, the
    same `kernels/ref.dfa_int8_epoch` / Pallas `online_sgd_int8` math
    the TifedStrategy trains with), so a tifed-trained phi adapts on
    exactly the arithmetic the training run promised. phi must sit on
    the tifed integer grid (`tifed_requantize` output / a tifed run's
    params). ``support`` and ``k_max`` are fixed per adapter: the
    quantized-scale prologue folds 1/support into the bit-shift rate
    and the per-epoch dither planes are baked for epochs < k_max.
    """
    support: int
    k_max: int
    lr_shift: int = 6
    feedback_seed: int = 0
    use_pallas: Optional[bool] = None

    name = "tifed"

    def pack_phi(self, phi):
        """Quantize phi once onto the int8/accumulator grids; the pack
        rides the tick as traced arrays (phi-swap keeps the trace)."""
        for i in range(3):
            if f"w{i}" not in phi or f"b{i}" not in phi:
                raise ValueError(
                    "TifedAdapter expects the paper MLP pytree "
                    f"{{w0,b0,w1,b1,w2,b2}}; got keys {sorted(phi)}")
        f32 = jnp.float32
        ws, ew = [], []
        for i in range(3):
            q, e = kref.quantize_pow2(phi[f"w{i}"])
            ws.append(q)
            ew.append(e)
        ea = (TIFED_EX, TIFED_ACT, TIFED_ACT)
        sacc = [ew[i] + ea[i] for i in range(3)]
        bs = [jnp.clip(jnp.round(phi[f"b{i}"]
                                 * jnp.exp2(-sacc[i].astype(f32))),
                       -kref.BIAS_MAX, kref.BIAS_MAX) for i in range(3)]
        n = self.support
        lrs = self.lr_shift + int(np.floor(np.log2(n)))
        scales = {
            "f0": jnp.exp2((sacc[0] - TIFED_ACT).astype(f32)),
            "f1": jnp.exp2((sacc[1] - TIFED_ACT).astype(f32)),
            "fe": jnp.exp2((sacc[2] - TIFED_SERR).astype(f32)),
            "floss": jnp.exp2(2.0 * sacc[2].astype(f32)) / n,
            "ftw": tuple(
                jnp.exp2((ea[i] + TIFED_SERR - ew[i] - lrs).astype(f32))
                for i in range(3)),
            "ftb": tuple(
                jnp.exp2((TIFED_SERR - sacc[i] - lrs).astype(f32))
                for i in range(3)),
        }
        dims = (phi["w0"].shape[0], phi["w0"].shape[1],
                phi["w1"].shape[1], phi["w2"].shape[1])
        fb_np, dith_np = _tifed_constants(self.feedback_seed, self.k_max,
                                          dims)
        return {"ws": tuple(ws), "bs": tuple(bs),
                "ew": tuple(e.astype(f32) for e in ew),
                "sacc": tuple(s.astype(f32) for s in sacc),
                "scales": scales,
                "fb": tuple(jnp.asarray(f) for f in fb_np),
                "dith": tuple(jnp.asarray(d) for d in dith_np)}

    def prepare(self, pack, sx, sy):
        f32 = jnp.float32
        din = pack["ws"][0].shape[0]
        dout = pack["ws"][2].shape[1]
        x = sx.reshape(-1, din)
        y = sy.reshape(x.shape[0], dout)
        xq = jnp.clip(jnp.round(x * 2.0 ** -TIFED_EX), -127.0, 127.0)
        yal = jnp.round(y * jnp.exp2(-pack["sacc"][2].astype(f32)))
        return {"cw": pack["ws"], "cb": pack["bs"], "xq": xq, "yal": yal}

    def unit_step(self, pack, slot, step):
        e = jnp.clip(step, 0, self.k_max - 1)
        layer = (e % 3).astype(jnp.int32)
        dither = tuple(
            jax.lax.dynamic_index_in_dim(d, e, keepdims=False)
            for d in pack["dith"])
        use_pallas = (_default_use_pallas() if self.use_pallas is None
                      else self.use_pallas)
        if use_pallas:
            from repro.kernels import ops as kops
            epoch_fn = kops.dfa_epoch_int8
            cw = tuple(w.astype(jnp.int8) for w in slot["cw"])
            cb = tuple(b.astype(jnp.int32) for b in slot["cb"])
            xq = slot["xq"].astype(jnp.int8)
            yal = slot["yal"].astype(jnp.int32)
            nw, nb, loss = epoch_fn(cw, cb, xq, yal, layer, pack["fb"],
                                    dither, pack["scales"])
            nw = tuple(w.astype(jnp.float32) for w in nw)
            nb = tuple(b.astype(jnp.float32) for b in nb)
        else:
            nw, nb, loss = kref.dfa_int8_epoch(
                slot["cw"], slot["cb"], slot["xq"], slot["yal"], layer,
                pack["fb"], dither, pack["scales"])
        return {**slot, "cw": nw, "cb": nb}, loss

    def _dequantize(self, pack, slot):
        out = {}
        for i in range(3):
            out[f"w{i}"] = slot["cw"][i] * jnp.exp2(pack["ew"][i])
            out[f"b{i}"] = slot["cb"][i] * jnp.exp2(pack["sacc"][i])
        return out

    def query_loss(self, pack, slot, qx, qy):
        """fp32 ReLU-MLP MSE on the dequantized adapted params — the
        network the integer arithmetic computes (same eval route as the
        engine's tifed runs)."""
        return relu_mlp_loss(self._dequantize(pack, slot),
                             {"x": qx, "y": qy})

    def finish(self, pack, slot):
        return self._dequantize(pack, slot)
