"""Memory metering: the analytic MCU model of paper Table II, plus a
LIVE host+device meter (:class:`MemoryMeter`) used by the fleet-scale
pool benchmarks to prove a run's residency is O(cohort), not O(N).

Analytic device-memory model — simulates the MCU resource accounting of
paper Table II (the hardware gate this container cannot measure directly).

Accounting per algorithm, for a model with P parameter bytes, per-sample
activation footprint A, per-sample data size D, support size S:

  Reptile (batched):  P (weights) + P (batch-accumulated grads)
                      + S*D (stored support set)
                      + S*A (batched activations for the update)
  TinyReptile (ours): P + 1*D + 1*A + delta-buffer
                      (stream: ONE sample alive; the gradient is applied
                       layer-by-layer during backprop — the TinyOL trick
                       [Ren et al. 2021] — so no full gradient buffer)

Calibration against paper Table II (S=32): sine 10.5 KB vs paper 10.7 KB
(Reptile) and 5.2 KB vs 4.8 KB (TinyReptile); omniglot 3.2 MB vs 3.7 MB
and 0.53 MB vs 0.65 MB. The KWS row differs in absolute terms because the
paper's pipeline stores raw 1-s waveforms per sample where we account the
preprocessed 49x10 MFCC map; the reduction factor direction matches.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

from repro.configs.paper_models import PaperModelConfig


BYTES_F32 = 4


def _per_sample_activation_elems(cfg: PaperModelConfig) -> int:
    if cfg.kind == "mlp":
        dims = list(cfg.hidden) + [cfg.num_outputs]
        return int(np.prod(cfg.input_shape)) + sum(dims)
    h, w, c = cfg.input_shape
    total = h * w * c
    for cout in cfg.channels:
        h, w = (h + 1) // 2, (w + 1) // 2
        total += h * w * cout
    return total + cfg.num_outputs


def _param_count(cfg: PaperModelConfig) -> int:
    if cfg.kind == "mlp":
        dims = (int(np.prod(cfg.input_shape)),) + cfg.hidden + (cfg.num_outputs,)
        return sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))
    n = 0
    cin = cfg.input_shape[-1]
    h, w = cfg.input_shape[0], cfg.input_shape[1]
    for cout in cfg.channels:
        n += 9 * cin * cout + cout
        cin = cout
        h, w = (h + 1) // 2, (w + 1) // 2
    return n + h * w * cin * cfg.num_outputs + cfg.num_outputs


def _max_layer_width(cfg: PaperModelConfig) -> int:
    if cfg.kind == "mlp":
        return max(cfg.hidden + (cfg.num_outputs,))
    h, w = cfg.input_shape[0], cfg.input_shape[1]
    widths = []
    for cout in cfg.channels:
        h, w = (h + 1) // 2, (w + 1) // 2
        widths.append(h * w * cout)
    return max(widths + [cfg.num_outputs])


def algorithm_memory_report(cfg: PaperModelConfig,
                            support: int = 32) -> Dict[str, float]:
    P = _param_count(cfg) * BYTES_F32
    A = _per_sample_activation_elems(cfg) * BYTES_F32
    D = (int(np.prod(cfg.input_shape)) + 1) * BYTES_F32
    reptile = 2 * P + support * (D + A)
    # TinyOL-style in-place update: backprop delta buffer, no grad copy
    tiny = P + (D + A) + 2 * _max_layer_width(cfg) * BYTES_F32
    return {
        "model": cfg.name,
        "params": _param_count(cfg),
        "param_bytes": P,
        "reptile_bytes": reptile,
        "tinyreptile_bytes": tiny,
        "reduction_factor": reptile / tiny,
        "fits_arduino_256kb_reptile": reptile <= 256 * 1024,
        "fits_arduino_256kb_tinyreptile": tiny <= 256 * 1024,
    }


def _statm_rss_bytes() -> int:
    """Current resident set size from /proc/self/statm (Linux; 0 where
    the proc filesystem is unavailable)."""
    try:
        with open("/proc/self/statm") as f:
            pages = int(f.read().split()[1])
        import os
        return pages * os.sysconf("SC_PAGESIZE")
    except (OSError, IndexError, ValueError):
        return 0


def _peak_rss_bytes() -> int:
    """Process-lifetime peak RSS via getrusage (ru_maxrss is KiB on
    Linux, bytes on macOS; 0 where the resource module is missing)."""
    try:
        import resource
        import sys
        peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        return peak if sys.platform == "darwin" else peak * 1024
    except (ImportError, ValueError):
        return 0


def _device_bytes() -> Dict[str, int]:
    """Per-device live allocation from ``Device.memory_stats()`` — {}
    on backends that don't report (CPU)."""
    out: Dict[str, int] = {}
    try:
        import jax
        for d in jax.devices():
            stats = d.memory_stats()
            if stats and "bytes_in_use" in stats:
                out[str(d)] = int(stats["bytes_in_use"])
    except Exception:
        pass
    return out


@dataclass
class MemoryMeter:
    """Live host+device memory meter for residency proofs.

    ``ru_maxrss`` is a process-LIFETIME high-water mark, so a meter
    started mid-process cannot see a peak below the history it inherits;
    the meter therefore reports both the baseline at construction and
    the growth since. Usage::

        meter = MemoryMeter()          # baseline snapshot
        ... run the workload ...
        rep = meter.report()
        rep["host_current_growth_bytes"]   # RSS now vs baseline
        rep["host_peak_growth_bytes"]      # lifetime peak vs baseline RSS
        rep["device_peak_bytes"]           # max over sampled device use

    ``sample()`` may be called any number of times mid-run to tighten
    the device high-water mark (CPU backends report no device stats and
    yield 0 there).
    """
    baseline_rss: int = 0
    baseline_peak: int = 0
    _device_peak: int = 0

    def __post_init__(self):
        self.baseline_rss = _statm_rss_bytes()
        self.baseline_peak = _peak_rss_bytes()
        self.sample()

    def sample(self) -> None:
        dev = _device_bytes()
        if dev:
            self._device_peak = max(self._device_peak,
                                    max(dev.values()))

    def report(self) -> Dict[str, int]:
        self.sample()
        current = _statm_rss_bytes()
        peak = _peak_rss_bytes()
        return {
            "host_baseline_bytes": self.baseline_rss,
            "host_current_bytes": current,
            "host_current_growth_bytes": max(current - self.baseline_rss,
                                             0),
            "host_peak_bytes": peak,
            "host_peak_growth_bytes": max(peak - self.baseline_rss, 0),
            "device_peak_bytes": self._device_peak,
        }
