from repro.metering.memory import algorithm_memory_report  # noqa: F401
from repro.metering.tracker import MetricsTracker  # noqa: F401
