from repro.metering.memory import algorithm_memory_report  # noqa: F401
