"""Pluggable run metrics: counters, gauges, series, and latency
distributions, plus the engine/serving hooks that feed them.

One `MetricsTracker` instance follows one run (a ``run_federated`` call
or an ``AdaptationServer`` lifetime). It is pure host-side bookkeeping:
every hook takes already-materialized Python/NumPy values, so attaching
a tracker never changes what the device computes — ``run_federated``
with ``tracker=None`` and with a tracker produce bit-for-bit identical
params/history (pinned in tests/test_metrics.py).

Closes ROADMAP item 2's leftover: the per-round metrics tracker
(losses, transport bills, staleness histograms, trace/cache counters)
and the JAX-profiler hook (``profile_dir=`` brackets the run in
``jax.profiler.start_trace``/``stop_trace``).
"""
from __future__ import annotations

import collections
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class MetricsTracker:
    """Counters + gauges + per-round series + observation distributions.

    Vocabulary (all names are free-form dotted strings):

    - ``inc(name, v)``        monotonic counter (transport bytes, retires)
    - ``gauge(name, v)``      last-value-wins (trace counts, cache sizes)
    - ``record(name, step, v)`` per-step series (round -> loss)
    - ``observe(name, v)``    distribution sample (latencies, steps)

    ``percentiles``/``histogram`` summarize observations; ``summary()``
    returns one JSON-able dict of everything. ``profile_dir=`` arms the
    JAX profiler: ``start_profile()``/``stop_profile()`` bracket a
    region (the engine calls them around the scan loop when the tracker
    is attached).
    """

    def __init__(self, profile_dir: Optional[str] = None):
        self.profile_dir = profile_dir
        self.counters: Dict[str, float] = collections.defaultdict(float)
        self.gauges: Dict[str, float] = {}
        self.series: Dict[str, List[Tuple[int, float]]] = (
            collections.defaultdict(list))
        self.observations: Dict[str, List[float]] = (
            collections.defaultdict(list))
        self._profiling = False

    # -- primitives --------------------------------------------------------
    def inc(self, name: str, value: float = 1.0) -> None:
        self.counters[name] += value

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = value

    def record(self, name: str, step: int, value: float) -> None:
        self.series[name].append((int(step), float(value)))

    def observe(self, name: str, value: float) -> None:
        self.observations[name].append(float(value))

    # -- summaries ---------------------------------------------------------
    def percentiles(self, name: str,
                    qs: Sequence[float] = (50.0, 95.0, 99.0)) -> Dict:
        """{"p50": ..., "p95": ..., ...} over the observations of
        ``name`` (empty dict when nothing was observed)."""
        vals = self.observations.get(name)
        if not vals:
            return {}
        pct = np.percentile(np.asarray(vals, np.float64), qs)
        return {f"p{q:g}": float(p) for q, p in zip(qs, pct)}

    def histogram(self, name: str, bins: int = 10) -> Dict:
        vals = self.observations.get(name)
        if not vals:
            return {"counts": [], "edges": []}
        counts, edges = np.histogram(np.asarray(vals, np.float64),
                                     bins=bins)
        return {"counts": counts.tolist(), "edges": edges.tolist()}

    def series_values(self, name: str) -> List[float]:
        return [v for _, v in self.series.get(name, [])]

    def summary(self) -> Dict:
        out = {"counters": dict(self.counters), "gauges": dict(self.gauges),
               "series": {k: list(v) for k, v in self.series.items()},
               "distributions": {}}
        for name, vals in self.observations.items():
            out["distributions"][name] = {
                "count": len(vals),
                "mean": float(np.mean(vals)),
                **self.percentiles(name)}
        return out

    # -- JAX profiler hook -------------------------------------------------
    def start_profile(self) -> None:
        if self.profile_dir is None or self._profiling:
            return
        import jax
        jax.profiler.start_trace(self.profile_dir)
        self._profiling = True

    def stop_profile(self) -> None:
        if not self._profiling:
            return
        import jax
        jax.profiler.stop_trace()
        self._profiling = False

    # -- engine hooks (run_federated) --------------------------------------
    # All hooks receive host values the engine already has (or fetches
    # only when a tracker is attached); none of them feed anything back,
    # so the training trajectory is tracker-independent by construction.
    def on_run_start(self) -> None:
        self._run_t0 = time.perf_counter()
        self.start_profile()

    def on_block(self, start: int, end: int, losses) -> None:
        """Per-round inner losses of one executed scan block
        (``losses[i]`` is round ``start + i``'s cohort-weighted loss)."""
        losses = np.asarray(losses)
        for i, lo in enumerate(losses):
            self.record("round.inner_loss", start + i, float(lo))
        self.inc("engine.rounds", end - start)
        self.inc("engine.blocks")

    def on_transport(self, round_end: int, delta_bytes: int,
                     total_bytes: int) -> None:
        self.inc("transport.bytes", delta_bytes)
        self.record("transport.cum_bytes", round_end, float(total_bytes))

    def on_eval(self, ev: Dict) -> None:
        self.record("eval.query_loss", ev["round"],
                    float(ev["query_loss"]))
        self.inc("engine.evals")

    def on_run_end(self, runner_stats: Optional[Dict] = None,
                   staleness=None) -> None:
        self.stop_profile()
        self.gauge("engine.wall_s",
                   time.perf_counter() - getattr(self, "_run_t0",
                                                 time.perf_counter()))
        if runner_stats:
            for k, v in runner_stats.items():
                self.gauge(f"runner_cache.{k}", float(v))
        if staleness is not None:
            for s in np.asarray(staleness).ravel():
                self.observe("pool.staleness", float(s))

    # -- serving hooks (AdaptationServer) ----------------------------------
    def on_admit(self, request_bytes: int) -> None:
        self.inc("serve.admitted")
        self.inc("serve.request_bytes", request_bytes)

    def on_retire(self, latency_s: float, steps: int) -> None:
        self.inc("serve.retired")
        self.observe("serve.latency_ms", 1e3 * latency_s)
        self.observe("serve.steps", steps)

    def on_tick(self) -> None:
        self.inc("serve.ticks")
