"""Persistent client identities: the ClientPool layer.

TinyReptile's serial protocol assumes each device KEEPS its data and
state across check-ins, but the engine historically resampled anonymous
cohort slots every round. This module makes the population first-class:

- ``ClientPool``: N persistent clients. Client ``i``'s task is
  materialized ONCE from ``(seed, i)`` (``TaskDistribution.
  materialize_client`` — the stable per-device data shard TinyMetaFed
  measures its savings against), and each client owns a private data
  RNG stream advanced only at its own check-ins, so what client ``i``
  sees depends only on how often IT has checked in — not on who else
  was scheduled.
- ``PoolState``: the cross-round per-client state pytree (last-seen
  round, staleness counters, check-in counts, and the FedBuff pending
  update buffer). It lives on device, rides the block runner's scan
  carry next to phi, and is gathered/scattered by the round's cohort
  indices INSIDE the scan — zero per-round host dispatches, one jit
  trace per (strategy, beta, channel, schedule-shape, pool-shape)
  config.
- ``BufferedAggregation``: FedBuff-style async aggregation
  [Nguyen et al. 2022]. Check-ins append their (possibly stale) updates
  to a server-side buffer; the buffer flushes every ``buffer_size``
  arrivals through the strategy's existing ``server_aggregate_weighted``
  hook with staleness-discounted weights (default 1/sqrt(1+tau), the
  FedBuff polynomial discount).
- ``AvailabilityProcess`` policies: check-in schedules beyond i.i.d. —
  ``DiurnalAvailability`` (fleet-wide sine: devices sleep at night) and
  ``MarkovAvailability`` (two-state sticky on/off chains per client).
  Rounds where NOBODY checks in are valid=False scan no-ops: the server
  idles, nobody trains, nobody pays transport.

``UniformSampling`` with ``pool=None`` keeps the engine's legacy
bit-for-bit fast path (pinned in tests/test_pool.py): the pool layer is
strictly additive.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import SamplingPolicy
from repro.data.tasks import TaskDistribution

#: stream-key constants: keep a pool's task seeds, per-client data
#: streams, and shape probes on disjoint rng streams.
_DATA_STREAM = 0x5EED
_PROBE_STREAM = 0x9


def default_staleness_weight(tau):
    """FedBuff's polynomial staleness discount: s(tau) = 1/sqrt(1+tau).
    ``tau`` is a traced f32 array of "rounds since this update was
    computed" at flush time; fresh updates weigh 1, a 3-round-stale
    update half that."""
    return 1.0 / jnp.sqrt(1.0 + tau)


@dataclasses.dataclass(frozen=True)
class PoolState:
    """Cross-round per-client state, on device, scanned next to phi.

    The first three fields are per-POOL-CLIENT arrays (length N = pool
    size), gathered/scattered by the round's ``ClientSchedule.cohort``
    indices inside the block-runner scan; the last four are the
    server-side FedBuff buffer (None on unbuffered runs).

    last_seen:   (N,) i32 — absolute round of the client's most recent
                 check-in; -1 for clients that never checked in.
    staleness:   (N,) i32 — the gap (in rounds) between the client's two
                 most recent check-ins, stamped AT check-in: a client
                 seen at rounds 3 and 7 carries staleness 4. First
                 check-ins count from round -1 (pool creation). This is
                 the per-device staleness the paper's serial protocol
                 implies and the example prints per client.
    checkins:    (N,) i32 — total rounds the client participated in.
    buf_updates: result-shaped tree, each leaf with a leading
                 (buffer_size + cohort - 1,) capacity axis — the pending
                 (not yet applied) client updates. None when unbuffered.
    buf_round:   (capacity,) i32 — the absolute round each buffered
                 update was computed at (its staleness tag). None when
                 unbuffered.
    buf_count:   () i32 — arrivals since the last flush (valid buffer
                 prefix length). None when unbuffered.
    flushes:     () i32 — how many times the buffer flushed into phi.
                 None when unbuffered.

    Mesh runs (run_federated(mesh=...)) use the SHARDED layout built by
    ``ClientPool.init_state(shards=...)``: per-client arrays padded to a
    multiple of the shard count and split over the "clients" mesh axis,
    the buffer stored as per-shard slabs, and ``buf_count`` a (shards,)
    array of local fill levels (the flush predicate reduces it with
    psum). ``pool_state_specs`` names each field's PartitionSpec.
    """
    last_seen: object
    staleness: object
    checkins: object
    buf_updates: object = None
    buf_round: object = None
    buf_count: object = None
    flushes: object = None

    _FIELDS = ("last_seen", "staleness", "checkins", "buf_updates",
               "buf_round", "buf_count", "flushes")


jax.tree_util.register_pytree_node(
    PoolState,
    lambda s: (tuple(getattr(s, f) for f in PoolState._FIELDS), None),
    lambda _, children: PoolState(*children))


@dataclasses.dataclass(frozen=True)
class BufferedAggregation:
    """FedBuff-style buffered async aggregation [Nguyen et al. 2022].

    Instead of folding each round's cohort into phi immediately, every
    check-in APPENDS its update to a server-side buffer; once
    ``buffer_size`` updates have arrived the whole buffer flushes
    through the strategy's ``server_aggregate_weighted`` hook in one
    step, weighted by ``staleness_fn(tau)`` (tau = flush round minus the
    round each update was computed at) and normalized. Between flushes
    phi does not move — buffered updates are genuinely stale when
    applied, which is exactly the async-fleet regime FedBuff models.

    Arrivals land at round granularity: a round that pushes the count to
    ``buffer_size`` or beyond flushes the ENTIRE buffer (up to
    buffer_size + cohort - 1 updates), so the capacity is static and the
    flush is a single ``lax.cond`` inside the scan — no host round-trip.

    ``flush_staleness`` makes the flush AVAILABILITY-AWARE: in a sparse
    fleet (diurnal troughs, small cohorts) a count-only buffer can sit
    on updates for many rounds, so the flush predicate additionally
    fires whenever HOLDING the buffer one more round would let its
    oldest update reach the staleness deadline — i.e. the buffer
    flushes at the end of round r if ``r - min(buffered rounds) + 1 >=
    flush_staleness`` (one extra comparison OR-ed into the existing
    ``lax.cond`` predicate, still zero host round-trips). No buffered
    update is ever applied with staleness >= flush_staleness, so a
    deadline of 1 degenerates to flush-on-every-arrival (every update
    applied the round it was computed, tau = 0).

    buffer_size:  flush threshold K, in client arrivals (>= 1).
    staleness_fn: traced discount tau -> weight; default FedBuff's
                  1/sqrt(1+tau). Must be a hashable callable (module
                  function or frozen partial) for the runner cache.
    flush_staleness: optional staleness deadline (rounds, >= 1); None
                  (default) keeps the count-only FedBuff flush.
    """
    buffer_size: int = 4
    staleness_fn: Callable = default_staleness_weight
    flush_staleness: Optional[int] = None

    def __post_init__(self):
        if not (isinstance(self.buffer_size, int) and self.buffer_size >= 1):
            raise ValueError(f"buffer_size must be an int >= 1, got "
                             f"{self.buffer_size!r}")
        if self.flush_staleness is not None and not (
                isinstance(self.flush_staleness, int)
                and self.flush_staleness >= 1):
            raise ValueError(f"flush_staleness must be None or an int >= 1, "
                             f"got {self.flush_staleness!r}")


class ClientPool:
    """A population of ``size`` persistent clients over a task
    distribution.

    Host side (this class): each client's STABLE task is materialized
    lazily from ``(seed, i)`` via ``task_dist.materialize_client``; each
    client owns a private data rng advanced only at its own check-ins,
    so its sample sequence is a function of its check-in count alone.
    ``sample_cohort_block`` draws a block of cohort data in strict block
    order (the prefetch thread's determinism contract).

    Device side: ``init_state`` builds the :class:`PoolState` pytree the
    engine threads through the block-runner scan.
    """

    def __init__(self, task_dist: TaskDistribution, size: int,
                 seed: int = 0):
        if size < 1:
            raise ValueError(f"pool size must be >= 1, got {size!r}")
        self.task_dist = task_dist
        self.size = int(size)
        self.seed = int(seed)
        self._tasks: Dict[int, object] = {}
        self._rngs: Dict[int, np.random.Generator] = {}
        self._templates: Dict[tuple, tuple] = {}

    def __repr__(self):
        return (f"ClientPool({type(self.task_dist).__name__}, "
                f"size={self.size}, seed={self.seed})")

    def client_task(self, i: int):
        """Pool client ``i``'s stable task (materialized once, cached)."""
        if not 0 <= i < self.size:
            raise IndexError(f"client {i} out of range for pool of "
                             f"{self.size}")
        if i not in self._tasks:
            self._tasks[i] = self.task_dist.materialize_client(
                i, seed=self.seed)
        return self._tasks[i]

    def _client_rng(self, i: int) -> np.random.Generator:
        if i not in self._rngs:
            self._rngs[i] = np.random.default_rng(
                [self.seed, _DATA_STREAM, i])
        return self._rngs[i]

    def host_state(self) -> Dict:
        """JSON-able snapshot of the pool's mutable host state: the
        per-client data rng streams that have advanced past their seed
        (one bit-generator state per client that ever checked in).
        Tasks and templates are NOT captured — they are pure functions
        of ``(seed, i)`` and rematerialize on demand. Paired with
        :meth:`load_host_state` for bit-for-bit checkpoint resume."""
        return {"rngs": {str(i): copy.deepcopy(g.bit_generator.state)
                         for i, g in self._rngs.items()}}

    def load_host_state(self, state: Dict) -> None:
        """Restore a :meth:`host_state` snapshot: every captured client
        rng resumes mid-stream; clients absent from the snapshot fall
        back to their fresh seeded stream (they had never checked in)."""
        self._rngs = {}
        for key, st in (state or {}).get("rngs", {}).items():
            g = np.random.default_rng()
            g.bit_generator.state = st
            self._rngs[int(key)] = g

    def _template(self, support: int, data_mode: str):
        """Zero-cost shape probe: one throwaway draw from client 0's
        task on a DEDICATED rng stream (never touches the per-client
        data streams), cached per (support, data_mode)."""
        key = (support, data_mode)
        if key not in self._templates:
            rng = np.random.default_rng([self.seed, _PROBE_STREAM])
            x, y = self._draw(self.client_task(0), rng, support, data_mode)
            self._templates[key] = (np.zeros_like(x), np.zeros_like(y))
        return self._templates[key]

    @staticmethod
    def _draw(task, rng, support: int, data_mode: str):
        if data_mode == "stream":
            sx, sy = zip(*task.support_stream(rng, support))
            return np.stack(sx), np.stack(sy)
        b = task.support_batch(rng, support)
        return np.asarray(b["x"]), np.asarray(b["y"])

    def sample_cohort_block(self, cohort, participation, support: int,
                            data_mode: str = "batch") -> Dict:
        """Support data for a planned block: for every participating
        (round, slot), draw ``support`` samples from THAT pool client's
        stable task using ITS private rng stream. Scheduled-out slots
        (and whole no-show rounds) stay zero. Called strictly in block
        order, so a client's data stream advances once per check-in —
        deterministic regardless of prefetch depth or who else was
        scheduled."""
        cohort = np.asarray(cohort)
        part = np.asarray(participation, bool)
        rounds, clients = part.shape
        zx, zy = self._template(support, data_mode)
        x = np.zeros((rounds, clients) + zx.shape, zx.dtype)
        y = np.zeros((rounds, clients) + zy.shape, zy.dtype)
        for r in range(rounds):
            for c in range(clients):
                if not part[r, c]:
                    continue
                m = int(cohort[r, c])
                x[r, c], y[r, c] = self._draw(
                    self.client_task(m), self._client_rng(m), support,
                    data_mode)
        return {"x": x, "y": y}

    def init_state(self, phi, cohort_size: int,
                   buffered: Optional[BufferedAggregation] = None,
                   shards: int = 1, template=None) -> PoolState:
        """Fresh device-resident pool state. The FedBuff buffer's static
        capacity is ``buffer_size + cohort_size - 1``: a flush triggers
        at count >= buffer_size, and at most cohort_size arrivals land
        per round on top of a count of at most buffer_size - 1.

        ``template`` (default phi) gives the SHAPES/DTYPES of the
        buffer slots — the strategy's uplink tree
        (``FedStrategy.uplink_template``), so quantized strategies
        stage their native int8 result trees at int8 width.

        ``shards`` > 1 builds the MESH layout (run_federated(mesh=...)):
        the per-client arrays are padded to a multiple of ``shards`` so
        the "clients" mesh axis splits them evenly (padded rows are
        never indexed — cohort indices stay < pool size), and the
        FedBuff buffer becomes per-shard: each shard owns a
        ``buffer_size + local_cohort - 1`` slab (any one shard can hold
        the whole count-threshold backlog plus its own round of
        arrivals, since the flush predicate is on the psum-reduced
        GLOBAL count), with ``buf_count`` a (shards,) array of local
        fill levels. ``shards == 1`` is bit-for-bit the legacy layout
        (scalar ``buf_count``, one contiguous buffer)."""
        if cohort_size % max(shards, 1):
            raise ValueError(f"cohort_size={cohort_size} must be a "
                             f"multiple of shards={shards} (the engine "
                             f"pads the cohort before building state)")
        n = -(-self.size // shards) * shards        # ceil to shard multiple
        last_seen = jnp.full((n,), -1, jnp.int32)
        staleness = jnp.zeros((n,), jnp.int32)
        checkins = jnp.zeros((n,), jnp.int32)
        if buffered is None:
            return PoolState(last_seen, staleness, checkins)
        if shards == 1:
            cap = buffered.buffer_size + cohort_size - 1
            buf_count = jnp.int32(0)
        else:
            cap = shards * (buffered.buffer_size
                            + cohort_size // shards - 1)
            buf_count = jnp.zeros((shards,), jnp.int32)
        buf = jax.tree.map(
            lambda p: jnp.zeros((cap,) + p.shape, p.dtype),
            phi if template is None else template)
        return PoolState(last_seen, staleness, checkins, buf,
                         jnp.zeros((cap,), jnp.int32), buf_count,
                         jnp.int32(0))


def pool_state_specs(state: PoolState, axis: str) -> PoolState:
    """PartitionSpecs mirroring ``state`` for a client-sharded mesh run:
    per-client arrays and the per-shard FedBuff slabs split over the
    ``axis`` mesh axis, the flush counter replicated. Used both as the
    block runner's shard_map in/out specs and (wrapped in
    NamedSharding) as the host-side device_put target."""
    from jax.sharding import PartitionSpec as P
    sharded = P(axis)
    return PoolState(
        last_seen=sharded, staleness=sharded, checkins=sharded,
        buf_updates=(None if state.buf_updates is None else
                     jax.tree.map(lambda _: sharded, state.buf_updates)),
        buf_round=None if state.buf_round is None else sharded,
        buf_count=None if state.buf_count is None else sharded,
        flushes=None if state.flushes is None else P())


@dataclasses.dataclass(frozen=True)
class AvailabilityProcess(SamplingPolicy):
    """Base class for check-in processes over a persistent pool: who is
    AVAILABLE each round is a stochastic process over the N pool
    clients, and the round's cohort is whoever showed up (capped at the
    cohort width by a uniform thinning draw).

    Subclasses implement :meth:`availability` — a (blk, N) boolean
    matrix for rounds [start, end), consuming ``rng`` deterministically
    in block order (the prefetch-parity contract; the engine always
    calls contiguous blocks in order, starting at round 0).

    Rounds where nobody checks in plan an all-False participation row;
    the engine marks them valid=False, so the server idles that round
    (phi and pool state pass through, zero transport billed) — the
    fixed-shape scan never retraces. These policies only make sense
    over a pool: ``plan_schedule`` (the anonymous-cohort hook) raises.
    """
    sampler: str = "reference"

    schedule_kind = "scheduled"

    def availability(self, rng, start: int, end: int,
                     pool_size: int) -> np.ndarray:
        raise NotImplementedError

    def plan_schedule(self, rng, start, end, clients, budget):
        raise ValueError(
            f"{type(self).__name__} schedules PERSISTENT clients; pass "
            f"pool=ClientPool(...) to run_federated (anonymous cohort "
            f"slots have no identity to be available or not)")

    def plan_pool_schedule(self, rng, start, end, clients, budget,
                           pool_size):
        avail = np.asarray(
            self.availability(rng, start, end, pool_size), bool)
        blk = end - start
        assert avail.shape == (blk, pool_size)
        cohort = np.zeros((blk, clients), np.int32)
        part = np.zeros((blk, clients), bool)
        for r in range(blk):
            idx = np.flatnonzero(avail[r])
            if len(idx) > clients:      # more volunteers than slots
                idx = np.sort(rng.choice(idx, size=clients, replace=False))
            m = len(idx)
            cohort[r, :m] = idx
            part[r, :m] = True
        m_per_round = part.sum(axis=1, keepdims=True)
        weights = np.where(
            m_per_round > 0, part / np.maximum(m_per_round, 1), 0.0)
        return {
            "participation": part,
            "local_steps": np.where(part, budget, 0).astype(np.int32),
            "weights": weights.astype(np.float32),
            "cohort": cohort,
        }


@dataclasses.dataclass(frozen=True)
class DiurnalAvailability(AvailabilityProcess):
    """Fleet-wide diurnal check-ins: client ``i`` is available at round
    ``r`` with probability
    ``clip(base + amplitude * sin(2*pi*(r/period + phase_i)), 0, 1)``.

    With the default ``phase_spread=0`` the whole fleet shares one sine
    (everyone's devices sleep at night — the classic diurnal load
    curve, including trough rounds where NOBODY may check in);
    ``phase_spread=1`` staggers phases evenly across clients (a fleet
    spanning all timezones, whose aggregate availability is flat).
    """
    period: int = 24
    base: float = 0.5
    amplitude: float = 0.45
    phase_spread: float = 0.0

    def __post_init__(self):
        if self.period < 1:
            raise ValueError(f"period must be >= 1, got {self.period!r}")
        self._validate_sampler()

    def availability(self, rng, start, end, pool_size):
        r = np.arange(start, end, dtype=np.float64)[:, None]
        phase = (self.phase_spread
                 * np.arange(pool_size, dtype=np.float64)[None, :]
                 / max(pool_size, 1))
        p = np.clip(self.base + self.amplitude
                    * np.sin(2.0 * np.pi * (r / self.period + phase)),
                    0.0, 1.0)
        return rng.uniform(size=p.shape) < p


@dataclasses.dataclass(frozen=True)
class MarkovAvailability(AvailabilityProcess):
    """Two-state (on/off) Markov check-ins per client: an off client
    turns on with probability ``p_on`` each round, an on client turns
    off with ``p_off`` — sticky sessions and dropouts rather than
    i.i.d. coin flips. Long-run availability is the chain's stationary
    rate ``p_on / (p_on + p_off)``; chains start from a stationary draw
    at round 0.

    The chain state must survive across scan blocks: the policy stashes
    the ONE in-flight trajectory (keyed by the rng stream driving it,
    held strongly so the key can never be a recycled object) and
    requires contiguous in-order blocks — exactly how the engine's
    prefetch producer calls it. A fresh run starts at round 0, which
    resets the stash, so one policy instance serves any number of
    sequential runs without growing state.
    """
    p_on: float = 0.3
    p_off: float = 0.15
    #: single-slot chain stash: (rng, pool_size, next_start, state)
    _chain: list = dataclasses.field(default_factory=list, repr=False,
                                     compare=False)

    def __post_init__(self):
        for name in ("p_on", "p_off"):
            v = getattr(self, name)
            if not 0.0 < v <= 1.0:
                raise ValueError(f"{name} must be in (0, 1], got {v!r}")
        self._validate_sampler()

    def availability(self, rng, start, end, pool_size):
        if start == 0:
            self._chain.clear()          # a fresh trajectory begins
            state = rng.uniform(size=pool_size) < (
                self.p_on / (self.p_on + self.p_off))
        elif (self._chain and self._chain[0] is rng
                and self._chain[1] == pool_size
                and self._chain[2] == start):
            state = self._chain[3]
        else:
            raise RuntimeError(
                f"MarkovAvailability needs contiguous in-order blocks "
                f"from one rng stream: got start={start} with no "
                f"matching chain state (blocks must begin at round 0 "
                f"and follow back-to-back)")
        rows = np.zeros((end - start, pool_size), bool)
        for r in range(end - start):
            u = rng.uniform(size=pool_size)
            state = np.where(state, u >= self.p_off, u < self.p_on)
            rows[r] = state
        self._chain[:] = [rng, pool_size, end, state.copy()]
        return rows

    def state_dict(self):
        """The in-flight chain (pool size, next expected block start,
        per-client on/off booleans) — the one piece of policy state the
        restored rng stream alone cannot rebuild, captured into
        round-state checkpoints. {} when no trajectory is in flight."""
        if not self._chain:
            return {}
        return {"pool_size": int(self._chain[1]),
                "next_start": int(self._chain[2]),
                "state": np.asarray(self._chain[3], bool).tolist()}

    def load_state_dict(self, state, rng=None):
        """Prime the chain stash from a ``state_dict`` snapshot so the
        resumed run's first block (``start == next_start``) continues
        the interrupted trajectory; ``rng`` must be the run's restored
        host generator (the stash is keyed by stream identity)."""
        if not state:
            self._chain.clear()
            return
        self._chain[:] = [rng, int(state["pool_size"]),
                          int(state["next_start"]),
                          np.asarray(state["state"], bool)]
