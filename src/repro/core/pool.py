"""Persistent client identities: the ClientPool layer.

TinyReptile's serial protocol assumes each device KEEPS its data and
state across check-ins, but the engine historically resampled anonymous
cohort slots every round. This module makes the population first-class:

- ``ClientPool``: N persistent clients. Client ``i``'s task is
  materialized ONCE from ``(seed, i)`` (``TaskDistribution.
  materialize_client`` — the stable per-device data shard TinyMetaFed
  measures its savings against), and each client owns a private data
  RNG stream advanced only at its own check-ins, so what client ``i``
  sees depends only on how often IT has checked in — not on who else
  was scheduled.
- ``PoolState``: the cross-round per-client state pytree (last-seen
  round, staleness counters, check-in counts, and the FedBuff pending
  update buffer). It lives on device, rides the block runner's scan
  carry next to phi, and is gathered/scattered by the round's cohort
  indices INSIDE the scan — zero per-round host dispatches, one jit
  trace per (strategy, beta, channel, schedule-shape, pool-shape)
  config.
- ``BufferedAggregation``: FedBuff-style async aggregation
  [Nguyen et al. 2022]. Check-ins append their (possibly stale) updates
  to a server-side buffer; the buffer flushes every ``buffer_size``
  arrivals through the strategy's existing ``server_aggregate_weighted``
  hook with staleness-discounted weights (default 1/sqrt(1+tau), the
  FedBuff polynomial discount).
- ``AvailabilityProcess`` policies: check-in schedules beyond i.i.d. —
  ``DiurnalAvailability`` (fleet-wide sine: devices sleep at night) and
  ``MarkovAvailability`` (two-state sticky on/off chains per client).
  Rounds where NOBODY checks in are valid=False scan no-ops: the server
  idles, nobody trains, nobody pays transport.

``UniformSampling`` with ``pool=None`` keeps the engine's legacy
bit-for-bit fast path (pinned in tests/test_pool.py): the pool layer is
strictly additive.
"""
from __future__ import annotations

import collections
import copy
import dataclasses
import os
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import SAMPLERS, SamplingPolicy
from repro.data.tasks import TaskDistribution

#: stream-key constants: keep a pool's task seeds, per-client data
#: streams, and shape probes on disjoint rng streams. _TASK_STREAM is
#: the ``materialize_client`` derivation (data/tasks.py) — the
#: vectorized sampler re-derives it per check-in instead of caching the
#: task object.
_DATA_STREAM = 0x5EED
_PROBE_STREAM = 0x9
_TASK_STREAM = 0x9E37

#: bound on the (support, data_mode) shape-template cache — a run uses
#: one or two keys; the bound only guards pathological callers.
_MAX_TEMPLATES = 16

#: residency of the per-client identity arrays (see ClientPool).
RESIDENCIES = ("device", "host")


def default_staleness_weight(tau):
    """FedBuff's polynomial staleness discount: s(tau) = 1/sqrt(1+tau).
    ``tau`` is a traced f32 array of "rounds since this update was
    computed" at flush time; fresh updates weigh 1, a 3-round-stale
    update half that."""
    return 1.0 / jnp.sqrt(1.0 + tau)


@dataclasses.dataclass(frozen=True)
class PoolState:
    """Cross-round per-client state, on device, scanned next to phi.

    The first three fields are per-POOL-CLIENT arrays (length N = pool
    size), gathered/scattered by the round's ``ClientSchedule.cohort``
    indices inside the block-runner scan; the last four are the
    server-side FedBuff buffer (None on unbuffered runs).

    last_seen:   (N,) i32 — absolute round of the client's most recent
                 check-in; -1 for clients that never checked in.
    staleness:   (N,) i32 — the gap (in rounds) between the client's two
                 most recent check-ins, stamped AT check-in: a client
                 seen at rounds 3 and 7 carries staleness 4. First
                 check-ins count from round -1 (pool creation). This is
                 the per-device staleness the paper's serial protocol
                 implies and the example prints per client.
    checkins:    (N,) i32 — total rounds the client participated in.
    buf_updates: result-shaped tree, each leaf with a leading
                 (buffer_size + cohort - 1,) capacity axis — the pending
                 (not yet applied) client updates. None when unbuffered.
    buf_round:   (capacity,) i32 — the absolute round each buffered
                 update was computed at (its staleness tag). None when
                 unbuffered.
    buf_count:   () i32 — arrivals since the last flush (valid buffer
                 prefix length). None when unbuffered.
    flushes:     () i32 — how many times the buffer flushed into phi.
                 None when unbuffered.

    Mesh runs (run_federated(mesh=...)) use the SHARDED layout built by
    ``ClientPool.init_state(shards=...)``: per-client arrays padded to a
    multiple of the shard count and split over the "clients" mesh axis,
    the buffer stored as per-shard slabs, and ``buf_count`` a (shards,)
    array of local fill levels (the flush predicate reduces it with
    psum). ``pool_state_specs`` names each field's PartitionSpec.
    """
    last_seen: object
    staleness: object
    checkins: object
    buf_updates: object = None
    buf_round: object = None
    buf_count: object = None
    flushes: object = None

    _FIELDS = ("last_seen", "staleness", "checkins", "buf_updates",
               "buf_round", "buf_count", "flushes")


jax.tree_util.register_pytree_node(
    PoolState,
    lambda s: (tuple(getattr(s, f) for f in PoolState._FIELDS), None),
    lambda _, children: PoolState(*children))


@dataclasses.dataclass(frozen=True)
class BufferedAggregation:
    """FedBuff-style buffered async aggregation [Nguyen et al. 2022].

    Instead of folding each round's cohort into phi immediately, every
    check-in APPENDS its update to a server-side buffer; once
    ``buffer_size`` updates have arrived the whole buffer flushes
    through the strategy's ``server_aggregate_weighted`` hook in one
    step, weighted by ``staleness_fn(tau)`` (tau = flush round minus the
    round each update was computed at) and normalized. Between flushes
    phi does not move — buffered updates are genuinely stale when
    applied, which is exactly the async-fleet regime FedBuff models.

    Arrivals land at round granularity: a round that pushes the count to
    ``buffer_size`` or beyond flushes the ENTIRE buffer (up to
    buffer_size + cohort - 1 updates), so the capacity is static and the
    flush is a single ``lax.cond`` inside the scan — no host round-trip.

    ``flush_staleness`` makes the flush AVAILABILITY-AWARE: in a sparse
    fleet (diurnal troughs, small cohorts) a count-only buffer can sit
    on updates for many rounds, so the flush predicate additionally
    fires whenever HOLDING the buffer one more round would let its
    oldest update reach the staleness deadline — i.e. the buffer
    flushes at the end of round r if ``r - min(buffered rounds) + 1 >=
    flush_staleness`` (one extra comparison OR-ed into the existing
    ``lax.cond`` predicate, still zero host round-trips). No buffered
    update is ever applied with staleness >= flush_staleness, so a
    deadline of 1 degenerates to flush-on-every-arrival (every update
    applied the round it was computed, tau = 0).

    buffer_size:  flush threshold K, in client arrivals (>= 1).
    staleness_fn: traced discount tau -> weight; default FedBuff's
                  1/sqrt(1+tau). Must be a hashable callable (module
                  function or frozen partial) for the runner cache.
    flush_staleness: optional staleness deadline (rounds, >= 1); None
                  (default) keeps the count-only FedBuff flush.
    """
    buffer_size: int = 4
    staleness_fn: Callable = default_staleness_weight
    flush_staleness: Optional[int] = None

    def __post_init__(self):
        if not (isinstance(self.buffer_size, int) and self.buffer_size >= 1):
            raise ValueError(f"buffer_size must be an int >= 1, got "
                             f"{self.buffer_size!r}")
        if self.flush_staleness is not None and not (
                isinstance(self.flush_staleness, int)
                and self.flush_staleness >= 1):
            raise ValueError(f"flush_staleness must be None or an int >= 1, "
                             f"got {self.flush_staleness!r}")


class ClientPool:
    """A population of ``size`` persistent clients over a task
    distribution.

    Host side (this class): each client's STABLE task derives from
    ``(seed, i)`` via ``task_dist.materialize_client``, and each
    client's data stream advances only at its own check-ins, so its
    sample sequence is a function of its check-in count alone.
    ``sample_cohort_block`` draws a block of cohort data in strict block
    order (the prefetch thread's determinism contract).

    Two host-identity representations:

    - ``sampler="reference"`` (default, legacy bit-for-bit): one cached
      task object and one live ``np.random.Generator`` per client that
      ever checked in — O(active clients) host objects, generators
      never evictable (their stream state is irreplaceable).
    - ``sampler="vectorized"``: NO per-client host objects. The pool
      keeps ONE ``(N,)`` int32 check-in counter array; client ``i``'s
      ``k``-th check-in draws from the counter-derived streams
      ``default_rng([seed, _TASK_STREAM, i])`` (task params — the
      ``materialize_client`` derivation) and ``default_rng([seed,
      _DATA_STREAM, i, k])`` (data), routed through
      ``TaskDistribution.sample_client_support``. Host memory is
      O(cohort) per round plus the counters; ``host_state()`` shrinks
      from a dict of bit-generator states to the nonzero counters.
      A NEW deterministic stream contract (same precedent as the
      engine's vectorized block sampler), not bit-equal to reference.

    ``residency="host"`` additionally keeps the per-client
    :class:`PoolState` identity arrays in host slabs (optionally
    memory-mapped under ``mmap_dir``): the engine stages only the
    cohort's rows to device each block and scatters them back after —
    see ``init_slabs`` / ``gather_rows`` / ``scatter_rows``.

    Device side: ``init_state`` builds the :class:`PoolState` pytree the
    engine threads through the block-runner scan.
    """

    #: host-slab field names, mirroring PoolState's per-client arrays.
    SLAB_FIELDS = ("last_seen", "staleness", "checkins")

    def __init__(self, task_dist: TaskDistribution, size: int,
                 seed: int = 0, *, sampler: str = "reference",
                 residency: str = "device",
                 mmap_dir: Optional[str] = None,
                 max_cached_tasks: int = 4096):
        if size < 1:
            raise ValueError(f"pool size must be >= 1, got {size!r}")
        if sampler not in SAMPLERS:
            raise ValueError(f"unknown sampler {sampler!r}; expected "
                             f"one of {SAMPLERS}")
        if residency not in RESIDENCIES:
            raise ValueError(f"unknown residency {residency!r}; "
                             f"expected one of {RESIDENCIES}")
        if mmap_dir is not None and residency != "host":
            raise ValueError("mmap_dir only applies to residency='host' "
                             "(device-resident pools have no host slabs "
                             "to back with files)")
        if not (isinstance(max_cached_tasks, int)
                and max_cached_tasks >= 1):
            raise ValueError(f"max_cached_tasks must be an int >= 1, "
                             f"got {max_cached_tasks!r}")
        self.task_dist = task_dist
        self.size = int(size)
        self.seed = int(seed)
        self.sampler = sampler
        self.residency = residency
        self.mmap_dir = mmap_dir
        self.max_cached_tasks = int(max_cached_tasks)
        self._tasks: "collections.OrderedDict[int, object]" = \
            collections.OrderedDict()
        self._rngs: Dict[int, np.random.Generator] = {}
        self._templates: "collections.OrderedDict[tuple, tuple]" = \
            collections.OrderedDict()
        #: vectorized-sampler identity: client i's next check-in index.
        self._checkins = (np.zeros(self.size, np.int32)
                          if sampler == "vectorized" else None)
        self._slabs: Optional[Dict[str, np.ndarray]] = None

    def __repr__(self):
        return (f"ClientPool({type(self.task_dist).__name__}, "
                f"size={self.size}, seed={self.seed}, "
                f"sampler={self.sampler!r}, residency={self.residency!r})")

    def client_task(self, i: int):
        """Pool client ``i``'s stable task. Cached in a bounded LRU
        (``max_cached_tasks``): tasks are pure functions of
        ``(seed, i)``, so eviction only costs rematerialization — a
        long-lived million-client pool no longer accretes one Python
        task object per client it has ever seen."""
        if not 0 <= i < self.size:
            raise IndexError(f"client {i} out of range for pool of "
                             f"{self.size}")
        t = self._tasks.get(i)
        if t is None:
            t = self.task_dist.materialize_client(i, seed=self.seed)
            self._tasks[i] = t
            while len(self._tasks) > self.max_cached_tasks:
                self._tasks.popitem(last=False)
        else:
            self._tasks.move_to_end(i)
        return t

    def _client_rng(self, i: int) -> np.random.Generator:
        # Reference-sampler identity. These generators hold irreplaceable
        # mid-stream state, so the dict grows with the number of DISTINCT
        # clients ever seated — the legacy O(N) liability the
        # sampler="vectorized" counter derivation exists to remove.
        if i not in self._rngs:
            self._rngs[i] = np.random.default_rng(
                [self.seed, _DATA_STREAM, i])
        return self._rngs[i]

    def host_state(self) -> Dict:
        """JSON-able snapshot of the pool's mutable host state, paired
        with :meth:`load_host_state` for bit-for-bit checkpoint resume.
        Tasks and templates are NOT captured — they are pure functions
        of ``(seed, i)`` and rematerialize on demand.

        - reference sampler: ``{"rngs": {client: bit-generator state}}``
          — one entry per client that ever checked in.
        - vectorized sampler: ``{"checkins": {client: count}}`` — just
          the NONZERO check-in counters (the whole mutable state; the
          streams re-derive from ``(seed, i, k)``). Compact even at
          N=10^6."""
        if self.sampler == "vectorized":
            nz = np.flatnonzero(self._checkins)
            return {"checkins": {str(int(i)): int(self._checkins[i])
                                 for i in nz}}
        return {"rngs": {str(i): copy.deepcopy(g.bit_generator.state)
                         for i, g in self._rngs.items()}}

    def load_host_state(self, state: Dict) -> None:
        """Restore a :meth:`host_state` snapshot: captured clients
        resume mid-stream; clients absent from the snapshot fall back
        to their fresh seeded stream (they had never checked in). The
        snapshot format must match this pool's sampler — a legacy rng
        snapshot cannot seed counters (or vice versa) and raises rather
        than silently replaying data."""
        state = state or {}
        if self.sampler == "vectorized":
            if state.get("rngs"):
                raise ValueError(
                    "checkpoint holds a legacy per-client rng snapshot "
                    "('rngs'), but this pool uses sampler='vectorized' "
                    "(counter-based streams); resume with "
                    "ClientPool(..., sampler='reference') or restart "
                    "the run")
            self._checkins = np.zeros(self.size, np.int32)
            for key, k in (state.get("checkins") or {}).items():
                i = int(key)
                if not 0 <= i < self.size:
                    raise ValueError(f"checkpointed counter for client "
                                     f"{i} out of range for pool of "
                                     f"{self.size}")
                self._checkins[i] = int(k)
            return
        if state.get("checkins"):
            raise ValueError(
                "checkpoint holds a check-in counter snapshot "
                "('checkins'), but this pool uses sampler='reference' "
                "(per-client rng streams); resume with "
                "ClientPool(..., sampler='vectorized') or restart the "
                "run")
        self._rngs = {}
        for key, st in state.get("rngs", {}).items():
            g = np.random.default_rng()
            g.bit_generator.state = st
            self._rngs[int(key)] = g

    def _template(self, support: int, data_mode: str):
        """Zero-cost shape probe: one throwaway draw from client 0's
        task on a DEDICATED rng stream (never touches the per-client
        data streams), cached per (support, data_mode)."""
        key = (support, data_mode)
        if key not in self._templates:
            rng = np.random.default_rng([self.seed, _PROBE_STREAM])
            x, y = self._draw(self.client_task(0), rng, support, data_mode)
            self._templates[key] = (np.zeros_like(x), np.zeros_like(y))
            while len(self._templates) > _MAX_TEMPLATES:
                self._templates.popitem(last=False)
        else:
            self._templates.move_to_end(key)
        return self._templates[key]

    @staticmethod
    def _draw(task, rng, support: int, data_mode: str):
        if data_mode == "stream":
            sx, sy = zip(*task.support_stream(rng, support))
            return np.stack(sx), np.stack(sy)
        b = task.support_batch(rng, support)
        return np.asarray(b["x"]), np.asarray(b["y"])

    def sample_cohort_block(self, cohort, participation, support: int,
                            data_mode: str = "batch") -> Dict:
        """Support data for a planned block: for every participating
        (round, slot), draw ``support`` samples from THAT pool client's
        stable task using ITS private stream. Scheduled-out slots (and
        whole no-show rounds) stay zero. Called strictly in block
        order, so a client's data stream advances once per check-in —
        deterministic regardless of prefetch depth or who else was
        scheduled. Dispatches on the pool's ``sampler``: "reference"
        replays the legacy cached-generator path bit-for-bit,
        "vectorized" derives both streams from the check-in counters
        and draws each slot's support set in O(1) array calls."""
        cohort = np.asarray(cohort)
        part = np.asarray(participation, bool)
        rounds, clients = part.shape
        zx, zy = self._template(support, data_mode)
        x = np.zeros((rounds, clients) + zx.shape, zx.dtype)
        y = np.zeros((rounds, clients) + zy.shape, zy.dtype)
        if self.sampler == "vectorized":
            self._fill_block_counter(cohort, part, support, data_mode,
                                     x, y)
        else:
            self._fill_block_reference(cohort, part, support, data_mode,
                                       x, y)
        return {"x": x, "y": y}

    def _fill_block_reference(self, cohort, part, support, data_mode,
                              x, y):
        rounds, clients = part.shape
        for r in range(rounds):
            for c in range(clients):
                if not part[r, c]:
                    continue
                m = int(cohort[r, c])
                x[r, c], y[r, c] = self._draw(
                    self.client_task(m), self._client_rng(m), support,
                    data_mode)

    def _fill_block_counter(self, cohort, part, support, data_mode,
                            x, y):
        # One pass over the PARTICIPATING slots only (np.nonzero, not a
        # rounds x clients scan): each seats client m at its k-th
        # check-in and draws from the (seed, m, k)-derived streams, then
        # advances the counter. Cohorts are unique within a round, so
        # slot order within a round cannot change any client's k.
        counters = self._checkins
        rs, cs = np.nonzero(part)
        for r, c in zip(rs.tolist(), cs.tolist()):
            m = int(cohort[r, c])
            k = int(counters[m])
            x[r, c], y[r, c] = self.task_dist.sample_client_support(
                np.random.default_rng([self.seed, _TASK_STREAM, m]),
                np.random.default_rng([self.seed, _DATA_STREAM, m, k]),
                support, data_mode)
            counters[m] = k + 1

    def init_slabs(self, shards: int = 1) -> Dict[str, np.ndarray]:
        """Allocate (or reuse) the host-resident per-client identity
        slabs for ``residency="host"`` runs: one ``(n,)`` int32 array
        per :class:`PoolState` identity field (n = pool size rounded up
        to the shard multiple; padded rows are never seated). With
        ``mmap_dir`` the slabs are file-backed ``np.memmap``\\ s, so the
        O(N) identity state need not even occupy RAM."""
        if self.residency != "host":
            raise ValueError("init_slabs requires "
                             "ClientPool(residency='host')")
        shards = max(int(shards), 1)
        n = -(-self.size // shards) * shards
        if (self._slabs is not None
                and len(self._slabs["last_seen"]) == n):
            return self._slabs
        fill = {"last_seen": -1, "staleness": 0, "checkins": 0}
        slabs = {}
        for name in self.SLAB_FIELDS:
            if self.mmap_dir is not None:
                os.makedirs(self.mmap_dir, exist_ok=True)
                arr = np.memmap(
                    os.path.join(self.mmap_dir, f"pool_{name}.i32"),
                    dtype=np.int32, mode="w+", shape=(n,))
            else:
                arr = np.empty((n,), np.int32)
            arr[:] = fill[name]
            slabs[name] = arr
        self._slabs = slabs
        return slabs

    def gather_rows(self, idx) -> Dict[str, np.ndarray]:
        """Rows ``idx`` of the host identity slabs, as fresh (len(idx),)
        int32 arrays (fancy indexing copies — safe to stage to device
        while the slabs keep mutating)."""
        if self._slabs is None:
            raise ValueError("no host slabs: call init_slabs first")
        return {name: np.asarray(slab[idx])
                for name, slab in self._slabs.items()}

    def scatter_rows(self, idx, rows: Dict[str, np.ndarray]) -> None:
        """Write a block's updated identity rows back into the host
        slabs (the device->host half of the gathered-slab round trip)."""
        if self._slabs is None:
            raise ValueError("no host slabs: call init_slabs first")
        for name, slab in self._slabs.items():
            slab[idx] = np.asarray(rows[name], np.int32)

    def init_state(self, phi, cohort_size: int,
                   buffered: Optional[BufferedAggregation] = None,
                   shards: int = 1, template=None,
                   rows: Optional[int] = None) -> PoolState:
        """Fresh device-resident pool state. The FedBuff buffer's static
        capacity is ``buffer_size + cohort_size - 1``: a flush triggers
        at count >= buffer_size, and at most cohort_size arrivals land
        per round on top of a count of at most buffer_size - 1.

        ``template`` (default phi) gives the SHAPES/DTYPES of the
        buffer slots — the strategy's uplink tree
        (``FedStrategy.uplink_template``), so quantized strategies
        stage their native int8 result trees at int8 width.

        ``shards`` > 1 builds the MESH layout (run_federated(mesh=...)):
        the per-client arrays are padded to a multiple of ``shards`` so
        the "clients" mesh axis splits them evenly (padded rows are
        never indexed — cohort indices stay < pool size), and the
        FedBuff buffer becomes per-shard: each shard owns a
        ``buffer_size + local_cohort - 1`` slab (any one shard can hold
        the whole count-threshold backlog plus its own round of
        arrivals, since the flush predicate is on the psum-reduced
        GLOBAL count), with ``buf_count`` a (shards,) array of local
        fill levels. ``shards == 1`` is bit-for-bit the legacy layout
        (scalar ``buf_count``, one contiguous buffer).

        ``rows`` overrides the per-client axis length (the
        ``residency="host"`` gathered-slab window: device state holds
        only that many staged rows, remapped window-local by the
        engine, while the full (N,) identity lives in the host slabs).
        The FedBuff buffer is SERVER-side state and keeps its usual
        capacity regardless of ``rows``."""
        if cohort_size % max(shards, 1):
            raise ValueError(f"cohort_size={cohort_size} must be a "
                             f"multiple of shards={shards} (the engine "
                             f"pads the cohort before building state)")
        if rows is None:
            n = -(-self.size // shards) * shards    # ceil to shard multiple
        else:
            if rows % max(shards, 1):
                raise ValueError(f"rows={rows} must be a multiple of "
                                 f"shards={shards}")
            n = int(rows)
        last_seen = jnp.full((n,), -1, jnp.int32)
        staleness = jnp.zeros((n,), jnp.int32)
        checkins = jnp.zeros((n,), jnp.int32)
        if buffered is None:
            return PoolState(last_seen, staleness, checkins)
        if shards == 1:
            cap = buffered.buffer_size + cohort_size - 1
            buf_count = jnp.int32(0)
        else:
            cap = shards * (buffered.buffer_size
                            + cohort_size // shards - 1)
            buf_count = jnp.zeros((shards,), jnp.int32)
        buf = jax.tree.map(
            lambda p: jnp.zeros((cap,) + p.shape, p.dtype),
            phi if template is None else template)
        return PoolState(last_seen, staleness, checkins, buf,
                         jnp.zeros((cap,), jnp.int32), buf_count,
                         jnp.int32(0))


def pool_state_specs(state: PoolState, axis: str) -> PoolState:
    """PartitionSpecs mirroring ``state`` for a client-sharded mesh run:
    per-client arrays and the per-shard FedBuff slabs split over the
    ``axis`` mesh axis, the flush counter replicated. Used both as the
    block runner's shard_map in/out specs and (wrapped in
    NamedSharding) as the host-side device_put target."""
    from jax.sharding import PartitionSpec as P
    sharded = P(axis)
    return PoolState(
        last_seen=sharded, staleness=sharded, checkins=sharded,
        buf_updates=(None if state.buf_updates is None else
                     jax.tree.map(lambda _: sharded, state.buf_updates)),
        buf_round=None if state.buf_round is None else sharded,
        # shards == 1 layout (also the 2-D GSPMD route) keeps a SCALAR
        # fill counter — replicate it; the mesh layout's (shards,)
        # vector of local fill levels splits like the rows
        buf_count=(None if state.buf_count is None else
                   (sharded if jnp.ndim(state.buf_count) else P())),
        flushes=None if state.flushes is None else P())


@dataclasses.dataclass(frozen=True)
class AvailabilityProcess(SamplingPolicy):
    """Base class for check-in processes over a persistent pool: who is
    AVAILABLE each round is a stochastic process over the N pool
    clients, and the round's cohort is whoever showed up (capped at the
    cohort width by a uniform thinning draw).

    Subclasses implement :meth:`availability` — a (blk, N) boolean
    matrix for rounds [start, end), consuming ``rng`` deterministically
    in block order (the prefetch-parity contract; the engine always
    calls contiguous blocks in order, starting at round 0).

    Rounds where nobody checks in plan an all-False participation row;
    the engine marks them valid=False, so the server idles that round
    (phi and pool state pass through, zero transport billed) — the
    fixed-shape scan never retraces. These policies only make sense
    over a pool: ``plan_schedule`` (the anonymous-cohort hook) raises.
    """
    sampler: str = "reference"

    schedule_kind = "scheduled"

    def availability(self, rng, start: int, end: int,
                     pool_size: int) -> np.ndarray:
        raise NotImplementedError

    def plan_schedule(self, rng, start, end, clients, budget):
        raise ValueError(
            f"{type(self).__name__} schedules PERSISTENT clients; pass "
            f"pool=ClientPool(...) to run_federated (anonymous cohort "
            f"slots have no identity to be available or not)")

    def plan_pool_schedule(self, rng, start, end, clients, budget,
                           pool_size):
        avail = np.asarray(
            self.availability(rng, start, end, pool_size), bool)
        blk = end - start
        assert avail.shape == (blk, pool_size)
        if self.sampler == "vectorized":
            cohort, part = self._seat_available_block(rng, avail, clients)
        else:
            cohort = np.zeros((blk, clients), np.int32)
            part = np.zeros((blk, clients), bool)
            for r in range(blk):
                idx = np.flatnonzero(avail[r])
                if len(idx) > clients:  # more volunteers than slots
                    idx = np.sort(
                        rng.choice(idx, size=clients, replace=False))
                m = len(idx)
                cohort[r, :m] = idx
                part[r, :m] = True
        m_per_round = part.sum(axis=1, keepdims=True)
        weights = np.where(
            m_per_round > 0, part / np.maximum(m_per_round, 1), 0.0)
        return {
            "participation": part,
            "local_steps": np.where(part, budget, 0).astype(np.int32),
            "weights": weights.astype(np.float32),
            "cohort": cohort,
        }

    @staticmethod
    def _seat_available_block(rng, avail, clients):
        """Loop-free cohort seating for the whole block: every available
        client draws one uniform key, each round keeps the ``clients``
        smallest keys (a uniform without-replacement thinning), and a
        sort packs the winners ascending into the leading slots — the
        reference layout (sorted cohort, False tail), on a NEW rng
        stream contract (one (blk, N) key draw instead of per-round
        ``choice`` calls)."""
        blk, pool_size = avail.shape
        k = min(clients, pool_size)
        keys = np.where(avail, rng.uniform(size=avail.shape), np.inf)
        cand = np.argpartition(keys, k - 1, axis=1)[:, :k]
        alive = np.isfinite(np.take_along_axis(keys, cand, axis=1))
        seats = np.sort(np.where(alive, cand, pool_size), axis=1)
        cohort = np.zeros((blk, clients), np.int32)
        part = np.zeros((blk, clients), bool)
        part[:, :k] = seats < pool_size
        cohort[:, :k] = np.where(part[:, :k], seats, 0)
        return cohort, part


@dataclasses.dataclass(frozen=True)
class DiurnalAvailability(AvailabilityProcess):
    """Fleet-wide diurnal check-ins: client ``i`` is available at round
    ``r`` with probability
    ``clip(base + amplitude * sin(2*pi*(r/period + phase_i)), 0, 1)``.

    With the default ``phase_spread=0`` the whole fleet shares one sine
    (everyone's devices sleep at night — the classic diurnal load
    curve, including trough rounds where NOBODY may check in);
    ``phase_spread=1`` staggers phases evenly across clients (a fleet
    spanning all timezones, whose aggregate availability is flat).
    """
    period: int = 24
    base: float = 0.5
    amplitude: float = 0.45
    phase_spread: float = 0.0

    def __post_init__(self):
        if self.period < 1:
            raise ValueError(f"period must be >= 1, got {self.period!r}")
        # base/amplitude/phase_spread are probability-curve parameters:
        # reject out-of-range values at construction (parse) time rather
        # than silently clipping into a degenerate fleet.
        if not 0.0 <= self.base <= 1.0:
            raise ValueError(f"base must be in [0, 1] (a check-in "
                             f"probability), got {self.base!r}")
        if not 0.0 <= self.amplitude <= 1.0:
            raise ValueError(f"amplitude must be in [0, 1], got "
                             f"{self.amplitude!r}")
        if not 0.0 <= self.phase_spread <= 1.0:
            raise ValueError(f"phase_spread must be in [0, 1] (fraction "
                             f"of the fleet's phase fan-out), got "
                             f"{self.phase_spread!r}")
        self._validate_sampler()

    def availability(self, rng, start, end, pool_size):
        r = np.arange(start, end, dtype=np.float64)[:, None]
        phase = (self.phase_spread
                 * np.arange(pool_size, dtype=np.float64)[None, :]
                 / max(pool_size, 1))
        p = np.clip(self.base + self.amplitude
                    * np.sin(2.0 * np.pi * (r / self.period + phase)),
                    0.0, 1.0)
        return rng.uniform(size=p.shape) < p


@dataclasses.dataclass(frozen=True)
class MarkovAvailability(AvailabilityProcess):
    """Two-state (on/off) Markov check-ins per client: an off client
    turns on with probability ``p_on`` each round, an on client turns
    off with ``p_off`` — sticky sessions and dropouts rather than
    i.i.d. coin flips. Long-run availability is the chain's stationary
    rate ``p_on / (p_on + p_off)``; chains start from a stationary draw
    at round 0.

    The chain state must survive across scan blocks: the policy stashes
    the ONE in-flight trajectory (keyed by the rng stream driving it,
    held strongly so the key can never be a recycled object) and
    requires contiguous in-order blocks — exactly how the engine's
    prefetch producer calls it. A fresh run starts at round 0, which
    resets the stash, so one policy instance serves any number of
    sequential runs without growing state.
    """
    p_on: float = 0.3
    p_off: float = 0.15
    #: single-slot chain stash: (rng, pool_size, next_start, state)
    _chain: list = dataclasses.field(default_factory=list, repr=False,
                                     compare=False)

    def __post_init__(self):
        for name in ("p_on", "p_off"):
            v = getattr(self, name)
            if not 0.0 < v <= 1.0:
                raise ValueError(f"{name} must be in (0, 1], got {v!r}")
        self._validate_sampler()

    def availability(self, rng, start, end, pool_size):
        if start == 0:
            self._chain.clear()          # a fresh trajectory begins
            state = rng.uniform(size=pool_size) < (
                self.p_on / (self.p_on + self.p_off))
        elif (self._chain and self._chain[0] is rng
                and self._chain[1] == pool_size
                and self._chain[2] == start):
            state = self._chain[3]
        else:
            raise RuntimeError(
                f"MarkovAvailability needs contiguous in-order blocks "
                f"from one rng stream: got start={start} with no "
                f"matching chain state (blocks must begin at round 0 "
                f"and follow back-to-back)")
        rows = np.zeros((end - start, pool_size), bool)
        for r in range(end - start):
            u = rng.uniform(size=pool_size)
            state = np.where(state, u >= self.p_off, u < self.p_on)
            rows[r] = state
        self._chain[:] = [rng, pool_size, end, state.copy()]
        return rows

    def state_dict(self):
        """The in-flight chain (pool size, next expected block start,
        per-client on/off booleans) — the one piece of policy state the
        restored rng stream alone cannot rebuild, captured into
        round-state checkpoints. {} when no trajectory is in flight."""
        if not self._chain:
            return {}
        return {"pool_size": int(self._chain[1]),
                "next_start": int(self._chain[2]),
                "state": np.asarray(self._chain[3], bool).tolist()}

    def load_state_dict(self, state, rng=None):
        """Prime the chain stash from a ``state_dict`` snapshot so the
        resumed run's first block (``start == next_start``) continues
        the interrupted trajectory; ``rng`` must be the run's restored
        host generator (the stash is keyed by stream identity)."""
        if not state:
            self._chain.clear()
            return
        self._chain[:] = [rng, int(state["pool_size"]),
                          int(state["next_start"]),
                          np.asarray(state["state"], bool)]
