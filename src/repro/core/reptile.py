"""Reptile baseline [Nichol et al. 2018], batched & serial variants —
the paper's main comparison (Figs. 2-4, Tables II-IV).

Difference from TinyReptile: the client trains on its ENTIRE support set
in batch for E epochs (data stored and reused — the resource cost the
paper measures in Table II).

The loop lives in the shared round engine (repro.core.engine); with
clients_per_round > 1 the per-client inner loops run vmapped on-device
instead of one Python iteration per client."""
from __future__ import annotations

from typing import Callable, Dict, Optional

from repro.core.engine import CommChannel, run_federated
from repro.core.pipeline import SamplingPolicy
from repro.core.pool import BufferedAggregation, ClientPool
from repro.core.strategies import ReptileStrategy
from repro.data.tasks import TaskDistribution


def reptile_train(loss_fn: Callable, init_params,
                  task_dist: TaskDistribution, *,
                  rounds: int = 1000, alpha: float = 1.0, beta: float = 0.01,
                  support: int = 32, epochs: int = 8,
                  clients_per_round: int = 1, anneal: bool = True,
                  seed: int = 0, eval_every: int = 0,
                  eval_kwargs: Optional[dict] = None,
                  channel: Optional[CommChannel] = None,
                  prefetch: int = 2, sampler: str = "reference",
                  max_block: int = 512,
                  sampling: Optional[SamplingPolicy] = None,
                  pool: Optional[ClientPool] = None,
                  buffered: Optional[BufferedAggregation] = None,
                  mesh=None) -> Dict:
    """clients_per_round == 1 -> serial Reptile; > 1 -> batched Reptile
    (server averages the per-client pseudo-gradients; requires concurrent
    connections to all sampled clients — the cost the paper calls out).
    `sampling` plugs in a heterogeneity schedule (partial participation /
    stragglers over the cohort)."""
    return run_federated(
        init_params, task_dist, ReptileStrategy(loss_fn, epochs=epochs),
        rounds=rounds, clients_per_round=clients_per_round, alpha=alpha,
        beta=beta, support=support, anneal=anneal, seed=seed,
        eval_every=eval_every, eval_kwargs=eval_kwargs, channel=channel,
        prefetch=prefetch, sampler=sampler, max_block=max_block,
        sampling=sampling, pool=pool, buffered=buffered, mesh=mesh)
