"""Reptile baseline [Nichol et al. 2018], batched & serial variants —
the paper's main comparison (Figs. 2-4, Tables II-IV).

Difference from TinyReptile: the client trains on its ENTIRE support set
in batch for E epochs (data stored and reused — the resource cost the
paper measures in Table II)."""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.meta import (evaluate_init, finetune_batch, tree_bytes,
                             tree_lerp)
from repro.data.tasks import TaskDistribution


def reptile_train(loss_fn: Callable, init_params,
                  task_dist: TaskDistribution, *,
                  rounds: int = 1000, alpha: float = 1.0, beta: float = 0.01,
                  support: int = 32, epochs: int = 8,
                  clients_per_round: int = 1, anneal: bool = True,
                  seed: int = 0, eval_every: int = 0,
                  eval_kwargs: Optional[dict] = None) -> Dict:
    """clients_per_round == 1 -> serial Reptile; > 1 -> batched Reptile
    (server averages the per-client pseudo-gradients; requires concurrent
    connections to all sampled clients — the cost the paper calls out)."""
    rng = np.random.default_rng(seed)
    phi = init_params
    history: List[Dict] = []
    pbytes = tree_bytes(phi)
    comm_bytes = 0

    for rnd in range(rounds):
        alpha_t = alpha * (1 - rnd / rounds) if anneal else alpha
        deltas = None
        inner_loss = 0.0
        for _ in range(clients_per_round):
            task = task_dist.sample_task(rng)
            comm_bytes += 2 * pbytes
            sup = task.support_batch(rng, support)
            phi_hat, losses = finetune_batch(loss_fn, phi, sup, epochs,
                                             jnp.float32(beta))
            inner_loss += float(losses.mean()) / clients_per_round
            d = jax.tree.map(lambda q, p: q - p, phi_hat, phi)
            deltas = d if deltas is None else jax.tree.map(
                lambda a, b: a + b, deltas, d)
        phi = jax.tree.map(
            lambda p, d: p + alpha_t * d / clients_per_round, phi, deltas)
        if eval_every and (rnd + 1) % eval_every == 0:
            ev = evaluate_init(loss_fn, phi, task_dist,
                               np.random.default_rng(10_000 + rnd),
                               **(eval_kwargs or {}))
            ev.update(round=rnd + 1, comm_bytes=comm_bytes,
                      inner_loss=inner_loss)
            history.append(ev)
    return {"params": phi, "history": history, "comm_bytes": comm_bytes}
