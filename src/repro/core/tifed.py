"""TIFeD integer-only federated training [arXiv 2307.03102] — the
compute half of int8 federation (the transport half has been
``CommChannel("int8")`` since PR 1).

Clients train in integer arithmetic: int8 weights on per-tensor
power-of-two grids, int32 accumulators, direct-feedback-alignment
updates with bit-shift learning rates and stochastic-rounding
requantization (see ``core.strategies.TifedStrategy`` and the fused
``kernels/online_sgd_int8.py`` epoch kernel). The uplink is the native
int8 result tree, billed at 1 byte/param; the server dequantizes,
aggregates in one fused psum, and snaps phi back onto the integer grid.

The loop lives in the shared round engine, so tifed composes with
pools, FedBuff, availability processes, schedules, and the client mesh
exactly like the fp32 strategies."""
from __future__ import annotations

from typing import Callable, Dict, Optional

from repro.core.engine import CommChannel, run_federated
from repro.core.pipeline import SamplingPolicy
from repro.core.pool import BufferedAggregation, ClientPool
from repro.core.strategies import TifedStrategy
from repro.data.tasks import TaskDistribution
from repro.models.paper_nets import relu_mlp_loss


def tifed_train(init_params, task_dist: TaskDistribution, *,
                rounds: int = 1000, alpha: float = 1.0,
                support: int = 32, epochs: int = 8, lr_shift: int = 6,
                feedback_seed: int = 0, clients_per_round: int = 1,
                anneal: bool = True, seed: int = 0, eval_every: int = 0,
                eval_kwargs: Optional[dict] = None,
                channel: Optional[CommChannel] = None,
                prefetch: int = 2, sampler: str = "reference",
                max_block: int = 512,
                sampling: Optional[SamplingPolicy] = None,
                pool: Optional[ClientPool] = None,
                buffered: Optional[BufferedAggregation] = None,
                mesh=None, loss_fn: Optional[Callable] = None,
                use_pallas: Optional[bool] = None) -> Dict:
    """Integer-only federated training on the paper's sine MLP shapes.

    No ``beta``: the client learning rate is the integer bit-shift
    ``lr_shift`` (effective rate 2^-(lr_shift + log2(support))).
    ``channel`` defaults to the non-simulating int8 channel — the
    payload already IS int8, so the channel only bills it (a simulating
    or fp32 channel is rejected by the engine). ``loss_fn`` (default
    ``relu_mlp_loss``) is only used for fp32 eval finetuning; keep its
    eval lr <= 0.01 — the ReLU net diverges at the tanh-tuned 0.02 when
    k_steps is large."""
    if channel is None:
        channel = CommChannel("int8", quantize=False)
    strategy = TifedStrategy(
        relu_mlp_loss if loss_fn is None else loss_fn, epochs=epochs,
        lr_shift=lr_shift, feedback_seed=feedback_seed,
        use_pallas=use_pallas)
    return run_federated(
        init_params, task_dist, strategy,
        rounds=rounds, clients_per_round=clients_per_round, alpha=alpha,
        beta=0.0, support=support, anneal=anneal, seed=seed,
        eval_every=eval_every, eval_kwargs=eval_kwargs, channel=channel,
        prefetch=prefetch, sampler=sampler, max_block=max_block,
        sampling=sampling, pool=pool, buffered=buffered, mesh=mesh)
