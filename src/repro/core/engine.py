"""The federated round engine: one pipelined loop for every core/ algorithm.

Historically each algorithm file (tinyreptile, reptile, fedavg, fedsgd,
transfer) hand-rolled the same Python-side server loop — client sampling,
comm-byte metering, annealing, eval cadence — and paid one host->device
dispatch per client per round. This module owns all of that once:

  run_federated(init_params, task_dist, strategy, ...)

* A ``FedStrategy`` (see repro.core.strategies) supplies the two
  algorithm-specific hooks: ``client_update`` (what one device does with
  the broadcast parameters and its local data) and ``server_aggregate``
  (how the server folds the client results back into phi).
* Rounds execute as fixed-shape on-device blocks: ``jax.vmap`` across the
  clients_per_round axis and ``jax.lax.scan`` across the rounds between
  evals, with the parameter buffers donated between blocks. Every block —
  including the uneven eval-boundary tail — is padded on the host to ONE
  per-run length and carries a per-round validity mask (``lax.cond``
  skips padded rounds at runtime), so the block runner compiles exactly
  once per (strategy, beta, channel) config; ``_BlockRunner.trace_count``
  makes that observable.
* The host side is a producer/consumer pipeline (repro.core.pipeline):
  per-round round state is a structured ``ClientSchedule`` (participation
  mask, per-client local step counts, aggregation weights, absolute
  round index) planned by a pluggable ``SamplingPolicy`` — uniform
  i.i.d. by default (with a legacy-exact "reference" RNG order and a
  vectorized one-allocation fast path), ``PartialParticipation`` and
  ``StragglerSampling`` as deployment-scenario plugins — and a
  background prefetch thread plans, samples, and ``device_put``s block
  N+1 while the device runs block N (double buffered). ``prefetch=0``
  is the synchronous escape hatch; pipelined and synchronous runs are
  bit-for-bit identical because the producer consumes the host RNG in
  exactly the synchronous block order.
* A pluggable ``CommChannel`` does the paper's Table-II byte accounting
  for fp32/fp16/int8 payloads and can optionally *simulate* the quantized
  transport (int8 motivated by TIFeD's integer-based FL).
  ``PartialCommChannel`` additionally transmits only a per-round
  parameter FRACTION (TinyMetaFed-style partial communication): masked
  uplink deltas plus fraction-scaled accounting, billed per
  participating client, with optional per-round rotating masks that
  cover every parameter entry once per ``ceil(1/fraction)`` rounds.
* Persistent identities are one layer up: a ``repro.core.pool.
  ClientPool`` gives every client a stable task/data shard and a
  cross-round state pytree (last-seen round, staleness counters, the
  FedBuff pending-update buffer) that rides the scan carry next to phi
  and is gathered/scattered by the round's cohort indices inside the
  scan. ``BufferedAggregation`` makes aggregation FedBuff-style async
  (flush every K arrivals, staleness-discounted weights);
  ``DiurnalAvailability`` / ``MarkovAvailability`` drive who checks in.
  ``pool=None`` keeps the legacy anonymous-cohort path bit-for-bit.
* The server update routes through the fused Pallas kernel
  (``repro.kernels.ops.meta_update``) by default on TPU backends;
  elsewhere the same fp32 math runs as plain XLA (the kernel would only
  interpret there).
* ``run_federated(..., mesh=...)`` SHARDS THE CLIENT AXIS across a
  device mesh: the block runner wraps its scan in ``shard_map`` (manual
  over a 1-D "clients" mesh axis), each device vmaps over its local
  cohort shard, and server aggregation becomes a weighted all-reduce
  (``server_aggregate_weighted(..., axis_name="clients")`` — a masked
  psum of per-shard partial sums). The round scan carries REPLICATED
  phi next to the client-sharded ``ClientSchedule`` and ``PoolState``;
  cohorts are padded to a multiple of the shard count via the existing
  validity/participation masks, so uneven cohorts never retrace, and
  the two hot-path invariants survive sharding: zero per-round host
  dispatches and one jit trace per (strategy, beta, channel,
  schedule-shape, pool-shape, mesh) config. ``mesh=None`` (the
  default) is bit-for-bit the single-device engine.

``meta_interpolate`` and ``streaming_sgd`` are the engine's round
building blocks, shared with the mesh-scale cohort step in
``repro.runtime.steps``. Jitted block runners are memoized per
(strategy, beta, channel); ``runner_cache_stats`` / ``clear_runner_cache``
expose and reset that cache (long sweeps over many configs would
otherwise pin up to 64 stale executables).
"""
from __future__ import annotations

import collections
import copy
import dataclasses
import inspect
import logging
import math
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.ckpt import (AsyncCheckpointWriter, RoundState,
                                   restore_round_state, save_round_state)
from repro.core.meta import evaluate_init
from repro.core.pipeline import (ClientSchedule, SamplingPolicy,
                                 UniformSampling, block_shardings,
                                 plan_blocks, prefetch_items,
                                 single_device_of)
from repro.core.pool import (BufferedAggregation, ClientPool, PoolState,
                             pool_state_specs)
from repro.data.tasks import TaskDistribution
from repro.runtime.sharding import shard_map_compat

logger = logging.getLogger(__name__)

#: the engine's mesh axis: run_federated(mesh=...) shards the per-round
#: cohort over it (see client_mesh).
CLIENT_AXIS = "clients"

#: bytes per parameter for each transport payload dtype (paper Table II
#: generalized: the paper ships fp32; fp16/int8 model compressed uplinks).
PAYLOAD_ITEMSIZE = {"float32": 4, "float16": 2, "int8": 1}


def default_use_pallas() -> bool:
    """Pallas server update only where it compiles natively."""
    return jax.default_backend() == "tpu"


def client_mesh(devices=None) -> Mesh:
    """A 1-D device mesh over the engine's client axis ("clients").

    ``devices``: None uses every ``jax.devices()``; an int takes the
    first n; a sequence of Devices is used as given. Pass the result
    (or just the int / "auto") to ``run_federated(mesh=...)`` to shard
    each round's cohort across the devices.
    """
    if devices is None:
        devs = jax.devices()
    elif isinstance(devices, int):
        avail = jax.devices()
        if not 1 <= devices <= len(avail):
            raise ValueError(f"client_mesh asked for {devices} devices; "
                             f"this process has {len(avail)} (forcing "
                             f"host devices needs XLA_FLAGS="
                             f"--xla_force_host_platform_device_count)")
        devs = avail[:devices]
    else:
        devs = list(devices)
    return Mesh(np.array(devs), (CLIENT_AXIS,))


#: the optional second mesh axis: a 2-D (clients, model) mesh
#: additionally shards phi's weight matrices per the run's
#: ModelPartitioner (see repro.runtime.sharding.client_model_mesh).
MODEL_AXIS = "model"


def _resolve_mesh(mesh) -> Optional[Mesh]:
    """Normalize run_federated's mesh argument: None passes through,
    "auto" builds a mesh over every device, an int over the first n,
    and an explicit Mesh must be 1-D over the "clients" axis or 2-D
    over ("clients", "model")."""
    if mesh is None:
        return None
    if mesh == "auto":
        return client_mesh()
    if isinstance(mesh, int):
        return client_mesh(mesh)
    if tuple(mesh.axis_names) not in ((CLIENT_AXIS,),
                                      (CLIENT_AXIS, MODEL_AXIS)):
        raise ValueError(
            f"run_federated shards the cohort over a '{CLIENT_AXIS}' "
            f"mesh axis — 1-D ('{CLIENT_AXIS}',) or 2-D ('{CLIENT_AXIS}', "
            f"'{MODEL_AXIS}'); got axes {tuple(mesh.axis_names)} (build "
            f"one with repro.core.engine.client_mesh / "
            f"repro.runtime.sharding.client_model_mesh, or pass an int / "
            f"'auto')")
    return mesh


def _model_sharded(mesh) -> bool:
    return mesh is not None and MODEL_AXIS in mesh.axis_names


def meta_interpolate(phi, phi_hat, alpha, *, use_pallas: Optional[bool] = None):
    """Reptile server update phi <- phi + alpha (phi_hat - phi), fp32 math,
    cast back to each leaf's storage dtype. Routed through the fused Pallas
    kernel when `use_pallas` (default: on TPU)."""
    if use_pallas is None:
        use_pallas = default_use_pallas()
    if use_pallas:
        from repro.kernels import ops as kops
        return jax.tree.map(
            lambda p, q: kops.meta_update(p, q, alpha), phi, phi_hat)
    return jax.tree.map(
        lambda p, q: (p.astype(jnp.float32)
                      + alpha * (q.astype(jnp.float32)
                                 - p.astype(jnp.float32))).astype(p.dtype),
        phi, phi_hat)


def streaming_sgd(loss_fn, phi, batch, beta):
    """The inner loop: one SGD step per arriving microbatch (the paper's
    online learning), scanned on-device; fp32 update math, params cast
    back to their storage dtype. In probe mode the scan unrolls so XLA
    cost analysis counts every step (see repro.runtime.flags)."""
    def inner(phi_hat, micro):
        loss, g = jax.value_and_grad(loss_fn)(phi_hat, micro)
        phi_hat = jax.tree.map(
            lambda p, gg: (p.astype(jnp.float32)
                           - beta * gg.astype(jnp.float32)).astype(p.dtype),
            phi_hat, g)
        return phi_hat, loss

    from repro.runtime.flags import probe_mode
    if probe_mode():
        k = jax.tree.leaves(batch)[0].shape[0]
        phi_hat, losses = phi, []
        for i in range(k):
            micro = jax.tree.map(lambda a: a[i], batch)
            phi_hat, l = inner(phi_hat, micro)
            losses.append(l)
        return phi_hat, jnp.stack(losses)
    return jax.lax.scan(inner, phi, batch)


@dataclasses.dataclass(frozen=True)
class CommChannel:
    """Server<->client transport: byte accounting + optional quantization.

    dtype: payload dtype on the wire ("float32" | "float16" | "int8").
      Accounting scales `tree_bytes` by the itemsize ratio — the paper's
      Table II generalized beyond fp32.
    quantize: simulate the lossy payload in-round (cast round-trip for
      fp16, per-leaf symmetric affine quantization for int8). Default:
      quantize iff dtype != float32. Accounting-only studies can set
      quantize=False to meter a compressed link while training in fp32;
      quantize=True on an fp32 wire is rejected (an exact wire has
      nothing to simulate).
    """
    dtype: str = "float32"
    quantize: Optional[bool] = None

    #: set on subclasses whose transmit() needs the engine to pass a
    #: server-side reference tree for the uplink (delta-style transports).
    needs_uplink_ref = False

    def __post_init__(self):
        if self.dtype not in PAYLOAD_ITEMSIZE:
            raise ValueError(f"unknown payload dtype {self.dtype!r}; "
                             f"expected one of {sorted(PAYLOAD_ITEMSIZE)}")
        if self.quantize and self.dtype == "float32":
            raise ValueError("quantize=True with an fp32 wire: the payload "
                             "is exact, there is no quantization to "
                             "simulate (drop quantize or pick fp16/int8)")

    @property
    def simulates_quantization(self) -> bool:
        if self.quantize is None:
            return self.dtype != "float32"
        return self.quantize

    def payload_bytes(self, tree) -> int:
        """One direction, one client: every leaf at the wire itemsize."""
        itemsize = PAYLOAD_ITEMSIZE[self.dtype]
        return sum(x.size * itemsize for x in jax.tree.leaves(tree))

    def payload_bytes_at(self, tree, round_index: int) -> int:
        """Per-round exact payload. Equal to ``payload_bytes`` for every
        channel except rotating partial masks, whose per-round payload
        is the round's chunk (see PartialCommChannel)."""
        del round_index
        return self.payload_bytes(tree)

    def round_bytes(self, tree, clients: int) -> int:
        """Downlink (phi out) + uplink (result back) for every client."""
        return 2 * clients * self.payload_bytes(tree)

    def _wire(self, tree):
        """Simulated dtype round-trip (encode + decode), jax-traceable.
        The fp32 wire is exact."""
        if self.dtype == "float16":
            return jax.tree.map(
                lambda x: x.astype(jnp.float16).astype(x.dtype), tree)
        if self.dtype == "int8":
            def q_int8(x):
                scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / 127.0
                q = jnp.round(x / scale).astype(jnp.int8)
                return (q.astype(x.dtype) * scale).astype(x.dtype)
            return jax.tree.map(q_int8, tree)
        return tree

    def transmit(self, tree, ref=None, masks=None, round_index=None):
        """Simulated wire round-trip. ``ref`` is the engine-provided
        server-side reference tree for delta-style transports, ``masks``
        a precomputed keep-mask tree, and ``round_index`` the absolute
        round for rotating masks (see PartialCommChannel); the base
        channel ignores all three."""
        del ref, masks, round_index
        if not self.simulates_quantization:
            return tree
        return self._wire(tree)


@dataclasses.dataclass(frozen=True)
class PartialCommChannel(CommChannel):
    """TinyMetaFed-style partial communication: each round only a fixed
    FRACTION of the parameter vector crosses the wire.

    Accounting: per leaf, ``kept_entries(n) = max(1, round(fraction*n))``
    entries at the wire itemsize, both directions. The kept-index set is
    derived deterministically from ``mask_seed`` (shared by both ends),
    so no index side-channel is metered.

    Simulation: on the uplink the engine passes a server-side reference
    tree — kept entries carry the client result (after any base dtype
    quantization), dropped entries fall back to the reference, i.e. the
    server keeps its own value where the client sent nothing (reference =
    phi for model-returning strategies, 0 for gradient uplinks; see
    ``FedStrategy.uplink_ref``). On the downlink, transmitted entries
    ride the dtype wire (fp16/int8 quantized); untransmitted entries
    approximate the client's stale copy with the server's exact value
    (clients are stateless in this simulation). Both directions converge
    to the base channel as fraction -> 1.

    rotate=False (default): ONE fixed keep mask for the whole run, with
    exactly ``kept_entries(n) = max(1, round(fraction * n))`` entries
    per leaf. rotate=True: the mask ROTATES every round — each leaf's
    entries are split (in a fixed ``mask_seed``-keyed permutation order)
    into ``rotation_period = ceil(1/fraction)`` near-equal chunks, and
    round r transmits chunk ``r % rotation_period``, so EVERY parameter
    entry crosses the wire within one rotation period and a full period
    accounts exactly one complete tree at the wire itemsize (per-round
    chunk sizes differ by at most one entry per leaf;
    ``payload_bytes_at`` is the per-round exact meter). Both ends derive
    the round's mask from (mask_seed, round index), so no index
    side-channel is metered; inside the engine's scan the round index is
    folded in from the ClientSchedule carry — no per-round host work.
    """
    fraction: float = 0.5
    mask_seed: int = 0
    rotate: bool = False

    needs_uplink_ref = True

    def __post_init__(self):
        super().__post_init__()
        if not 0.0 < self.fraction <= 1.0:
            raise ValueError(f"fraction must be in (0, 1], got "
                             f"{self.fraction!r}")

    def kept_entries(self, n: int) -> int:
        """How many of a leaf's n entries are transmitted per round.
        Fixed masks: max(1, round(fraction * n)). Rotating masks
        transmit the round's CHUNK — 1/rotation_period of the entries,
        which only equals the fraction when 1/fraction is an integer —
        so this reports round 0's (largest) chunk and
        ``kept_entries_at`` is the per-round exact count."""
        if self.rotate:
            return self.kept_entries_at(n, 0)
        return max(1, int(round(self.fraction * n)))

    @property
    def rotation_period(self) -> int:
        """Rounds until a rotating mask has covered every entry:
        ceil(1/fraction), guarded against float noise (1/(1/3) slightly
        above 3 must still give period 3)."""
        return max(1, math.ceil(1.0 / self.fraction - 1e-9))

    def kept_entries_at(self, n: int, round_index: int) -> int:
        """Entries of an n-entry leaf transmitted at ``round_index`` under
        rotation: the size of chunk (round_index % period) in the
        balanced split (first n % period chunks get the extra entry)."""
        period = self.rotation_period
        j = round_index % period
        return n // period + (1 if j < n % period else 0)

    def payload_bytes(self, tree) -> int:
        itemsize = PAYLOAD_ITEMSIZE[self.dtype]
        return sum(self.kept_entries(x.size) * itemsize
                   for x in jax.tree.leaves(tree))

    def payload_bytes_at(self, tree, round_index: int) -> int:
        if not self.rotate:
            return self.payload_bytes(tree)
        itemsize = PAYLOAD_ITEMSIZE[self.dtype]
        return sum(self.kept_entries_at(x.size, round_index) * itemsize
                   for x in jax.tree.leaves(tree))

    @property
    def simulates_quantization(self) -> bool:
        if self.fraction < 1.0:
            return True
        return CommChannel.simulates_quantization.fget(self)

    def chunk_id_tree(self, tree):
        """Static rotation state: per leaf, an int32 array (leaf-shaped)
        assigning every entry to one of ``rotation_period`` balanced
        chunks in ``mask_seed``-keyed permutation order. Round r's keep
        mask is just ``chunk_ids == r % rotation_period`` — cheap enough
        to evaluate inside the scan with a traced round index."""
        period = self.rotation_period
        leaves, treedef = jax.tree.flatten(tree)
        key = jax.random.PRNGKey(self.mask_seed)
        ids = []
        for i, leaf in enumerate(leaves):
            n = leaf.size
            perm = jax.random.permutation(jax.random.fold_in(key, i), n)
            sizes = np.full(period, n // period, np.int32)
            sizes[: n % period] += 1
            chunk_of_pos = jnp.asarray(
                np.repeat(np.arange(period, dtype=np.int32), sizes))
            leaf_ids = jnp.zeros((n,), jnp.int32).at[perm].set(chunk_of_pos)
            ids.append(leaf_ids.reshape(leaf.shape))
        return jax.tree.unflatten(treedef, ids)

    def masks_for_round(self, chunk_ids, round_index):
        """Round ``round_index``'s keep-masks from precomputed chunk ids
        — the single source of the rotation rule (the engine's scan body
        calls this with the ClientSchedule's traced round index)."""
        phase = jnp.mod(round_index, self.rotation_period)
        return jax.tree.map(lambda ids: ids == phase, chunk_ids)

    def mask_tree(self, tree, round_index=None):
        """Boolean keep-masks, one per leaf. Fixed masks (rotate=False)
        have exactly ``kept_entries(leaf.size)`` True entries (matches
        the accounting); rotating masks select round ``round_index``'s
        chunk (default round 0). ``round_index`` may be traced."""
        if self.rotate:
            return self.masks_for_round(
                self.chunk_id_tree(tree),
                0 if round_index is None else round_index)
        leaves, treedef = jax.tree.flatten(tree)
        key = jax.random.PRNGKey(self.mask_seed)
        masks = []
        for i, leaf in enumerate(leaves):
            n = leaf.size
            perm = jax.random.permutation(jax.random.fold_in(key, i), n)
            m = jnp.zeros((n,), jnp.bool_)
            m = m.at[perm[:self.kept_entries(n)]].set(True)
            masks.append(m.reshape(leaf.shape))
        return jax.tree.unflatten(treedef, masks)

    def transmit(self, tree, ref=None, masks=None, round_index=None):
        # the base dtype simulation is gated on the BASE quantize decision
        # (quantize=False keeps the accounting-only contract: values pass
        # untouched even though fraction < 1 makes this channel simulate)
        base_wire = CommChannel.simulates_quantization.fget(self)
        if self.fraction >= 1.0:                 # degenerate: base channel
            return self._wire(tree) if base_wire else tree
        if ref is None and not base_wire:        # exact wire, nothing sent
            return tree                          # differs from the fallback
        if masks is None:
            # inside a scan, pass precomputed masks instead: the keep
            # masks (or the rotating chunk ids behind them) are constant
            # per run, the permutations are not free
            masks = self.mask_tree(tree if ref is None else ref,
                                   round_index)
        sent = self._wire(tree) if base_wire else tree
        if ref is None:
            # downlink: kept entries ride the wire dtype; dropped entries
            # approximate the client's stale copy with the exact value
            return jax.tree.map(lambda t, s, m: jnp.where(m, s, t),
                                tree, sent, masks)
        # uplink: masks/ref broadcast over the leading clients axis
        return jax.tree.map(lambda r, s, m: jnp.where(m, s, r),
                            ref, sent, masks)


class _BlockRunner:
    """Compiled block executor: lax.scan over the padded round axis whose
    body vmaps the client hook across clients; per-round validity via
    ``lax.cond`` so padded rounds are runtime no-ops (phi passes through
    untouched — bit-for-bit identical to an unpadded scan). phi is
    donated — successive blocks update in place.

    The scan's xs are ``(ClientSchedule, batch)``: the whole per-round,
    per-client round state (participation, local step counts,
    aggregation weights, absolute round index) rides the scan carry as
    device arrays, so heterogeneous rounds cost ZERO extra host
    dispatches. ``scheduled`` is a static flag baked in from the
    sampling policy's ``schedule_kind``:

    * scheduled=False (UniformSampling): the legacy unweighted body —
      ``client_update`` + ``server_aggregate`` — bit-for-bit identical
      to the pre-schedule engine (the schedule arrays are threaded but
      unused, so XLA drops them).
    * scheduled=True: ``client_update_steps`` honors each client's
      traced step budget and ``server_aggregate_weighted`` applies the
      round's normalized weights; the reported round loss is the
      weighted mean of each client's per-live-step mean loss.

    Rotating partial-comm masks fold the schedule's round index into the
    mask inside the scan body (``chunk_ids == round % period``); the
    expensive per-leaf permutations happen once per block, outside it.

    Pooled runs (``pooled=True``) scan the carry ``(phi, PoolState)``
    instead: the round body gathers the cohort's per-client state rows
    by the schedule's cohort indices, runs the scheduled client phase,
    aggregates (immediately, or into the FedBuff buffer when
    ``buffered`` is set — the buffer flushes through
    ``server_aggregate_weighted`` with staleness-discounted weights
    every ``buffer_size`` arrivals), and scatters the updated rows back
    — all inside the scan, so persistent identities and async
    aggregation still cost ZERO per-round host dispatches.

    Mesh runs (``mesh`` is a 1-D "clients" Mesh) wrap the same scan in
    ``shard_map`` manual over the client axis: each device holds phi
    REPLICATED and runs the client phase over its local cohort shard
    (the schedule's per-client rows and the batch arrive pre-sharded
    from the prefetcher's NamedSharding device_put), then aggregation
    reduces across shards — ``server_aggregate_weighted(...,
    axis_name="clients")``, whose ``weighted_client_mean`` fuses the
    per-leaf partial sums into ONE psum. Collectives are the sharded
    hot path's scarce resource (every all-reduce is a cross-device
    rendezvous), so that fused psum is the only per-round collective on
    the flat path: round losses stay shard-local partial sums and the
    whole (rounds,) vector all-reduces once per block. Pooled mesh runs
    shard the per-client ``PoolState`` rows too: one fused all_gather
    of the round's (tiny) cohort+participation rows lets each shard
    scatter updates for exactly the pool clients it OWNS (foreign
    indices route out of range and drop), while the FedBuff buffer
    becomes per-shard slabs — the flush predicate runs on REPLICATED
    count/oldest-tag counters carried by the scan (no per-round
    collective), and the flush itself normalizes by a psum-reduced
    weight denominator and folds through the collective aggregation
    hook: "the buffer reduced across shards at flush". The mesh path
    always runs the scheduled body (uniform schedules are just uniform
    weights there, with the per-step masking skipped — see ``masked``).

    2-D runs (``mesh`` is a ("clients", "model") Mesh from
    ``client_model_mesh``) take the GSPMD route instead: the GLOBAL
    block bodies (``axis is None`` — the same code a flat run traces)
    compile under plain ``jax.jit`` against the mesh, with all sharding
    flowing from the COMMITTED input layouts — phi carries the run's
    ``ModelPartitioner`` NamedShardings (weight matrices split on the
    model axis, norms/biases replicated; ``pin_phi`` re-asserts them at
    block entry/exit so the donated carry keeps one layout and the
    runner keeps one trace), and the schedule/batch rows arrive sharded
    over "clients". The partitioner vmaps the client phase over the
    clients axis and emits the cross-client reduction plus any in-loop
    model-axis collectives itself, compiler-scheduled. No manual
    ``shard_map`` is involved: partial-manual lowering (manual over
    "clients", auto over "model") hits an XLA sharding-propagation
    CHECK on this toolchain for scan-with-outputs under vmap inside
    lax.cond — a shape user-pluggable strategy hooks are free to
    produce — so the manual route is 1-D only. Pool state stages in
    the flat (``shards == 1``) layout.

    ``trace_count`` increments once per jit trace; with the engine's
    fixed per-run block shape it stays at 1 per (strategy, beta,
    channel, schedule-shape, pool-shape, masked, mesh) config — the
    retrace-free contract's observable.
    """

    def __init__(self, strategy, beta, channel: CommChannel,
                 scheduled: bool = False, pooled: bool = False,
                 buffered: Optional[BufferedAggregation] = None,
                 mesh: Optional[Mesh] = None,
                 masked: Optional[bool] = None, partitioner=None):
        self.trace_count = 0
        # 2-D (clients, model) meshes take the GSPMD route: axis=None
        # selects the global block bodies (no manual shard_map, no named
        # collectives) and the mesh partitions them from the committed
        # input shardings — see the model_sharded comment below.
        axis = (CLIENT_AXIS if mesh is not None
                and MODEL_AXIS not in mesh.axis_names else None)
        if mesh is not None:
            if not scheduled:
                raise ValueError("mesh runs always use the scheduled "
                                 "body (engine-internal invariant)")
            self._check_collective_hook(strategy)
        # masked: whether the scheduled client phase honors per-client
        # step budgets via the lax.cond-masked hooks. Uniform schedules
        # (full budget everywhere — every mesh run of UniformSampling,
        # every pooled uniform run) skip the per-step masking: the
        # masked hooks reproduce the unmasked ones op-for-op at k ==
        # budget (pinned in tests), but pay one lax.cond per inner
        # step, which is pure overhead on the hot path.
        self.masked = scheduled if masked is None else bool(masked)
        masked_hooks = self.masked
        beta_f = jnp.float32(beta)
        simulate = channel.simulates_quantization
        uplink_ref = getattr(strategy, "uplink_ref", "params")
        needs_ref = getattr(channel, "needs_uplink_ref", False)
        partial = getattr(channel, "fraction", 1.0) < 1.0
        rotating = partial and bool(getattr(channel, "rotate", False))

        def client_phase(phi, sched, batch, masks, chunk_ids):
            """Downlink -> vmapped client hook -> uplink: the wire-and-
            compute half of a round, shared by every scan body."""
            m = masks
            if chunk_ids is not None:
                m = channel.masks_for_round(chunk_ids, sched.round_index)
            phi_down = (channel.transmit(phi, masks=m)
                        if simulate else phi)
            if scheduled and masked_hooks:
                results, losses = jax.vmap(
                    lambda b, k: strategy.client_update_steps(
                        phi_down, b, beta_f, k))(batch, sched.local_steps)
            else:
                results, losses = jax.vmap(
                    lambda b: strategy.client_update(phi_down, b,
                                                     beta_f))(batch)
            if simulate:
                # the uplink fallback is the SERVER's own state
                # (phi, pre-wire), not the quantized broadcast
                # the clients saw
                ref = None
                if needs_ref and uplink_ref == "params":
                    ref = phi
                elif needs_ref and uplink_ref == "zeros":
                    ref = jax.tree.map(jnp.zeros_like, phi)
                results = channel.transmit(
                    results, ref=ref,
                    masks=m if ref is not None else None)
            return results, losses

        def weighted_round_loss(losses, sched):
            k = jnp.maximum(sched.local_steps, 1).astype(jnp.float32)
            per_client = losses.reshape(
                (losses.shape[0], -1)).sum(axis=1) / k
            # zero-weight clients are inert here too: their loss on a
            # zeroed batch may be non-finite and 0 * NaN would poison
            # the round loss (same guard as
            # strategies.weighted_client_mean)
            return jnp.sum(sched.weights * jnp.where(
                sched.weights > 0, per_client, 0.0))

        def make_round_fn(masks, chunk_ids):
            def round_fn(phi, xs):
                sched, batch = xs    # sched: one ClientSchedule row;
                #                      batch leaves: (C, S, ...) — the
                #                      LOCAL cohort shard on mesh runs

                def live(phi):
                    results, losses = client_phase(phi, sched, batch,
                                                   masks, chunk_ids)
                    if axis is not None:
                        phi = strategy.server_aggregate_weighted(
                            phi, results, sched.alpha, beta_f,
                            sched.weights, axis_name=axis)
                        # the round loss stays a SHARD-LOCAL partial sum
                        # here; the block body all-reduces the whole
                        # (rounds,) vector once per block — a per-round
                        # scalar psum would pay one extra cross-device
                        # rendezvous every round
                        loss = weighted_round_loss(losses, sched)
                    elif scheduled:
                        phi = strategy.server_aggregate_weighted(
                            phi, results, sched.alpha, beta_f,
                            sched.weights)
                        loss = weighted_round_loss(losses, sched)
                    else:
                        phi = strategy.server_aggregate(phi, results,
                                                        sched.alpha, beta_f)
                        loss = jnp.mean(losses)
                    return phi, loss

                def dead(phi):
                    return phi, jnp.float32(0.0)

                return jax.lax.cond(sched.valid, live, dead, phi)
            return round_fn

        _NEVER = jnp.int32(2 ** 30)      # "no buffered update" round tag

        def staleness_overdue(buf_round, count, cap, round_index):
            """The availability-aware flush predicate (one extra
            comparison OR-ed into the flush cond): True when holding
            the buffer past this round would let its oldest update
            reach the staleness deadline. (Unsharded path; the mesh
            path tracks the replicated oldest tag in the scan carry —
            see make_pooled_round_fn — so no per-round collective is
            needed there either.)"""
            valid = jnp.arange(cap) < count
            oldest = jnp.where(valid, buf_round, _NEVER).min()
            return (count > 0) & (round_index - oldest + 1
                                  >= buffered.flush_staleness)

        def make_pooled_round_fn(masks, chunk_ids):
            def round_fn(carry, xs):
                sched, batch = xs

                def live(carry):
                    if axis is not None:
                        # mesh carry: (phi, PoolState, replicated flush
                        # counters) — see live_sharded
                        phi, ps, gcount, goldest = carry
                        results, losses = client_phase(phi, sched, batch,
                                                       masks, chunk_ids)
                        return live_sharded(phi, ps, gcount, goldest,
                                            sched, results, losses)
                    phi, ps = carry
                    results, losses = client_phase(phi, sched, batch,
                                                   masks, chunk_ids)
                    if buffered is None:
                        phi = strategy.server_aggregate_weighted(
                            phi, results, sched.alpha, beta_f,
                            sched.weights)
                        buf, buf_round = ps.buf_updates, ps.buf_round
                        count, flushes = ps.buf_count, ps.flushes
                    else:
                        # append this round's arrivals at the buffer's
                        # write positions (a prefix-sum compaction of the
                        # participation mask); non-participants scatter
                        # to an out-of-range slot and are dropped
                        cap = ps.buf_round.shape[0]
                        arrive = sched.participation.astype(jnp.int32)
                        slot = jnp.where(
                            sched.participation,
                            ps.buf_count + jnp.cumsum(arrive) - 1, cap)
                        buf = jax.tree.map(
                            lambda b, q: b.at[slot].set(
                                q.astype(b.dtype), mode="drop"),
                            ps.buf_updates, results)
                        buf_round = ps.buf_round.at[slot].set(
                            sched.round_index, mode="drop")
                        count = ps.buf_count + arrive.sum()

                        def flush(args):
                            phi, buf, buf_round, count, flushes = args
                            tau = (sched.round_index
                                   - buf_round).astype(jnp.float32)
                            w = (buffered.staleness_fn(tau)
                                 * (jnp.arange(cap) < count))
                            w = (w / jnp.maximum(w.sum(), 1e-8)
                                 ).astype(jnp.float32)
                            phi = strategy.server_aggregate_weighted(
                                phi, buf, sched.alpha, beta_f, w)
                            return phi, jnp.int32(0), flushes + 1

                        def hold(args):
                            phi, buf, buf_round, count, flushes = args
                            return phi, count, flushes

                        do_flush = count >= buffered.buffer_size
                        if buffered.flush_staleness is not None:
                            do_flush = do_flush | staleness_overdue(
                                buf_round, count, cap, sched.round_index)
                        phi, count, flushes = jax.lax.cond(
                            do_flush, flush, hold,
                            (phi, buf, buf_round, count, ps.flushes))

                    # scatter the cohort's identity-state rows back:
                    # non-participants route to the out-of-range index
                    # n and are dropped; cohort indices are unique per
                    # round, so set/add never collide
                    n = ps.last_seen.shape[0]
                    idx = jnp.where(sched.participation, sched.cohort, n)
                    gap = (sched.round_index
                           - ps.last_seen[sched.cohort]).astype(jnp.int32)
                    ps = PoolState(
                        last_seen=ps.last_seen.at[idx].set(
                            sched.round_index, mode="drop"),
                        staleness=ps.staleness.at[idx].set(
                            gap, mode="drop"),
                        checkins=ps.checkins.at[idx].add(1, mode="drop"),
                        buf_updates=buf, buf_round=buf_round,
                        buf_count=count, flushes=flushes)
                    return (phi, ps), weighted_round_loss(losses, sched)

                def live_sharded(phi, ps, gcount, goldest, sched, results,
                                 losses):
                    # mesh round: phi replicated, per-client state rows
                    # and the cohort/batch sharded over the client
                    # axis. Per-round collectives are kept to the bare
                    # minimum — ONE fused all_gather of the (tiny)
                    # cohort+participation rows and the aggregation's
                    # fused psum; the flush predicate runs on the
                    # REPLICATED (gcount, goldest) counters carried by
                    # the scan, and the round loss stays a shard-local
                    # partial (all-reduced once per block).
                    c_local = sched.cohort.shape[0]
                    packed = jnp.concatenate(
                        [sched.cohort,
                         sched.participation.astype(jnp.int32)])
                    packed = jax.lax.all_gather(packed, axis)
                    cohort_f = packed[:, :c_local].reshape(-1)
                    part_f = packed[:, c_local:].reshape(-1) > 0

                    if buffered is None:
                        phi = strategy.server_aggregate_weighted(
                            phi, results, sched.alpha, beta_f,
                            sched.weights, axis_name=axis)
                        buf, buf_round = ps.buf_updates, ps.buf_round
                        count, flushes = ps.buf_count, ps.flushes
                    else:
                        # per-shard slab: local arrivals compact into
                        # THIS shard's buffer; the flush predicate is
                        # on the replicated global count, and the flush
                        # itself is a weighted all-reduce with a
                        # psum-normalized denominator — "the buffer
                        # reduced across shards at flush"
                        cap = ps.buf_round.shape[0]
                        arrive = sched.participation.astype(jnp.int32)
                        cnt = ps.buf_count[0]        # local fill level
                        slot = jnp.where(
                            sched.participation,
                            cnt + jnp.cumsum(arrive) - 1, cap)
                        buf = jax.tree.map(
                            lambda b, q: b.at[slot].set(
                                q.astype(b.dtype), mode="drop"),
                            ps.buf_updates, results)
                        buf_round = ps.buf_round.at[slot].set(
                            sched.round_index, mode="drop")
                        cnt = cnt + arrive.sum()
                        gcount = gcount + part_f.sum()
                        goldest = jnp.where(part_f.any(),
                                            jnp.minimum(goldest,
                                                        sched.round_index),
                                            goldest)

                        def flush(args):
                            phi, buf, buf_round, cnt, flushes = args
                            tau = (sched.round_index
                                   - buf_round).astype(jnp.float32)
                            w = (buffered.staleness_fn(tau)
                                 * (jnp.arange(cap) < cnt))
                            denom = jax.lax.psum(w.sum(), axis)
                            w = (w / jnp.maximum(denom, 1e-8)
                                 ).astype(jnp.float32)
                            phi = strategy.server_aggregate_weighted(
                                phi, buf, sched.alpha, beta_f, w,
                                axis_name=axis)
                            return phi, jnp.int32(0), flushes + 1

                        def hold(args):
                            phi, buf, buf_round, cnt, flushes = args
                            return phi, cnt, flushes

                        do_flush = gcount >= buffered.buffer_size
                        if buffered.flush_staleness is not None:
                            do_flush = do_flush | (
                                (gcount > 0)
                                & (sched.round_index - goldest + 1
                                   >= buffered.flush_staleness))
                        phi, cnt, flushes = jax.lax.cond(
                            do_flush, flush, hold,
                            (phi, buf, buf_round, cnt, ps.flushes))
                        gcount = jnp.where(do_flush, 0, gcount)
                        goldest = jnp.where(do_flush, _NEVER, goldest)
                        count = cnt[None]            # back to (1,) local

                    # scatter identity rows for the pool clients THIS
                    # shard owns, wherever in the cohort they sat:
                    # foreign/idle indices route out of range and drop
                    n_local = ps.last_seen.shape[0]
                    base = jax.lax.axis_index(axis) * n_local
                    loc = cohort_f - base
                    own = part_f & (loc >= 0) & (loc < n_local)
                    idx = jnp.where(own, loc, n_local)
                    safe = jnp.clip(loc, 0, n_local - 1)
                    gap = (sched.round_index
                           - ps.last_seen[safe]).astype(jnp.int32)
                    ps = PoolState(
                        last_seen=ps.last_seen.at[idx].set(
                            sched.round_index, mode="drop"),
                        staleness=ps.staleness.at[idx].set(
                            gap, mode="drop"),
                        checkins=ps.checkins.at[idx].add(1, mode="drop"),
                        buf_updates=buf, buf_round=buf_round,
                        buf_count=count, flushes=flushes)
                    loss = weighted_round_loss(losses, sched)
                    return (phi, ps, gcount, goldest), loss

                def dead(carry):
                    return carry, jnp.float32(0.0)

                return jax.lax.cond(sched.valid, live, dead, carry)
            return round_fn

        def mask_state(phi):
            # the partial-channel mask state is constant for the whole
            # run: build it here, OUTSIDE the scan body, so the per-leaf
            # permutations execute once per block instead of every round
            # (rotating channels precompute chunk ids; the per-round mask
            # is one elementwise compare against the scanned round index)
            masks = (channel.mask_tree(phi)
                     if simulate and partial and not rotating else None)
            chunk_ids = (channel.chunk_id_tree(phi)
                         if simulate and rotating else None)
            return masks, chunk_ids

        def sched_spec():
            # specs for the whole padded block: per-round vectors
            # replicated, per-client rows sharded on the client axis
            return ClientSchedule(
                valid=P(), alpha=P(), round_index=P(),
                participation=P(None, axis), local_steps=P(None, axis),
                weights=P(None, axis),
                cohort=P(None, axis) if pooled else None)

        # 2-D (clients, model) meshes run the GLOBAL (unsharded) block
        # body under plain jit — NO shard_map. All sharding flows from
        # the committed input layouts (phi carries the ModelPartitioner's
        # per-leaf NamedShardings, batch/schedule rows are split over the
        # clients axis), so GSPMD partitions the vmapped client phase
        # over "clients" and every model-axis collective the sharded
        # matmuls imply is compiler-scheduled. The weighted client mean
        # then reduces the clients-sharded results axis — one all-reduce
        # with phi's model shards aggregated IN PLACE (no gather of full
        # phi to any device). The per-round partial-manual shard_map form
        # (manual over "clients", auto over "model") is what
        # shard_map_compat was built for, but XLA's partitioner in this
        # toolchain hard-aborts (CHECK sharding.IsManualSubgroup) on
        # scan-emitting-outputs under vmap inside a manual subgroup —
        # strategy hooks are user-pluggable, so that pattern cannot be
        # outlawed. Pure GSPMD keeps both invariants (zero per-round
        # host dispatches, one jit trace) without restricting hooks.
        # ``pin_phi`` pins phi's layout at block entry/exit: GSPMD is
        # otherwise free to pick a different output layout, which would
        # re-commit the donated phi and retrace the next block.
        model_sharded = mesh is not None and MODEL_AXIS in mesh.axis_names
        if model_sharded:
            if partitioner is None:     # direct construction in tests
                from repro.runtime.sharding import DEFAULT_PARTITIONER
                partitioner = DEFAULT_PARTITIONER

            def pin_phi(phi):
                return jax.tree_util.tree_map_with_path(
                    lambda path, leaf: jax.lax.with_sharding_constraint(
                        leaf, NamedSharding(mesh, partitioner.spec(
                            path, leaf.shape, mesh))), phi)
        else:
            def pin_phi(phi):
                return phi

        if pooled:
            if axis is not None:
                # buf_count dummy must be RANK 1: this route carries the
                # mesh layout's (shards,) local fill levels, and
                # pool_state_specs replicates rank-0 fill counters (the
                # flat layout the 2-D GSPMD route runs in)
                state_spec = pool_state_specs(
                    PoolState(0, 0, 0,
                              buf_updates=(0 if buffered else None),
                              buf_round=(0 if buffered else None),
                              buf_count=(np.zeros(1, np.int32)
                                         if buffered else None),
                              flushes=(0 if buffered else None)),
                    axis)
            if axis is None:
                def block_body(phi, pool_state, sched, batch):
                    phi = pin_phi(phi)
                    masks, chunk_ids = mask_state(phi)
                    (phi, pool_state), losses = jax.lax.scan(
                        make_pooled_round_fn(masks, chunk_ids),
                        (phi, pool_state), (sched, batch))
                    return pin_phi(phi), pool_state, losses
            else:
                def block_body(phi, pool_state, sched, batch):
                    masks, chunk_ids = mask_state(phi)
                    # replicated flush counters enter the carry ONCE per
                    # block (one psum/pmin here instead of per round)
                    if buffered is not None:
                        cnt = pool_state.buf_count[0]
                        cap = pool_state.buf_round.shape[0]
                        gcount = jax.lax.psum(cnt, axis)
                        goldest = jax.lax.pmin(
                            jnp.where(jnp.arange(cap) < cnt,
                                      pool_state.buf_round, _NEVER).min(),
                            axis)
                    else:
                        gcount, goldest = jnp.int32(0), _NEVER
                    (phi, pool_state, _, _), losses = jax.lax.scan(
                        make_pooled_round_fn(masks, chunk_ids),
                        (phi, pool_state, gcount, goldest),
                        (sched, batch))
                    # per-round losses were shard-local partial sums
                    return phi, pool_state, jax.lax.psum(losses, axis)

            body = block_body
            if axis is not None:
                body = shard_map_compat(
                    block_body, mesh=mesh,
                    in_specs=(P(), state_spec, sched_spec(),
                              P(None, axis)),
                    out_specs=(P(), state_spec, P()),
                    manual_axes_names={axis})

            def run_block(phi, pool_state, sched, batch):
                self.trace_count += 1             # runs at trace time only
                return body(phi, pool_state, sched, batch)

            self._jit = jax.jit(run_block, donate_argnums=(0, 1))
        else:
            def block_body(phi, sched, batch):
                phi = pin_phi(phi)
                masks, chunk_ids = mask_state(phi)
                phi, losses = jax.lax.scan(make_round_fn(masks, chunk_ids),
                                           phi, (sched, batch))
                if axis is not None:
                    # per-round losses were shard-local partial sums;
                    # one (rounds,)-vector all-reduce per block
                    losses = jax.lax.psum(losses, axis)
                return pin_phi(phi), losses

            body = block_body
            if axis is not None:
                body = shard_map_compat(
                    block_body, mesh=mesh,
                    in_specs=(P(), sched_spec(), P(None, axis)),
                    out_specs=(P(), P()),
                    manual_axes_names={axis})

            def run_block(phi, sched, batch):
                self.trace_count += 1             # runs at trace time only
                return body(phi, sched, batch)

            self._jit = jax.jit(run_block, donate_argnums=(0,))

    @staticmethod
    def _check_collective_hook(strategy) -> None:
        """Mesh runs need the axis_name-aware collective aggregation
        form; fail at construction with a plugin-author-facing message
        instead of a TypeError from inside the trace."""
        try:
            sig = inspect.signature(strategy.server_aggregate_weighted)
        except (TypeError, ValueError):      # builtins/partials: assume ok
            return
        params = sig.parameters.values()
        if not ("axis_name" in sig.parameters or any(
                p.kind is inspect.Parameter.VAR_KEYWORD for p in params)):
            raise ValueError(
                f"{type(strategy).__name__}.server_aggregate_weighted "
                f"does not accept axis_name=: mesh-sharded runs reduce "
                f"the weighted client aggregate across the "
                f"'{CLIENT_AXIS}' mesh axis — add axis_name=None to the "
                f"hook and route it through weighted_client_mean (see "
                f"docs/PLUGINS.md)")

    def __call__(self, *args):
        return self._jit(*args)


class _RunnerLRU:
    """Hand-rolled LRU replacing the old ``functools.lru_cache``: same
    counters and eviction order, but with INSPECTABLE keys, so
    ``runner_cache_stats`` can account for mesh-keyed entries (the old
    opaque cache could not tell a sharded runner from a flat one)."""

    def __init__(self, maxsize: int = 64):
        self.maxsize = maxsize
        self._entries: "collections.OrderedDict" = collections.OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key, build):
        """Cached runner for ``key`` (raises TypeError on unhashable
        keys, like lru_cache), building and LRU-inserting on a miss."""
        if key in self._entries:
            self._entries.move_to_end(key)
            self.hits += 1
            return self._entries[key]
        self.misses += 1
        runner = build()
        self._entries[key] = runner
        if len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
        return runner

    def keys(self):
        return list(self._entries.keys())

    def clear(self):
        self._entries.clear()
        self.hits = 0
        self.misses = 0


_RUNNER_CACHE = _RunnerLRU(maxsize=64)
_UNHASHABLE_MISSES = {"count": 0}


def _block_runner(strategy, beta, channel: CommChannel,
                  scheduled: bool = False, pooled: bool = False,
                  buffered: Optional[BufferedAggregation] = None,
                  mesh: Optional[Mesh] = None,
                  masked: Optional[bool] = None,
                  partitioner=None) -> _BlockRunner:
    """Strategies and channels are frozen dataclasses, so identically-
    configured runs (every test/bench re-entry) reuse one jitted runner
    instead of recompiling per call; ``scheduled`` (the policy's static
    schedule shape), ``pooled``, the ``buffered`` config, the
    ``partitioner`` (2-D-mesh runs: phi's model-axis layout is part of
    the traced program, so two partitionings never share an
    executable), and the ``mesh`` are part of the key. A Mesh hashes
    over its device list and axis names, so a runner traced for one
    device topology can NEVER be served for another (a 4-device and an
    8-device mesh are distinct keys, a 1-D and a 2-D mesh over the same
    devices differ in axis names, and jax.devices() cannot change
    within a process for the mesh=None entries). Unhashable custom
    strategies still work — they pay a fresh trace per run, counted and
    logged so sweeps notice."""
    masked = bool(scheduled) if masked is None else bool(masked)
    key = (strategy, float(beta), channel, bool(scheduled), bool(pooled),
           buffered, masked, partitioner, mesh)

    def build():
        return _BlockRunner(strategy, beta, channel, scheduled, pooled,
                            buffered, mesh, masked, partitioner)

    try:
        return _RUNNER_CACHE.get(key, build)
    except TypeError:
        _UNHASHABLE_MISSES["count"] += 1
        logger.warning(
            "block-runner cache miss #%d: strategy %s (channel %s) is "
            "unhashable; building an uncached jitted runner (fresh trace "
            "per run). Make custom strategies frozen dataclasses to cache "
            "them.", _UNHASHABLE_MISSES["count"],
            type(strategy).__name__, type(channel).__name__)
        return build()


def runner_cache_stats() -> Dict[str, int]:
    """Block-runner cache counters: lru hits/misses/size, how many
    times an unhashable strategy forced an uncached runner, and how
    many of the cached entries are mesh-keyed (sharded runners pin
    multi-device executables — sweeps over topologies should clear
    between phases)."""
    return {"hits": _RUNNER_CACHE.hits, "misses": _RUNNER_CACHE.misses,
            "currsize": len(_RUNNER_CACHE.keys()),
            "maxsize": _RUNNER_CACHE.maxsize,
            "unhashable_misses": _UNHASHABLE_MISSES["count"],
            "mesh_entries": sum(1 for k in _RUNNER_CACHE.keys()
                                if k[-1] is not None)}


def clear_runner_cache() -> None:
    """Drop every cached jitted block runner — mesh-keyed sharded
    runners included — and reset the counters. Long sweeps over many
    strategy/channel/topology configs should call this between phases
    so up to 64 stale executables don't stay pinned."""
    _RUNNER_CACHE.clear()
    _UNHASHABLE_MISSES["count"] = 0


@jax.jit
def _snapshot_copy(tree):
    """One fused dispatch copying the whole carry (vs one dispatch per
    leaf with a bare tree.map) — the snapshot path runs between donating
    block launches, so its host cost lands on the round hot path."""
    return jax.tree.map(jnp.copy, tree)


def run_federated(init_params, task_dist: TaskDistribution, strategy, *,
                  rounds: int, clients_per_round: int = 1,
                  alpha: float = 1.0, beta: float = 0.01, support: int = 32,
                  anneal: bool = True, seed: int = 0, eval_every: int = 0,
                  eval_kwargs: Optional[dict] = None,
                  channel: Optional[CommChannel] = None,
                  max_block: int = 512, prefetch: int = 2,
                  sampler: str = "reference",
                  sampling: Optional[SamplingPolicy] = None,
                  pool: Optional[ClientPool] = None,
                  buffered: Optional[BufferedAggregation] = None,
                  mesh=None, partitioner=None,
                  ckpt_dir: Optional[str] = None,
                  ckpt_every: int = 10, ckpt_keep: int = 3,
                  ckpt_async: bool = True, resume: bool = False,
                  tracker=None) -> Dict:
    """Run `rounds` federated rounds of `strategy`.

    Returns {"params", "history"} (+ "comm_bytes" and "per_client_bytes"
    for strategies that meter communication — per_client_bytes[c] is the
    total transport paid by cohort slot c over the run; only rounds the
    slot PARTICIPATES in are billed). History rows are per-eval dicts in
    the legacy loops' format: evaluate_init fields + round
    [+ comm_bytes, inner_loss].

    Rounds between evals execute as fixed-shape on-device scan blocks
    (padded to one per-run length, masked, `max_block`-bounded — see
    repro.core.pipeline.plan_blocks), so the block runner compiles once
    per (strategy, beta, channel, schedule-shape, pool-shape) config.
    The host only plans the per-round ClientSchedule and samples client
    data (`sampling` policy; `sampler` picks the legacy-exact
    "reference" RNG order or the "vectorized" fast path for the default
    uniform policy) and runs the eval protocol — heterogeneous scenarios
    (partial participation, stragglers, rotating partial-comm masks)
    ride the schedule through the scan with no extra per-round host
    dispatches. With `prefetch` > 0 a background thread plans, samples,
    and stages block N+1 while the device runs block N (double-buffered
    at the default 2); `prefetch=0` is the synchronous escape hatch —
    both are bit-for-bit identical.

    `pool` switches the run onto PERSISTENT client identities (a
    repro.core.pool.ClientPool over `task_dist`): each round the policy
    seats a cohort of pool clients (`plan_pool_schedule`), their stable
    per-client data shards feed the round, and the pool's cross-round
    state (last-seen round, staleness, check-in counts) updates inside
    the scan. `buffered` (requires `pool`) turns aggregation
    FedBuff-style async: check-ins append to a server buffer that
    flushes every `buffer_size` arrivals with staleness-discounted
    weights. Pooled metered runs bill per POOL CLIENT
    (per_client_bytes has pool.size entries) and return a "pool_state"
    dict (last_seen / staleness / checkins arrays [+ flushes,
    buffered_pending]); `pool=None` keeps the legacy anonymous-cohort
    path bit-for-bit.

    `mesh` SHARDS THE CLIENT AXIS across devices: pass a 1-D "clients"
    Mesh (see `client_mesh`), an int (first n devices), or "auto"
    (every device). The cohort is padded to a multiple of the device
    count with scheduled-out slots (participation False, weight 0), the
    prefetcher stages each block with a NamedSharding (client rows
    split, per-round vectors replicated), each device vmaps its local
    shard, and aggregation / transport-weight reductions run as
    collectives inside the scan — still zero per-round host dispatches
    and one jit trace per config. Schedules, host RNG draws, billing,
    and pooled identity state are mesh-INDEPENDENT: an N-device run
    computes the same training trajectory as the 1-device run up to
    float reduction order. `mesh=None` (default) is bit-for-bit the
    single-device engine.

    `ckpt_dir` makes the run PREEMPTION-SAFE: at every block boundary
    crossing a multiple of `ckpt_every` rounds (blocks are additionally
    cut there — bitwise-neutral) the engine snapshots the complete scan
    carry as a repro.checkpoint.RoundState — phi, PoolState (incl.
    FedBuff buffer slabs), per-client transport bills, eval history,
    and the host RNG / pool-stream / policy state captured at the
    prefetch producer — via a background AsyncCheckpointWriter
    (device->host transfer off the critical path, bounded queue, atomic
    checksum-manifested files, last-`ckpt_keep` retention;
    `ckpt_async=False` writes inline). `resume=True` restores the
    newest VALID snapshot (torn/corrupted files fall back with a
    warning) and fast-forwards block planning: a killed-and-resumed run
    is bit-for-bit identical — params, pool state, history rows, and
    bills — to the uninterrupted seeded run. `rounds` may grow between
    the original run and the resume (training continues past the old
    horizon); seed/cohort/pool/mesh-shard mismatches are rejected via a
    config fingerprint.

    `tracker` attaches a `repro.metering.MetricsTracker`: per-round
    inner losses, cumulative transport bytes, eval rows, runner-cache /
    wall-clock gauges, and (pooled runs) the end-of-run staleness
    distribution flow into it, and a tracker with `profile_dir=` set
    brackets the scan loop in the JAX profiler. The tracker is
    host-side observation only — attaching one is bit-for-bit inert
    (the per-block loss fetch happens ONLY when a tracker is present,
    and feeds nothing back).
    """
    if channel is None:
        channel = CommChannel()
    if sampling is None:
        # a pooled run's host-path contract is the POOL's sampler: a
        # vectorized (fleet-scale) pool must also seat cohorts through
        # the O(cohort) block path, not the per-round O(N) choice loop
        if pool is not None and sampler == "reference":
            sampling = UniformSampling(pool.sampler)
        else:
            sampling = UniformSampling(sampler)
    elif sampler != "reference":
        # an explicit policy owns its own sampler choice; silently
        # ignoring a non-default `sampler=` string would run a different
        # host path than the caller asked for
        raise ValueError(
            f"pass the sampler on the sampling policy (e.g. "
            f"{type(sampling).__name__}(..., sampler={sampler!r})), not "
            f"as run_federated(sampler=...) alongside sampling=")
    pooled = pool is not None
    if buffered is not None:
        if not pooled:
            raise ValueError("buffered aggregation needs persistent "
                             "clients to be stale against: pass "
                             "pool=ClientPool(...) alongside buffered=")
        if getattr(strategy, "uplink_ref", "params") == "none":
            raise ValueError(
                f"{type(strategy).__name__} uplinks raw data "
                f"(uplink_ref='none'); the FedBuff buffer holds "
                f"phi-shaped updates and cannot stage it")
    if pooled and pool.size < clients_per_round:
        raise ValueError(f"pool of {pool.size} clients cannot seat a "
                         f"cohort of {clients_per_round} (identities are "
                         f"unique within a round)")
    payload_dtype = getattr(strategy, "payload_dtype", "float32")
    if payload_dtype != "float32" and (channel.simulates_quantization
                                       or channel.dtype != payload_dtype):
        raise ValueError(
            f"{type(strategy).__name__} uplinks NATIVE {payload_dtype} "
            f"result trees (payload_dtype={payload_dtype!r}): the channel "
            f"must bill at that wire rate and must not re-simulate "
            f"quantization on already-quantized payloads — pass "
            f"CommChannel({payload_dtype!r}, quantize=False), got "
            f"{type(channel).__name__}(dtype={channel.dtype!r}, "
            f"simulates_quantization={channel.simulates_quantization})")
    mesh = _resolve_mesh(mesh)
    # the cohort is split over the CLIENTS axis extent only; on a 2-D
    # (clients, model) mesh the model axis splits phi's weight
    # matrices, not the cohort
    shards = int(mesh.shape[CLIENT_AXIS]) if mesh is not None else 1
    model_sharded = _model_sharded(mesh)
    if model_sharded:
        from repro.runtime.sharding import DEFAULT_PARTITIONER
        if partitioner is None:
            partitioner = DEFAULT_PARTITIONER
        if getattr(strategy, "payload_dtype", "float32") == "int8":
            raise ValueError(
                f"{type(strategy).__name__} uplinks NATIVE int8 trees "
                f"whose per-tensor quantization grids assume each "
                f"parameter tensor is whole on every device; a 2-D "
                f"('{CLIENT_AXIS}', '{MODEL_AXIS}') mesh shards phi's "
                f"weight matrices — run int8 strategies on a 1-D "
                f"'{CLIENT_AXIS}' mesh (or mesh=None) instead")
    elif partitioner is not None:
        raise ValueError(
            f"partitioner= only applies to a 2-D ('{CLIENT_AXIS}', "
            f"'{MODEL_AXIS}') mesh (build one with "
            f"repro.runtime.sharding.client_model_mesh); this run's mesh "
            f"is {'1-D' if mesh is not None else 'None'} and phi stays "
            f"replicated")
    # a mesh spanning >1 process (jax.distributed) changes only HOW
    # arrays move: every process runs this same host loop on the same
    # seed (plans, rng draws, and bills are process-replicated), each
    # contributes its addressable shard at staging, and device->host
    # reads of client-sharded state go through a replicating collective
    multiproc = (mesh is not None and
                 len({d.process_index for d in mesh.devices.flat}) > 1)

    def stage_tree(tree, target):
        """device_put — or, cross-host, per-leaf global-array assembly
        from the process-replicated host copy (device_put cannot build
        an array it only partially addresses)."""
        if not multiproc:
            return jax.device_put(tree, target)
        return jax.tree.map(
            lambda x, s: jax.make_array_from_callback(
                np.shape(x), s, lambda idx, _x=np.asarray(x): _x[idx]),
            tree, target)

    def fetch_tree(tree):
        """device_get — or, cross-host, an all-gather into replicated
        form first (client-sharded leaves are not fully addressable
        from any one process). The gather is a collective: every
        process calls this at the same points, which the lockstep host
        loop guarantees."""
        if not multiproc:
            return jax.device_get(tree)
        rep = jax.jit(
            lambda t: t,
            out_shardings=jax.tree.map(
                lambda _: NamedSharding(mesh, P()), tree))(tree)
        return jax.tree.map(np.asarray, rep)
    # mesh runs pad the cohort to a multiple of the shard count: the
    # pad slots are permanently scheduled out (participation False,
    # weight 0, zero batch) so every device sees an equal shard and the
    # validity-mask machinery keeps them inert
    c_pad = -(-clients_per_round // shards) * shards
    # pool-state LAYOUT: the 1-D manual shard_map body needs the
    # per-shard layout (per-shard FedBuff slabs, (shards,) local fill
    # levels); the 2-D GSPMD route runs the GLOBAL body, which sees the
    # whole state like a flat run does — build the shards == 1 layout
    # and let the committed input shardings split it
    state_shards = 1 if model_sharded else shards
    # residency="host" pools keep the (N,) identity arrays in host
    # slabs; the device carries only a fixed gathered WINDOW of the
    # rows each block actually touches (O(block cohort), not O(N)) —
    # the producer remaps cohort indices window-local, the consumer
    # stages the window before each block and scatters it back after
    host_resident = pooled and pool.residency == "host"
    slabs = pool.init_slabs(shards=state_shards) if host_resident else None
    rng = np.random.default_rng(seed)
    # private copy: the block runner donates its phi argument, and the
    # caller's init_params must stay usable (they are reused across runs)
    phi = jax.tree.map(jnp.array, init_params)
    history: List[Dict] = []
    comm_bytes = 0
    start_round = 0
    per_client_bytes = np.zeros(pool.size if pooled else clients_per_round,
                                np.int64)
    uniform = getattr(sampling, "schedule_kind", "scheduled") == "uniform"
    scheduled = pooled or mesh is not None or not uniform
    # uniform schedules run every client at the full budget, so the
    # scheduled body skips the per-step lax.cond masking (bit-for-bit
    # identical at k == budget, without the per-inner-step overhead)
    masked = scheduled and not uniform
    budget = int(strategy.local_step_budget(support))
    run_block = _block_runner(strategy, beta, channel, scheduled,
                              pooled=pooled, buffered=buffered, mesh=mesh,
                              masked=masked, partitioner=partitioner)
    # FedBuff buffers stage whatever the strategy uplinks — sized from
    # its template so quantized strategies buffer int8 trees at int8
    # width, never dequantized copies
    uplink_template = getattr(strategy, "uplink_template", None)
    pool_state = (pool.init_state(
        phi, c_pad, buffered, shards=state_shards,
        template=uplink_template(phi) if uplink_template else None)
        if pooled else None)
    if ckpt_dir is not None:
        if not (isinstance(ckpt_every, int) and ckpt_every >= 1):
            raise ValueError(f"ckpt_every must be an int >= 1, got "
                             f"{ckpt_every!r}")
        if not (isinstance(ckpt_keep, int) and ckpt_keep >= 1):
            raise ValueError(f"ckpt_keep must be an int >= 1, got "
                             f"{ckpt_keep!r}")
        # config identity stamped into every snapshot: a resume under a
        # different seed/cohort/pool/mesh would replay a DIFFERENT run
        # from this run's carry — reject it instead of training garbage
        fingerprint = {
            "seed": int(seed), "clients_per_round": int(clients_per_round),
            "support": int(support), "shards": int(shards),
            # full mesh topology + partitioning identity: a snapshot of
            # model-sharded (or differently-mesh-shaped) phi must never
            # silently resume into a run with a different layout
            "mesh": (",".join(f"{a}:{int(mesh.shape[a])}"
                              for a in mesh.axis_names)
                     if mesh is not None else ""),
            "partitioner": partitioner.name if partitioner is not None
            else "",
            "strategy": type(strategy).__name__,
            "pool_size": int(pool.size) if pooled else 0,
            "pool_sampler": pool.sampler if pooled else "",
            "policy_sampler": getattr(sampling, "sampler", "reference"),
            "buffered": buffered is not None}
    elif resume:
        raise ValueError("resume=True needs ckpt_dir= to restore from")
    if resume:
        try:
            saved = restore_round_state(
                ckpt_dir, phi=phi, pool_state=pool_state,
                per_client_bytes=per_client_bytes)
        except FileNotFoundError:
            logger.info("resume: no snapshot in %s yet; starting fresh",
                        ckpt_dir)
            saved = None
        if saved is not None:
            diff = {k: (saved.fingerprint.get(k), v)
                    for k, v in fingerprint.items()
                    if saved.fingerprint and saved.fingerprint.get(k) != v}
            if diff:
                raise ValueError(
                    f"checkpoint in {ckpt_dir} was written by a different "
                    f"run config (saved != current): {diff}")
            if saved.round > rounds:
                raise ValueError(
                    f"checkpoint in {ckpt_dir} is at round {saved.round}, "
                    f"past rounds={rounds}; raise the horizon to continue")
            start_round = int(saved.round)
            phi = jax.tree.map(jnp.asarray, saved.phi)
            if pooled:
                pool_state = jax.tree.map(jnp.asarray, saved.pool_state)
                pool.load_host_state(saved.host.get("pool", {}))
            per_client_bytes = np.asarray(saved.per_client_bytes,
                                          np.int64).copy()
            comm_bytes = int(saved.comm_bytes)
            history = list(saved.history)
            # the host rng resumes EXACTLY where the interrupted run's
            # producer stopped drawing — the bit-for-bit contract
            rng.bit_generator.state = saved.host["rng"]
            sampling.load_state_dict(saved.host.get("sampling", {}),
                                     rng=rng)
            logger.info("resumed %s from round %d", ckpt_dir, start_round)
    blocks, pad = plan_blocks(rounds, eval_every, max_block,
                              start=start_round,
                              ckpt_every=ckpt_every if ckpt_dir else 0)
    if host_resident:
        # flush the full (possibly just-restored) identity into the
        # host slabs, then shrink the device carry to the gathered
        # window: one row per DISTINCT client a block can seat (a block
        # has pad rounds of c_pad slots), fixed for the whole run so
        # the runner still compiles once
        n_full = len(slabs["last_seen"])
        pool.scatter_rows(
            np.arange(n_full),
            {f: np.asarray(getattr(pool_state, f))
             for f in ClientPool.SLAB_FIELDS})
        slab_rows = min(n_full,
                        -(-pad * c_pad // state_shards) * state_shards)
        win = pool.init_state(
            phi, c_pad, buffered, shards=state_shards,
            template=uplink_template(phi) if uplink_template else None,
            rows=slab_rows)
        # identity rows are re-staged from the slabs every block; the
        # FedBuff buffer is SERVER state and carries over (restored
        # buffers survive the shrink)
        pool_state = PoolState(
            win.last_seen, win.staleness, win.checkins,
            pool_state.buf_updates, pool_state.buf_round,
            pool_state.buf_count, pool_state.flushes)
    if mesh is not None:
        # 1-D mesh: phi fully replicated. 2-D mesh: each leaf carries
        # the partitioner's NamedSharding — weight matrices split on
        # the model axis, norms/biases replicated — and stays that way
        # through the whole run (aggregation psums over the clients
        # axis leave the model-axis shards in place; phi is never
        # gathered whole onto one device)
        phi = jax.device_put(
            phi, partitioner.shardings(phi, mesh) if model_sharded
            else NamedSharding(mesh, P()))
    if mesh is not None and pooled:
        # 1-D manual route: rows and FedBuff slabs are device_put in
        # shard_map's layout. 2-D GSPMD route: the flat-layout state is
        # staged replicated (rows are O(N) int32, not padded to the
        # clients extent) and the compiler re-shards inside the block
        # as the client-phase shardings dictate.
        pool_state = stage_tree(
            jax.tree.map(np.asarray, pool_state) if multiproc
            else pool_state,
            jax.tree.map(lambda s: NamedSharding(mesh, s),
                         (jax.tree.map(lambda _: P(), pool_state)
                          if model_sharded
                          else pool_state_specs(pool_state, CLIENT_AXIS)),
                         is_leaf=lambda x: isinstance(x, P)))

    def ckpt_at(end):
        """Deterministic snapshot predicate, shared by the producer's
        host-state capture and the consumer's device-state snapshot
        (plan_blocks cuts blocks at these rounds when ckpt_dir is set)."""
        return ckpt_dir is not None and (end == rounds
                                         or end % ckpt_every == 0)

    def snapshot_host():
        """Host-side carry at 'all draws for blocks <= i done' — called
        on the prefetch producer right after block i's sampling, so a
        resume continues the rng/pool/policy streams exactly where the
        uninterrupted run's producer would."""
        snap = {"rng": copy.deepcopy(rng.bit_generator.state)}
        if pooled:
            snap["pool"] = pool.host_state()
        policy_state = sampling.state_dict()
        if policy_state:
            snap["sampling"] = policy_state
        return snap

    host_snaps: Dict[int, dict] = {}
    writer = (AsyncCheckpointWriter(ckpt_dir, keep=ckpt_keep)
              if ckpt_dir is not None and ckpt_async and blocks else None)
    device = single_device_of(phi)       # staging target for the prefetcher
    if strategy.meters_comm:
        # per-round payloads repeat with the channel's rotation period
        # (period 1 = the constant legacy accounting)
        period = (channel.rotation_period
                  if getattr(channel, "rotate", False) else 1)
        payload_by_phase = np.array(
            [channel.payload_bytes_at(init_params, j) for j in range(period)],
            np.int64)

    def stage(i):
        """Plan the schedule, sample, pad, and device-stage block i.
        Called strictly in block order (inline, or from the single
        prefetch thread), so the host RNG stream is
        prefetch-schedule-independent: plan_schedule (or its pooled
        variant) draws first, then the data sampling, every block."""
        start, end = blocks[i]
        blk = end - start
        if pooled:
            plan = sampling.plan_pool_schedule(rng, start, end,
                                               clients_per_round, budget,
                                               pool.size)
            part = np.asarray(plan["participation"], bool)
            cohort = np.asarray(plan["cohort"], np.int32)
            batch = pool.sample_cohort_block(cohort, part, support,
                                             strategy.data_mode)
            if host_resident:
                # remap global cohort ids to window-local rows: the
                # sorted distinct participants seat the window prefix,
                # searchsorted inverts the map. Non-participant slots
                # clamp into range (they are masked in-scan) and
                # billing keeps the GLOBAL ids.
                uniq = np.unique(cohort[part]).astype(np.int64)
                if uniq.size:
                    local = np.searchsorted(uniq, cohort).astype(np.int32)
                    np.clip(local, 0, uniq.size - 1, out=local)
                else:
                    local = np.zeros_like(cohort)
                sched_cohort = local
            else:
                uniq = None
                sched_cohort = cohort
        else:
            plan = sampling.plan_schedule(rng, start, end,
                                          clients_per_round, budget)
            part = np.asarray(plan["participation"], bool)
            cohort = uniq = sched_cohort = None
            batch = sampling.sample_block(task_dist, rng, blk,
                                          clients_per_round, support,
                                          strategy.data_mode,
                                          participation=part)
        r = np.arange(start, end)
        alphas = np.zeros(pad, np.float32)
        alphas[:blk] = alpha * (1 - r / rounds) if anneal else alpha
        valid = np.zeros(pad, bool)
        # pooled rounds where nobody checked in (an availability trough)
        # are runtime no-ops, same as the padding mask
        valid[:blk] = part.any(axis=1) if pooled else True
        round_index = np.zeros(pad, np.int32)
        round_index[:blk] = r

        def pad_rows(a, dtype):
            # pads BOTH axes: short tail blocks on the round axis and
            # the mesh cohort pad (c_pad == clients_per_round off-mesh)
            out = np.zeros((pad, c_pad), dtype)
            out[:blk, :clients_per_round] = a
            return out

        sched = ClientSchedule(
            valid=valid, alpha=alphas, round_index=round_index,
            participation=pad_rows(part, bool),
            local_steps=pad_rows(plan["local_steps"], np.int32),
            weights=pad_rows(plan["weights"], np.float32),
            cohort=pad_rows(sched_cohort, np.int32) if pooled else None)
        batch = {k: np.asarray(v) for k, v in batch.items()}
        if c_pad > clients_per_round:
            batch = {k: np.concatenate(
                [v, np.zeros((v.shape[0], c_pad - clients_per_round)
                             + v.shape[2:], v.dtype)], axis=1)
                for k, v in batch.items()}
        if blk < pad:
            batch = {k: np.concatenate(
                [v, np.zeros((pad - blk,) + v.shape[1:], v.dtype)])
                for k, v in batch.items()}
        target = (block_shardings(mesh, CLIENT_AXIS, (sched, batch))
                  if mesh is not None else device)
        if ckpt_at(end):
            host_snaps[end] = snapshot_host()
        return part, cohort, uniq, stage_tree((sched, batch), target)

    id_sharding = (NamedSharding(mesh, P(CLIENT_AXIS))
                   if mesh is not None else device)

    def stage_window(uniq):
        """Gather the block's identity rows from the host slabs onto
        device (window prefix = the block's distinct participants, tail
        rows inert). Runs on the CONSUMER, after the previous block's
        write-back — the prefetch thread never races the slabs."""
        uniq_pad = np.zeros(slab_rows, np.int64)
        uniq_pad[:uniq.size] = uniq
        rows = pool.gather_rows(uniq_pad)
        rows = tuple(rows[f] for f in ClientPool.SLAB_FIELDS)
        return stage_tree(rows, (None if id_sharding is None else
                                 tuple(id_sharding for _ in rows)))

    staged_iter = prefetch_items(stage, len(blocks), depth=prefetch)
    if tracker is not None:
        tracker.on_run_start()
    try:
        for (start, end), (part, cohort, uniq, staged) in zip(blocks,
                                                              staged_iter):
            sched_d, batch_d = staged
            if host_resident:
                ls, st, ck = stage_window(uniq)
                pool_state = PoolState(
                    ls, st, ck, pool_state.buf_updates,
                    pool_state.buf_round, pool_state.buf_count,
                    pool_state.flushes)
            if pooled:
                phi, pool_state, round_losses = run_block(
                    phi, pool_state, sched_d, batch_d)
            else:
                phi, round_losses = run_block(phi, sched_d, batch_d)
            if host_resident and uniq.size:
                got = fetch_tree(
                    tuple(getattr(pool_state, f)
                          for f in ClientPool.SLAB_FIELDS))
                pool.scatter_rows(
                    uniq, {f: np.asarray(g)[:uniq.size] for f, g in
                           zip(ClientPool.SLAB_FIELDS, got)})
            blk = end - start
            if tracker is not None:
                # the loss fetch syncs on the block — done ONLY when a
                # tracker asks for it, so tracker=None stays fetch-free
                tracker.on_block(start, end,
                                 np.asarray(round_losses)[:blk])
            if strategy.meters_comm:
                # bill downlink + uplink per participating client, at the
                # round's exact (possibly rotating) payload
                payloads = payload_by_phase[
                    np.arange(start, end) % len(payload_by_phase)]
                if pooled:
                    # bill the POOL CLIENT seated in each participating
                    # slot (np.add.at accumulates repeat check-ins)
                    bills = 2 * payloads[:, None] * part
                    np.add.at(per_client_bytes, cohort[part], bills[part])
                else:
                    per_client_bytes += (2 * payloads[:, None] * part).sum(0)
                block_bytes = int((2 * payloads * part.sum(axis=1)).sum())
                comm_bytes += block_bytes
                if tracker is not None:
                    tracker.on_transport(end, block_bytes, comm_bytes)
            if eval_every and end % eval_every == 0:
                # cross-host: run the eval protocol on a LOCAL numpy
                # copy of the replicated phi, so it stays a per-process
                # computation (identical on every process) instead of a
                # collective
                eval_phi = (jax.tree.map(np.asarray, phi) if multiproc
                            else phi)
                ev = evaluate_init(strategy.loss_fn, eval_phi, task_dist,
                                   np.random.default_rng(10_000 + end - 1),
                                   **(eval_kwargs or {}))
                ev["round"] = end
                if strategy.meters_comm:
                    ev["comm_bytes"] = comm_bytes
                if strategy.tracks_inner_loss:
                    ev["inner_loss"] = float(round_losses[blk - 1])
                history.append(ev)
                if tracker is not None:
                    tracker.on_eval(ev)
            if ckpt_at(end):
                # block-boundary COPIES: the live carry is donated to
                # the next block, so the snapshot dispatches a device
                # copy (async, off the host critical path) and hands
                # THAT to the writer thread for the D2H transfer
                # cross-host snapshots materialize to host numpy HERE
                # (the replicating fetch is a collective every process
                # must join); single-process runs keep the async device
                # copy. Only process 0 touches the filesystem.
                snap_copy = fetch_tree if multiproc else _snapshot_copy
                if host_resident:
                    # checkpoints always carry the FULL (N,) layout —
                    # identity straight from the host slabs (post
                    # write-back), buffer leaves device-copied — so
                    # snapshots restore into either residency
                    pool_snap = PoolState(
                        *(np.array(slabs[f])
                          for f in ClientPool.SLAB_FIELDS),
                        *(snap_copy((
                            pool_state.buf_updates, pool_state.buf_round,
                            pool_state.buf_count, pool_state.flushes))))
                elif pooled:
                    pool_snap = snap_copy(pool_state)
                else:
                    pool_snap = None
                state = RoundState(
                    round=end,
                    phi=(jax.tree.map(np.asarray, phi) if multiproc
                         else _snapshot_copy(phi)),
                    pool_state=pool_snap,
                    per_client_bytes=per_client_bytes.copy(),
                    comm_bytes=comm_bytes, history=list(history),
                    host=host_snaps.pop(end), fingerprint=fingerprint)
                if multiproc and jax.process_index() != 0:
                    pass                 # peers only joined the fetch
                elif writer is not None:
                    writer.submit_state(state)
                else:
                    save_round_state(ckpt_dir, state, keep=ckpt_keep)
        if writer is not None:
            writer.close()      # drain pending snapshots; surface errors
    finally:
        staged_iter.close()
        if writer is not None:
            writer.close(raise_errors=False)
        if tracker is not None:
            tracker.stop_profile()   # idempotent; covers error exits

    out = {"params": phi, "history": history}
    if strategy.meters_comm:
        out["comm_bytes"] = comm_bytes
        # C-level tolist(), not a per-element int() loop: the bill has
        # pool.size entries, and a million-client fleet pays ~100ms for
        # the boxing loop vs ~10ms here
        out["per_client_bytes"] = per_client_bytes.tolist()
    if pooled:
        ps = fetch_tree(pool_state)
        # [:pool.size] drops the mesh shard-padding rows (a no-op slice
        # on unsharded runs); host-resident identity reads from the
        # slabs (the device window only holds the last block's rows)
        ident = (slabs if host_resident else
                 {f: getattr(ps, f) for f in ClientPool.SLAB_FIELDS})
        out["pool_state"] = {
            f: np.array(ident[f][:pool.size])
            for f in ClientPool.SLAB_FIELDS}
        if buffered is not None:
            out["pool_state"]["flushes"] = int(ps.flushes)
            # scalar off-mesh; per-shard fill levels (shards,) on mesh
            out["pool_state"]["buffered_pending"] = int(
                np.asarray(ps.buf_count).sum())
    if tracker is not None:
        tracker.on_run_end(
            runner_cache_stats(),
            staleness=(out["pool_state"]["staleness"] if pooled else None))
    return out
