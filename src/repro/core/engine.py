"""The federated round engine: one loop for every core/ algorithm.

Historically each algorithm file (tinyreptile, reptile, fedavg, fedsgd,
transfer) hand-rolled the same Python-side server loop — client sampling,
comm-byte metering, annealing, eval cadence — and paid one host->device
dispatch per client per round. This module owns all of that once:

  run_federated(init_params, task_dist, strategy, ...)

* A ``FedStrategy`` (see repro.core.strategies) supplies the two
  algorithm-specific hooks: ``client_update`` (what one device does with
  the broadcast parameters and its local data) and ``server_aggregate``
  (how the server folds the client results back into phi).
* The engine samples clients on the host (NumPy RNG, in the exact order
  the legacy loops used, so seeded runs are reproducible), then executes
  whole blocks of rounds on-device: ``jax.vmap`` across the
  clients_per_round axis and ``jax.lax.scan`` across the rounds between
  evals, with the parameter buffers donated between blocks. A round is
  one scan step, not a Python iteration per client.
* A pluggable ``CommChannel`` does the paper's Table-II byte accounting
  for fp32/fp16/int8 payloads and can optionally *simulate* the quantized
  transport (int8 motivated by TIFeD's integer-based FL), so
  communication-efficiency variants are a channel object, not a new loop.
* The server update routes through the fused Pallas kernel
  (``repro.kernels.ops.meta_update``) by default on TPU backends;
  elsewhere the same fp32 math runs as plain XLA (the kernel would only
  interpret there).

``meta_interpolate`` and ``streaming_sgd`` are the engine's round
building blocks, shared with the mesh-scale cohort step in
``repro.runtime.steps``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.meta import evaluate_init
from repro.data.tasks import TaskDistribution

#: bytes per parameter for each transport payload dtype (paper Table II
#: generalized: the paper ships fp32; fp16/int8 model compressed uplinks).
PAYLOAD_ITEMSIZE = {"float32": 4, "float16": 2, "int8": 1}


def default_use_pallas() -> bool:
    """Pallas server update only where it compiles natively."""
    return jax.default_backend() == "tpu"


def meta_interpolate(phi, phi_hat, alpha, *, use_pallas: Optional[bool] = None):
    """Reptile server update phi <- phi + alpha (phi_hat - phi), fp32 math,
    cast back to each leaf's storage dtype. Routed through the fused Pallas
    kernel when `use_pallas` (default: on TPU)."""
    if use_pallas is None:
        use_pallas = default_use_pallas()
    if use_pallas:
        from repro.kernels import ops as kops
        return jax.tree.map(
            lambda p, q: kops.meta_update(p, q, alpha), phi, phi_hat)
    return jax.tree.map(
        lambda p, q: (p.astype(jnp.float32)
                      + alpha * (q.astype(jnp.float32)
                                 - p.astype(jnp.float32))).astype(p.dtype),
        phi, phi_hat)


def streaming_sgd(loss_fn, phi, batch, beta):
    """The inner loop: one SGD step per arriving microbatch (the paper's
    online learning), scanned on-device; fp32 update math, params cast
    back to their storage dtype. In probe mode the scan unrolls so XLA
    cost analysis counts every step (see repro.runtime.flags)."""
    def inner(phi_hat, micro):
        loss, g = jax.value_and_grad(loss_fn)(phi_hat, micro)
        phi_hat = jax.tree.map(
            lambda p, gg: (p.astype(jnp.float32)
                           - beta * gg.astype(jnp.float32)).astype(p.dtype),
            phi_hat, g)
        return phi_hat, loss

    from repro.runtime.flags import probe_mode
    if probe_mode():
        k = jax.tree.leaves(batch)[0].shape[0]
        phi_hat, losses = phi, []
        for i in range(k):
            micro = jax.tree.map(lambda a: a[i], batch)
            phi_hat, l = inner(phi_hat, micro)
            losses.append(l)
        return phi_hat, jnp.stack(losses)
    return jax.lax.scan(inner, phi, batch)


@dataclasses.dataclass(frozen=True)
class CommChannel:
    """Server<->client transport: byte accounting + optional quantization.

    dtype: payload dtype on the wire ("float32" | "float16" | "int8").
      Accounting scales `tree_bytes` by the itemsize ratio — the paper's
      Table II generalized beyond fp32.
    quantize: simulate the lossy payload in-round (cast round-trip for
      fp16, per-leaf symmetric affine quantization for int8). Default:
      quantize iff dtype != float32. Accounting-only studies can set
      quantize=False to meter a compressed link while training in fp32.
    """
    dtype: str = "float32"
    quantize: Optional[bool] = None

    def __post_init__(self):
        if self.dtype not in PAYLOAD_ITEMSIZE:
            raise ValueError(f"unknown payload dtype {self.dtype!r}; "
                             f"expected one of {sorted(PAYLOAD_ITEMSIZE)}")

    @property
    def simulates_quantization(self) -> bool:
        if self.quantize is None:
            return self.dtype != "float32"
        return self.quantize

    def payload_bytes(self, tree) -> int:
        """One direction, one client: every leaf at the wire itemsize."""
        itemsize = PAYLOAD_ITEMSIZE[self.dtype]
        return sum(x.size * itemsize for x in jax.tree.leaves(tree))

    def round_bytes(self, tree, clients: int) -> int:
        """Downlink (phi out) + uplink (result back) for every client."""
        return 2 * clients * self.payload_bytes(tree)

    def transmit(self, tree):
        """Simulated wire round-trip (encode + decode), jax-traceable."""
        if not self.simulates_quantization:
            return tree
        if self.dtype == "float16":
            return jax.tree.map(
                lambda x: x.astype(jnp.float16).astype(x.dtype), tree)

        def q_int8(x):
            scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / 127.0
            q = jnp.round(x / scale).astype(jnp.int8)
            return (q.astype(x.dtype) * scale).astype(x.dtype)
        return jax.tree.map(q_int8, tree)


def _sample_round_block(task_dist: TaskDistribution, rng, rounds: int,
                        clients: int, support: int, data_mode: str) -> Dict:
    """Host-side client sampling for `rounds` x `clients`, consuming the
    NumPy RNG in exactly the order the per-round loops did: for each
    round, for each client, sample the task then draw its support data."""
    xs: List[np.ndarray] = []
    ys: List[np.ndarray] = []
    for _ in range(rounds * clients):
        task = task_dist.sample_task(rng)
        if data_mode == "stream":
            sx, sy = zip(*task.support_stream(rng, support))
            x, y = np.stack(sx), np.stack(sy)
        else:
            b = task.support_batch(rng, support)
            x, y = np.asarray(b["x"]), np.asarray(b["y"])
        xs.append(x)
        ys.append(y)
    x = np.stack(xs).reshape(rounds, clients, *xs[0].shape)
    y = np.stack(ys).reshape(rounds, clients, *ys[0].shape)
    return {"x": jnp.asarray(x), "y": jnp.asarray(y)}


@functools.lru_cache(maxsize=64)
def _cached_block_runner(strategy, beta, channel):
    return _build_block_runner(strategy, beta, channel)


def _block_runner(strategy, beta, channel: CommChannel):
    """Strategies are frozen dataclasses, so identically-configured runs
    (every test/bench re-entry) reuse one jitted runner instead of
    recompiling per call. Unhashable custom strategies still work — they
    just pay a fresh trace."""
    try:
        return _cached_block_runner(strategy, float(beta), channel)
    except TypeError:
        return _build_block_runner(strategy, beta, channel)


def _build_block_runner(strategy, beta, channel: CommChannel):
    """jit'd (phi, alphas, batch) -> (phi, per-round inner loss): a
    lax.scan over rounds whose body vmaps client_update across clients.
    phi is donated — successive blocks update in place."""
    beta_f = jnp.float32(beta)
    simulate = channel.simulates_quantization

    def round_fn(phi, xs):
        alpha_t, batch = xs                       # batch leaves: (C, S, ...)
        phi_down = channel.transmit(phi) if simulate else phi
        results, losses = jax.vmap(
            lambda b: strategy.client_update(phi_down, b, beta_f))(batch)
        if simulate:
            results = channel.transmit(results)
        phi = strategy.server_aggregate(phi, results, alpha_t, beta_f)
        return phi, jnp.mean(losses)

    def run_block(phi, alphas, batch):
        return jax.lax.scan(round_fn, phi, (alphas, batch))

    return jax.jit(run_block, donate_argnums=(0,))


def run_federated(init_params, task_dist: TaskDistribution, strategy, *,
                  rounds: int, clients_per_round: int = 1,
                  alpha: float = 1.0, beta: float = 0.01, support: int = 32,
                  anneal: bool = True, seed: int = 0, eval_every: int = 0,
                  eval_kwargs: Optional[dict] = None,
                  channel: Optional[CommChannel] = None,
                  max_block: int = 512) -> Dict:
    """Run `rounds` federated rounds of `strategy`.

    Returns {"params", "history"} (+ "comm_bytes" for strategies that
    meter communication). History rows are per-eval dicts in the legacy
    loops' format: evaluate_init fields + round [+ comm_bytes,
    inner_loss].

    Rounds between evals execute as one on-device scan (split into
    `max_block`-round jit blocks to bound host buffering); the host only
    samples client data and runs the eval protocol.
    """
    if channel is None:
        channel = CommChannel()
    rng = np.random.default_rng(seed)
    # private copy: the block runner donates its phi argument, and the
    # caller's init_params must stay usable (they are reused across runs)
    phi = jax.tree.map(jnp.array, init_params)
    history: List[Dict] = []
    comm_bytes = 0
    per_round_bytes = (channel.round_bytes(init_params, clients_per_round)
                       if strategy.meters_comm else 0)
    run_block = _block_runner(strategy, beta, channel)

    stride = eval_every if eval_every else rounds
    rnd = 0
    while rnd < rounds:
        eval_boundary = min(rounds, (rnd // stride + 1) * stride)
        end = min(eval_boundary, rnd + max_block)
        block = end - rnd
        alphas = jnp.asarray(
            [alpha * (1 - r / rounds) if anneal else alpha
             for r in range(rnd, end)], jnp.float32)
        batch = _sample_round_block(task_dist, rng, block, clients_per_round,
                                    support, strategy.data_mode)
        phi, round_losses = run_block(phi, alphas, batch)
        comm_bytes += block * per_round_bytes
        rnd = end
        if eval_every and rnd % eval_every == 0:
            ev = evaluate_init(strategy.loss_fn, phi, task_dist,
                               np.random.default_rng(10_000 + rnd - 1),
                               **(eval_kwargs or {}))
            ev["round"] = rnd
            if strategy.meters_comm:
                ev["comm_bytes"] = comm_bytes
            if strategy.tracks_inner_loss:
                ev["inner_loss"] = float(round_losses[-1])
            history.append(ev)

    out = {"params": phi, "history": history}
    if strategy.meters_comm:
        out["comm_bytes"] = comm_bytes
    return out
