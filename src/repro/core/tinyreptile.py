"""TinyReptile (the paper's Algorithm 1), faithful implementation.

Per round:
  1. sample ONE client t from the training population (serial schema);
  2. send phi to the client                      (bytes accounted);
  3. the client runs one SGD step per STREAMING support sample
     (online learning: the sample is discarded after its update —
     at any time only one sample lives in memory);
  4. the client returns phi_hat                  (bytes accounted);
  5. server: phi <- phi + alpha (phi_hat - phi).

Optionally the server learning rate anneals linearly (Appendix A notes
annealing helps; the Reptile paper uses it too).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.meta import (evaluate_init, finetune_online, tree_bytes,
                             tree_lerp)
from repro.data.tasks import TaskDistribution


def tinyreptile_train(loss_fn: Callable, init_params,
                      task_dist: TaskDistribution, *,
                      rounds: int = 1000, alpha: float = 1.0,
                      beta: float = 0.01, support: int = 32,
                      anneal: bool = True, seed: int = 0,
                      eval_every: int = 0, eval_kwargs: Optional[dict] = None,
                      use_pallas: bool = False) -> Dict:
    """Returns {"params", "history"}; history rows are per-eval dicts."""
    rng = np.random.default_rng(seed)
    phi = init_params
    history: List[Dict] = []
    pbytes = tree_bytes(phi)
    comm_bytes = 0

    for rnd in range(rounds):
        alpha_t = alpha * (1 - rnd / rounds) if anneal else alpha
        task = task_dist.sample_task(rng)                       # step 6
        comm_bytes += pbytes                                    # send phi
        # the client consumes its stream sample-by-sample (step 8-10);
        # we buffer to arrays only to drive lax.scan — semantics identical
        xs, ys = zip(*task.support_stream(rng, support))
        phi_hat, inner_losses = finetune_online(
            loss_fn, phi, jnp.stack(xs), jnp.stack(ys), jnp.float32(beta))
        comm_bytes += pbytes                                    # return phi_hat
        if use_pallas:
            from repro.kernels import ops as kops
            import jax
            phi = jax.tree.map(
                lambda p, q: kops.meta_update(p, q, alpha_t), phi, phi_hat)
        else:
            phi = tree_lerp(phi, phi_hat, alpha_t)              # step 12
        if eval_every and (rnd + 1) % eval_every == 0:
            ev = evaluate_init(loss_fn, phi, task_dist,
                               np.random.default_rng(10_000 + rnd),
                               **(eval_kwargs or {}))
            ev.update(round=rnd + 1, comm_bytes=comm_bytes,
                      inner_loss=float(inner_losses.mean()))
            history.append(ev)
    return {"params": phi, "history": history, "comm_bytes": comm_bytes}
