"""TinyReptile (the paper's Algorithm 1), faithful implementation.

Per round:
  1. sample ONE client t from the training population (serial schema);
  2. send phi to the client                      (bytes accounted);
  3. the client runs one SGD step per STREAMING support sample
     (online learning: the sample is discarded after its update —
     at any time only one sample lives in memory);
  4. the client returns phi_hat                  (bytes accounted);
  5. server: phi <- phi + alpha (phi_hat - phi).

Optionally the server learning rate anneals linearly (Appendix A notes
annealing helps; the Reptile paper uses it too).

The loop itself lives in the shared round engine (repro.core.engine);
this module only binds the TinyReptile strategy. `channel` selects the
transport (fp32/fp16/int8 byte accounting + optional quantization).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

from repro.core.engine import CommChannel, run_federated
from repro.core.pipeline import SamplingPolicy
from repro.core.pool import BufferedAggregation, ClientPool
from repro.core.strategies import TinyReptileStrategy
from repro.data.tasks import TaskDistribution


def tinyreptile_train(loss_fn: Callable, init_params,
                      task_dist: TaskDistribution, *,
                      rounds: int = 1000, alpha: float = 1.0,
                      beta: float = 0.01, support: int = 32,
                      anneal: bool = True, seed: int = 0,
                      eval_every: int = 0, eval_kwargs: Optional[dict] = None,
                      use_pallas: Optional[bool] = None,
                      channel: Optional[CommChannel] = None,
                      prefetch: int = 2, sampler: str = "reference",
                      max_block: int = 512,
                      clients_per_round: int = 1,
                      sampling: Optional[SamplingPolicy] = None,
                      pool: Optional[ClientPool] = None,
                      buffered: Optional[BufferedAggregation] = None,
                      mesh=None) -> Dict:
    """Returns {"params", "history", "comm_bytes", "per_client_bytes"};
    history rows are per-eval dicts. `prefetch`/`sampler`/`max_block`
    tune the engine's host/device pipeline; `sampling` plugs in a
    heterogeneity schedule (partial participation / stragglers) and
    `clients_per_round` > 1 grows the paper's serial schema into a
    cohort for such policies (see repro.core.engine.run_federated)."""
    return run_federated(
        init_params, task_dist,
        TinyReptileStrategy(loss_fn, use_pallas=use_pallas),
        rounds=rounds, clients_per_round=clients_per_round, alpha=alpha,
        beta=beta, support=support, anneal=anneal, seed=seed,
        eval_every=eval_every, eval_kwargs=eval_kwargs, channel=channel,
        prefetch=prefetch, sampler=sampler, max_block=max_block,
        sampling=sampling, pool=pool, buffered=buffered, mesh=mesh)
