"""Transfer-learning / joint-training baseline (paper Fig. 1): train one
model on pooled data from all clients — Eq. (2). In the sine example this
converges to E_t[f_t(x)] ~ 0, demonstrating why meta-learning is needed.

Expressed on the shared round engine as the degenerate strategy whose
clients forward raw batches and whose server takes one SGD step on the
pool (no federation -> no comm accounting)."""
from __future__ import annotations

from typing import Callable, Dict, Optional

from repro.core.engine import run_federated
from repro.core.pipeline import SamplingPolicy
from repro.core.pool import ClientPool
from repro.core.strategies import TransferStrategy
from repro.data.tasks import TaskDistribution


def transfer_train(loss_fn: Callable, init_params,
                   task_dist: TaskDistribution, *,
                   rounds: int = 1000, beta: float = 0.01,
                   batch_per_round: int = 32, tasks_per_round: int = 8,
                   seed: int = 0, eval_every: int = 0,
                   eval_kwargs: Optional[dict] = None,
                   prefetch: int = 2, sampler: str = "reference",
                   max_block: int = 512,
                   sampling: Optional[SamplingPolicy] = None,
                   pool: Optional[ClientPool] = None,
                   mesh=None) -> Dict:
    per_task = max(batch_per_round // tasks_per_round, 1)
    return run_federated(
        init_params, task_dist, TransferStrategy(loss_fn),
        rounds=rounds, clients_per_round=tasks_per_round, alpha=0.0,
        beta=beta, support=per_task, anneal=False, seed=seed,
        eval_every=eval_every, eval_kwargs=eval_kwargs, prefetch=prefetch,
        sampler=sampler, max_block=max_block, sampling=sampling, pool=pool,
        mesh=mesh)
