"""Transfer-learning / joint-training baseline (paper Fig. 1): train one
model on pooled data from all clients — Eq. (2). In the sine example this
converges to E_t[f_t(x)] ~ 0, demonstrating why meta-learning is needed."""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.meta import evaluate_init
from repro.data.tasks import TaskDistribution


def transfer_train(loss_fn: Callable, init_params,
                   task_dist: TaskDistribution, *,
                   rounds: int = 1000, beta: float = 0.01,
                   batch_per_round: int = 32, tasks_per_round: int = 8,
                   seed: int = 0, eval_every: int = 0,
                   eval_kwargs: Optional[dict] = None) -> Dict:
    rng = np.random.default_rng(seed)
    phi = init_params
    history: List[Dict] = []
    step = jax.jit(lambda p, b, lr: jax.tree.map(
        lambda w, g: w - lr * g, p, jax.grad(loss_fn)(p, b)))
    per_task = max(batch_per_round // tasks_per_round, 1)
    for rnd in range(rounds):
        xs, ys = [], []
        for _ in range(tasks_per_round):
            task = task_dist.sample_task(rng)
            b = task.support_batch(rng, per_task)
            xs.append(b["x"])
            ys.append(b["y"])
        batch = {"x": np.concatenate(xs), "y": np.concatenate(ys)}
        phi = step(phi, batch, jnp.float32(beta))
        if eval_every and (rnd + 1) % eval_every == 0:
            ev = evaluate_init(loss_fn, phi, task_dist,
                               np.random.default_rng(10_000 + rnd),
                               **(eval_kwargs or {}))
            ev.update(round=rnd + 1)
            history.append(ev)
    return {"params": phi, "history": history}
