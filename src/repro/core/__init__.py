"""The paper's contribution: TinyReptile + every baseline it compares to."""
from repro.core.fedavg import fedavg_train, fedsgd_train  # noqa: F401
from repro.core.meta import evaluate_init, finetune_batch, finetune_online  # noqa: F401
from repro.core.reptile import reptile_train  # noqa: F401
from repro.core.tinyreptile import tinyreptile_train  # noqa: F401
from repro.core.transfer import transfer_train  # noqa: F401
