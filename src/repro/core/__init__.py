"""The paper's contribution: TinyReptile + every baseline it compares to,
all running on ONE strategy-based federated round engine.

Architecture (post engine refactor):

  engine.py      — ``run_federated``: the single server loop. Owns client
                   sampling, CommChannel byte accounting (fp32/fp16/int8,
                   plus TinyMetaFed-style partial fractions), linear
                   annealing, eval cadence, and history. Executes rounds
                   on-device as fixed-shape masked blocks: vmap across
                   clients_per_round, lax.scan across the rounds between
                   evals, donated parameter buffers, one jit trace per
                   config, Pallas server update on TPU.
  pipeline.py    — the host side: block planning (retrace-free padded
                   shapes), background prefetch (stage block N+1 while
                   the device runs block N), and the ``ClientSchedule``
                   heterogeneity layer: pluggable ``SamplingPolicy``
                   schedule producers (uniform, partial participation,
                   stragglers).
  pool.py        — persistent client identities: ``ClientPool`` (stable
                   per-device tasks + a cross-round on-device state
                   pytree), FedBuff-style ``BufferedAggregation``, and
                   diurnal / Markov ``AvailabilityProcess`` check-in
                   schedules.
  strategies.py  — ``FedStrategy`` objects: each algorithm reduced to
                   ``client_update`` + ``server_aggregate`` hooks (plus
                   schedule-aware weighted/step-masked variants).
  tinyreptile.py, reptile.py, fedavg.py, transfer.py
                 — thin, signature-stable entry points binding a strategy
                   to the engine (the public ``*_train`` API).
  meta.py        — shared substrate: inner loops (finetune_online /
                   finetune_batch) and the paper's evaluation protocol.
  federated.py   — mesh-scale pod-client mode (pods as federated
                   clients), a thin configuration of the engine's
                   building blocks under shard_map.

``run_federated(mesh=...)`` (or an explicit ``client_mesh()``) shards
the per-round client axis across a device mesh: per-device vmap over
the local cohort shard, collective (psum) server aggregation, sharded
schedule/pool state — the fleet-scale path.

A new algorithm or transport policy is one strategy / CommChannel
object, not a new file-long loop.
"""
from repro.core.engine import (CommChannel, PartialCommChannel,  # noqa: F401
                               clear_runner_cache, client_mesh,
                               run_federated, runner_cache_stats)
from repro.core.fedavg import fedavg_train, fedsgd_train  # noqa: F401
from repro.core.pipeline import (BlockPrefetcher, ClientSchedule,  # noqa: F401
                                 PartialParticipation, SamplingPolicy,
                                 StragglerSampling, UniformSampling,
                                 plan_blocks)
from repro.core.pool import (AvailabilityProcess, BufferedAggregation,  # noqa: F401
                             ClientPool, DiurnalAvailability,
                             MarkovAvailability, PoolState)
from repro.core.meta import evaluate_init, finetune_batch, finetune_online  # noqa: F401
from repro.core.reptile import reptile_train  # noqa: F401
from repro.core.strategies import (FedAvgStrategy, FedSGDStrategy,  # noqa: F401
                                   FedStrategy, ReptileStrategy,
                                   TifedStrategy, TinyReptileStrategy,
                                   TransferStrategy)
from repro.core.tifed import tifed_train  # noqa: F401
from repro.core.tinyreptile import tinyreptile_train  # noqa: F401
from repro.core.transfer import transfer_train  # noqa: F401
