"""Meta-learning substrate shared by all core/ algorithms.

Implements the paper's evaluation protocol (§III-A): to score an
initialization phi, fine-tune it for K steps on each testing client's
support set S, then measure loss/accuracy on the query set Q, averaged
over clients — Eq. (1): L(phi) = sum_n l_n(phi_n^k).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tasks import TaskDistribution


def tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y, a, b)


def tree_add_scaled(a, b, scale):
    return jax.tree.map(lambda x, y: x + scale * y, a, b)


def tree_lerp(phi, phi_hat, alpha):
    """Reptile interpolation: phi + alpha (phi_hat - phi)."""
    return jax.tree.map(lambda p, q: p + alpha * (q - p), phi, phi_hat)


def tree_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))


@functools.partial(jax.jit, static_argnums=(0, 3))
def finetune_batch(loss_fn, params, batch, steps: int, lr):
    """K steps of full-batch gradient descent on one support set
    (Reptile's inner loop / the evaluation fine-tune)."""
    def body(p, _):
        loss, g = jax.value_and_grad(loss_fn)(p, batch)
        return jax.tree.map(lambda w, gg: w - lr * gg, p, g), loss
    params, losses = jax.lax.scan(body, params, None, length=steps)
    return params, losses


@functools.partial(jax.jit, static_argnums=(0,))
def finetune_online(loss_fn, params, xs, ys, lr):
    """One SGD step per sample, in arrival order (TinyReptile inner loop).
    xs: (S, ...), ys: (S, ...) — scanned one at a time; a real device
    would never materialize the stream, here it's scanned for jit."""
    def body(p, xy):
        x, y = xy
        batch = {"x": x[None], "y": y[None]}
        loss, g = jax.value_and_grad(loss_fn)(p, batch)
        return jax.tree.map(lambda w, gg: w - lr * gg, p, g), loss
    params, losses = jax.lax.scan(body, params, (xs, ys))
    return params, losses


def finetune_online_masked(loss_fn, params, xs, ys, lr, k):
    """``finetune_online`` with a TRACED per-client step budget ``k``:
    only the first k of the S streamed samples update the params; later
    steps are ``lax.cond`` no-ops (0 loss, params pass through), so the
    shape stays fixed and straggler clients vmap/scan with the rest of
    the cohort without retracing. ``k == S`` reproduces
    ``finetune_online``'s math op-for-op. Engine-internal: traced inside
    the block runner, hence no jit wrapper of its own."""
    def body(p, xyi):
        x, y, i = xyi

        def live(p):
            batch = {"x": x[None], "y": y[None]}
            loss, g = jax.value_and_grad(loss_fn)(p, batch)
            return jax.tree.map(lambda w, gg: w - lr * gg, p, g), loss

        def dead(p):
            return p, jnp.float32(0.0)

        return jax.lax.cond(i < k, live, dead, p)
    steps = jnp.arange(xs.shape[0])
    return jax.lax.scan(body, params, (xs, ys, steps))


def finetune_batch_masked(loss_fn, params, batch, steps: int, lr, k):
    """``finetune_batch`` with a static upper bound ``steps`` and a
    TRACED live-step count ``k``: epochs >= k are ``lax.cond`` no-ops
    (0 loss). ``k == steps`` reproduces ``finetune_batch`` op-for-op.
    Engine-internal (see ``finetune_online_masked``)."""
    def body(p, i):
        def live(p):
            loss, g = jax.value_and_grad(loss_fn)(p, batch)
            return jax.tree.map(lambda w, gg: w - lr * gg, p, g), loss

        def dead(p):
            return p, jnp.float32(0.0)

        return jax.lax.cond(i < k, live, dead, p)
    return jax.lax.scan(body, params, jnp.arange(steps))


def evaluate_init(loss_fn: Callable, params, task_dist: TaskDistribution,
                  rng: np.random.Generator, *, num_tasks: int = 10,
                  support: int = 8, query: int = 64, k_steps: int = 8,
                  lr: float = 0.01,
                  metric_fn: Optional[Callable] = None) -> Dict[str, float]:
    """Paper protocol: per testing client, fine-tune K steps on S then
    score on Q; average over clients."""
    losses, metrics = [], []
    for _ in range(num_tasks):
        task = task_dist.sample_task(rng)
        qry = task.query_batch(rng, query)
        if support > 0:
            sup = task.support_batch(rng, support)
            tuned, _ = finetune_batch(loss_fn, params, sup, k_steps,
                                      jnp.float32(lr))
        else:
            tuned = params  # S_test = 0: no adaptation (paper Fig. 6)
        losses.append(float(loss_fn(tuned, qry)))
        if metric_fn is not None:
            metrics.append(float(metric_fn(tuned, qry)))
    out = {"query_loss": float(np.mean(losses))}
    if metrics:
        out["query_metric"] = float(np.mean(metrics))
    return out
