"""FedAVG and FedSGD [McMahan et al. 2016] — the traditional-FL baselines
the paper shows FAIL in the meta-learning (heterogeneous-client) regime
(Fig. 2): their objective is Eq. (2) (one model good for all clients NOW),
not Eq. (1) (a model that adapts)."""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.meta import evaluate_init, finetune_batch, tree_bytes
from repro.data.tasks import TaskDistribution


def fedavg_train(loss_fn: Callable, init_params,
                 task_dist: TaskDistribution, *,
                 rounds: int = 1000, beta: float = 0.01, support: int = 32,
                 epochs: int = 8, clients_per_round: int = 8, seed: int = 0,
                 eval_every: int = 0,
                 eval_kwargs: Optional[dict] = None) -> Dict:
    """FedAVG: clients run E local epochs; server averages the MODELS."""
    rng = np.random.default_rng(seed)
    phi = init_params
    history: List[Dict] = []
    pbytes = tree_bytes(phi)
    comm_bytes = 0
    for rnd in range(rounds):
        acc = None
        for _ in range(clients_per_round):
            task = task_dist.sample_task(rng)
            comm_bytes += 2 * pbytes
            sup = task.support_batch(rng, support)
            phi_c, _ = finetune_batch(loss_fn, phi, sup, epochs,
                                      jnp.float32(beta))
            acc = phi_c if acc is None else jax.tree.map(
                lambda a, b: a + b, acc, phi_c)
        phi = jax.tree.map(lambda a: a / clients_per_round, acc)
        if eval_every and (rnd + 1) % eval_every == 0:
            ev = evaluate_init(loss_fn, phi, task_dist,
                               np.random.default_rng(10_000 + rnd),
                               **(eval_kwargs or {}))
            ev.update(round=rnd + 1, comm_bytes=comm_bytes)
            history.append(ev)
    return {"params": phi, "history": history, "comm_bytes": comm_bytes}


def fedsgd_train(loss_fn: Callable, init_params,
                 task_dist: TaskDistribution, *,
                 rounds: int = 1000, beta: float = 0.01, support: int = 32,
                 clients_per_round: int = 8, seed: int = 0,
                 eval_every: int = 0,
                 eval_kwargs: Optional[dict] = None) -> Dict:
    """FedSGD: each client sends ONE gradient; server applies the mean."""
    rng = np.random.default_rng(seed)
    phi = init_params
    history: List[Dict] = []
    pbytes = tree_bytes(phi)
    comm_bytes = 0
    grad_fn = jax.jit(jax.grad(loss_fn))
    for rnd in range(rounds):
        gacc = None
        for _ in range(clients_per_round):
            task = task_dist.sample_task(rng)
            comm_bytes += 2 * pbytes
            sup = task.support_batch(rng, support)
            g = grad_fn(phi, sup)
            gacc = g if gacc is None else jax.tree.map(
                lambda a, b: a + b, gacc, g)
        phi = jax.tree.map(lambda p, g: p - beta * g / clients_per_round,
                           phi, gacc)
        if eval_every and (rnd + 1) % eval_every == 0:
            ev = evaluate_init(loss_fn, phi, task_dist,
                               np.random.default_rng(10_000 + rnd),
                               **(eval_kwargs or {}))
            ev.update(round=rnd + 1, comm_bytes=comm_bytes)
            history.append(ev)
    return {"params": phi, "history": history, "comm_bytes": comm_bytes}
