"""FedAVG and FedSGD [McMahan et al. 2016] — the traditional-FL baselines
the paper shows FAIL in the meta-learning (heterogeneous-client) regime
(Fig. 2): their objective is Eq. (2) (one model good for all clients NOW),
not Eq. (1) (a model that adapts).

Both are thin bindings of the shared round engine (repro.core.engine):
the per-client work runs vmapped across the sampled cohort, the rounds
between evals run as one on-device scan."""
from __future__ import annotations

from typing import Callable, Dict, Optional

from repro.core.engine import CommChannel, run_federated
from repro.core.pipeline import SamplingPolicy
from repro.core.pool import BufferedAggregation, ClientPool
from repro.core.strategies import FedAvgStrategy, FedSGDStrategy
from repro.data.tasks import TaskDistribution


def fedavg_train(loss_fn: Callable, init_params,
                 task_dist: TaskDistribution, *,
                 rounds: int = 1000, beta: float = 0.01, support: int = 32,
                 epochs: int = 8, clients_per_round: int = 8, seed: int = 0,
                 eval_every: int = 0,
                 eval_kwargs: Optional[dict] = None,
                 channel: Optional[CommChannel] = None,
                 prefetch: int = 2, sampler: str = "reference",
                 max_block: int = 512,
                 sampling: Optional[SamplingPolicy] = None,
                 pool: Optional[ClientPool] = None,
                 buffered: Optional[BufferedAggregation] = None,
                 mesh=None) -> Dict:
    """FedAVG: clients run E local epochs; server averages the MODELS
    (participation-weighted under a heterogeneity `sampling` policy)."""
    return run_federated(
        init_params, task_dist, FedAvgStrategy(loss_fn, epochs=epochs),
        rounds=rounds, clients_per_round=clients_per_round, alpha=1.0,
        beta=beta, support=support, anneal=False, seed=seed,
        eval_every=eval_every, eval_kwargs=eval_kwargs, channel=channel,
        prefetch=prefetch, sampler=sampler, max_block=max_block,
        sampling=sampling, pool=pool, buffered=buffered, mesh=mesh)


def fedsgd_train(loss_fn: Callable, init_params,
                 task_dist: TaskDistribution, *,
                 rounds: int = 1000, beta: float = 0.01, support: int = 32,
                 clients_per_round: int = 8, seed: int = 0,
                 eval_every: int = 0,
                 eval_kwargs: Optional[dict] = None,
                 channel: Optional[CommChannel] = None,
                 prefetch: int = 2, sampler: str = "reference",
                 max_block: int = 512,
                 sampling: Optional[SamplingPolicy] = None,
                 pool: Optional[ClientPool] = None,
                 buffered: Optional[BufferedAggregation] = None,
                 mesh=None) -> Dict:
    """FedSGD: each client sends ONE gradient; server applies the mean
    (participation-weighted under a heterogeneity `sampling` policy)."""
    return run_federated(
        init_params, task_dist, FedSGDStrategy(loss_fn),
        rounds=rounds, clients_per_round=clients_per_round, alpha=1.0,
        beta=beta, support=support, anneal=False, seed=seed,
        eval_every=eval_every, eval_kwargs=eval_kwargs, channel=channel,
        prefetch=prefetch, sampler=sampler, max_block=max_block,
        sampling=sampling, pool=pool, buffered=buffered, mesh=mesh)
