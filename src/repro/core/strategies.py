"""Federated strategies: each core/ algorithm as pure-JAX hooks.

A ``FedStrategy`` tells the round engine (repro.core.engine) WHAT a
client computes and HOW the server folds the results back; the engine
owns everything else (scheduling, scanning, metering, annealing, eval).
All hooks must be jax-traceable — ``client_update`` runs under ``vmap``
across the round's clients inside a ``lax.scan`` over rounds:

  client_update(phi, client_batch, beta) -> (result_tree, inner_losses)
      phi: broadcast parameters; client_batch: {"x","y"} with leading
      support dim; beta: client learning rate (fp32 scalar).
  server_aggregate(phi, client_results, alpha_t, beta) -> phi
      client_results: result_tree with a leading clients_per_round axis;
      alpha_t: the (possibly annealed) server rate for this round.

Heterogeneity-scheduled runs (any ``SamplingPolicy`` whose
``schedule_kind`` != "uniform", see repro.core.pipeline) use the
schedule-aware variants instead:

  client_update_steps(phi, client_batch, beta, k)
      k: this client's TRACED local step budget from the round's
      ClientSchedule, in the strategy's own units (stream samples for
      TinyReptile, epochs for Reptile/FedAVG). The default ignores k —
      right for one-shot workloads (FedSGD's single gradient, Transfer's
      raw-batch forward) that have no straggler axis.
  server_aggregate_weighted(phi, client_results, alpha_t, beta, weights,
                            axis_name=None)
      weights: (clients,) per-round-normalized aggregation weights
      (0 for non-participants) — partial participation, arrival-weighted
      straggler aggregation, AND FedBuff-style buffered flushes
      (repro.core.pool.BufferedAggregation: the buffered updates arrive
      with a leading buffer-capacity axis and staleness-discounted
      weights, zeros on empty slots) all reduce to this one hook.
      ``axis_name`` is the COLLECTIVE form (mesh-sharded engine runs,
      see run_federated(mesh=...)): client_results and weights then
      carry only this device's cohort shard, and the hook must reduce
      the weighted sum across the named mesh axis (``psum``) — routing
      through ``weighted_client_mean(..., axis_name=...)`` gives that
      for free. ``axis_name=None`` (the default, and the only form the
      engine uses when mesh is None) is bit-for-bit the pre-mesh hook.
  local_step_budget(support) -> int
      The full per-client workload in scheduler units; scheduling
      policies draw each k_i from [1, budget].

A new algorithm is one strategy object — not a new file-long loop.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import meta_interpolate
from repro.core.meta import (finetune_batch, finetune_batch_masked,
                             finetune_online, finetune_online_masked)
from repro.kernels import ref as kref


def weighted_client_mean(trees, weights, axis_name=None):
    """sum_c weights[c] * tree_c along the leading clients axis, in fp32.
    With per-round-normalized weights this is the participation-weighted
    client mean (uniform weights 1/C recover the plain mean).

    Zero-weight clients are truly INERT: their results are zeroed before
    the sum, so a scheduled-out client whose hook still ran on its
    zeroed batch (one-shot strategies ignore local_steps) cannot poison
    the round with a NaN/inf — 0 * NaN would otherwise be NaN.

    ``axis_name`` is the collective form for mesh-sharded runs: the
    leading axis then holds only this device's cohort shard (weights
    likewise), and the local partial sum is all-reduced across the
    named mesh axis. Because the weights are normalized over the FULL
    cohort, psum of the per-shard partial sums IS the global weighted
    mean. The per-leaf partials go through ONE multi-operand ``psum``
    (a single psum primitive bind over the whole tree -> a single
    all-reduce) — XLA CPU (and most backends) execute each all-reduce
    as its own synchronization, so per-leaf psum CALLS would pay one
    cross-device rendezvous per parameter tensor per round. The
    per-leaf form (vs the old flatten-and-concatenate into one vector)
    sums the same elements in the same cross-device order — bitwise
    identical — while preserving each leaf's shape AND sharding: on a
    2-D (clients, model) mesh the partials of model-sharded leaves
    reduce over the clients axis IN PLACE, where the concat would
    force an all-gather of every shard onto every device."""
    def local_sum(q):
        qf = q.astype(jnp.float32)
        w = weights.reshape((-1,) + (1,) * (qf.ndim - 1))
        return jnp.sum(w * jnp.where(w > 0, qf, 0.0), axis=0)
    local = jax.tree.map(local_sum, trees)
    if axis_name is None or not jax.tree.leaves(local):
        return local
    return jax.lax.psum(local, axis_name)


def reptile_aggregate(phi, phi_hats, alpha_t, *,
                      use_pallas: Optional[bool] = None):
    """Server update shared by TinyReptile (C=1) and batched Reptile:
    phi <- phi + alpha_t * (mean_c(phi_hat_c) - phi). The client mean is
    taken in fp32; the interpolation (dtype policy, Pallas routing) is
    engine.meta_interpolate's."""
    mean = jax.tree.map(
        lambda q: jnp.mean(q.astype(jnp.float32), axis=0), phi_hats)
    return meta_interpolate(phi, mean, alpha_t, use_pallas=use_pallas)


def reptile_aggregate_weighted(phi, phi_hats, alpha_t, weights, *,
                               use_pallas: Optional[bool] = None,
                               axis_name=None):
    """Participation/arrival-weighted Reptile server update:
    phi <- phi + alpha_t * (sum_c w_c phi_hat_c - phi). Weights are the
    round's normalized ClientSchedule weights; zero-weight (scheduled
    out) clients contribute nothing. ``axis_name`` reduces the weighted
    client mean across a mesh axis (sharded cohorts / pod clients)."""
    mean = weighted_client_mean(phi_hats, weights, axis_name=axis_name)
    return meta_interpolate(phi, mean, alpha_t, use_pallas=use_pallas)


@dataclasses.dataclass(frozen=True)
class FedStrategy:
    """Base strategy. Subclasses set the class attributes and hooks."""
    loss_fn: Callable

    data_mode = "batch"          # "batch" | "stream" client data layout
    meters_comm = True           # account CommChannel bytes + report them
    tracks_inner_loss = False    # report last-round client loss at evals
    uplink_ref = "params"        # what a partial uplink falls back to for
    #                              untransmitted entries: "params" (the
    #                              broadcast phi — model-returning
    #                              uplinks), "zeros" (gradient uplinks),
    #                              or "none" (no reference; transmit the
    #                              result tree as-is)
    payload_dtype = "float32"    # wire dtype of the client result tree.
    #                              "float32" (default) leaves transport
    #                              simulation to the CommChannel;
    #                              anything else declares NATIVE
    #                              quantized uplinks — the engine then
    #                              requires a matching non-simulating
    #                              channel (e.g. CommChannel("int8",
    #                              quantize=False)) so bytes are billed
    #                              at the true rate and the channel never
    #                              re-quantizes already-integer payloads

    def uplink_template(self, phi):
        """A zero-cost template tree with the SHAPES/DTYPES of this
        strategy's client result (what client_update returns), given the
        broadcast phi. The engine sizes FedBuff buffer slabs from it, so
        quantized strategies stage int8 updates at int8 width. Default:
        phi itself (model- and gradient-shaped uplinks)."""
        return phi

    def client_update(self, phi, client_batch, beta):
        raise NotImplementedError

    def server_aggregate(self, phi, client_results, alpha_t, beta):
        raise NotImplementedError

    def local_step_budget(self, support: int) -> int:
        """Full per-client workload in scheduler units. Default: one
        unit per support sample (stream strategies); epoch-loop and
        one-shot strategies override."""
        return support

    def client_update_steps(self, phi, client_batch, beta, k):
        """Schedule-aware client hook: honor a traced local step budget
        k. Default ignores k (one-shot workloads); strategies with a
        real local loop mask steps >= k via the lax.cond machinery."""
        del k
        return self.client_update(phi, client_batch, beta)

    def server_aggregate_weighted(self, phi, client_results, alpha_t,
                                  beta, weights, axis_name=None):
        raise NotImplementedError(
            f"{type(self).__name__} does not implement weighted "
            "aggregation; define server_aggregate_weighted to run under "
            "scheduled sampling policies (partial participation / "
            "stragglers) — accept axis_name=None too if the strategy "
            "should run on a client-sharded mesh")


@dataclasses.dataclass(frozen=True)
class TinyReptileStrategy(FedStrategy):
    """Paper Algorithm 1: the client consumes its support STREAM one
    sample at a time (online SGD); the server interpolates toward the
    returned phi_hat."""
    use_pallas: Optional[bool] = None

    data_mode = "stream"
    tracks_inner_loss = True

    def client_update(self, phi, client_batch, beta):
        return finetune_online(self.loss_fn, phi,
                               client_batch["x"], client_batch["y"], beta)

    def client_update_steps(self, phi, client_batch, beta, k):
        """Straggler clients consume only their first k stream samples."""
        return finetune_online_masked(self.loss_fn, phi, client_batch["x"],
                                      client_batch["y"], beta, k)

    def server_aggregate(self, phi, client_results, alpha_t, beta):
        return reptile_aggregate(phi, client_results, alpha_t,
                                 use_pallas=self.use_pallas)

    def server_aggregate_weighted(self, phi, client_results, alpha_t,
                                  beta, weights, axis_name=None):
        return reptile_aggregate_weighted(phi, client_results, alpha_t,
                                          weights,
                                          use_pallas=self.use_pallas,
                                          axis_name=axis_name)


@dataclasses.dataclass(frozen=True)
class ReptileStrategy(FedStrategy):
    """Reptile [Nichol et al. 2018]: the client trains on its whole
    support set for E epochs; server averages pseudo-gradients. C=1 is
    serial Reptile, C>1 batched Reptile."""
    epochs: int = 8
    use_pallas: Optional[bool] = None

    tracks_inner_loss = True

    def client_update(self, phi, client_batch, beta):
        return finetune_batch(self.loss_fn, phi, client_batch,
                              self.epochs, beta)

    def local_step_budget(self, support):
        return self.epochs

    def client_update_steps(self, phi, client_batch, beta, k):
        """Straggler clients complete only their first k local epochs."""
        return finetune_batch_masked(self.loss_fn, phi, client_batch,
                                     self.epochs, beta, k)

    def server_aggregate(self, phi, client_results, alpha_t, beta):
        return reptile_aggregate(phi, client_results, alpha_t,
                                 use_pallas=self.use_pallas)

    def server_aggregate_weighted(self, phi, client_results, alpha_t,
                                  beta, weights, axis_name=None):
        return reptile_aggregate_weighted(phi, client_results, alpha_t,
                                          weights,
                                          use_pallas=self.use_pallas,
                                          axis_name=axis_name)


@dataclasses.dataclass(frozen=True)
class FedAvgStrategy(FedStrategy):
    """FedAVG [McMahan et al. 2016]: E local epochs, server averages the
    MODELS (the Eq.-2 objective the paper shows failing in the meta
    regime)."""
    epochs: int = 8

    def client_update(self, phi, client_batch, beta):
        return finetune_batch(self.loss_fn, phi, client_batch,
                              self.epochs, beta)

    def local_step_budget(self, support):
        return self.epochs

    def client_update_steps(self, phi, client_batch, beta, k):
        return finetune_batch_masked(self.loss_fn, phi, client_batch,
                                     self.epochs, beta, k)

    def server_aggregate(self, phi, client_results, alpha_t, beta):
        n = jax.tree.leaves(client_results)[0].shape[0]
        return jax.tree.map(lambda q: q.sum(0) / n, client_results)

    def server_aggregate_weighted(self, phi, client_results, alpha_t,
                                  beta, weights, axis_name=None):
        """Weighted model average over the participating clients only."""
        avg = weighted_client_mean(client_results, weights,
                                   axis_name=axis_name)
        return jax.tree.map(lambda p, q: q.astype(p.dtype), phi, avg)


@dataclasses.dataclass(frozen=True)
class FedSGDStrategy(FedStrategy):
    """FedSGD: every client ships ONE gradient; the server applies the
    mean with the client rate beta."""

    uplink_ref = "zeros"         # untransmitted gradient entries are 0

    def client_update(self, phi, client_batch, beta):
        loss, g = jax.value_and_grad(self.loss_fn)(phi, client_batch)
        return g, loss

    def local_step_budget(self, support):
        return 1                 # one gradient: no straggler axis

    def server_aggregate(self, phi, client_results, alpha_t, beta):
        n = jax.tree.leaves(client_results)[0].shape[0]
        return jax.tree.map(
            lambda p, g: p - beta * g.sum(0) / n, phi, client_results)

    def server_aggregate_weighted(self, phi, client_results, alpha_t,
                                  beta, weights, axis_name=None):
        """Apply the participation-weighted mean gradient."""
        g = weighted_client_mean(client_results, weights,
                                 axis_name=axis_name)
        return jax.tree.map(
            lambda p, gg: (p - beta * gg).astype(p.dtype), phi, g)


@dataclasses.dataclass(frozen=True)
class TransferStrategy(FedStrategy):
    """Joint-training baseline (paper Fig. 1): clients just forward their
    raw batches; the server takes one SGD step on the pooled data. No
    federation, so no comm accounting."""

    meters_comm = False
    uplink_ref = "none"          # raw-data uplink: no phi-shaped reference

    def client_update(self, phi, client_batch, beta):
        return client_batch, jnp.zeros(())

    def local_step_budget(self, support):
        return 1                 # raw-batch forward: no straggler axis

    def server_aggregate(self, phi, client_results, alpha_t, beta):
        pooled = jax.tree.map(
            lambda a: a.reshape(-1, *a.shape[2:]), client_results)
        g = jax.grad(self.loss_fn)(phi, pooled)
        return jax.tree.map(lambda w, gg: w - beta * gg, phi, g)

    def server_aggregate_weighted(self, phi, client_results, alpha_t,
                                  beta, weights, axis_name=None):
        """Per-client pool gradients, weighted — scheduled-out clients'
        (zeroed) batches get weight 0 instead of polluting the pool.
        Mathematically the pooled-gradient with client weights; not
        bitwise the unweighted pool (sum order differs)."""
        grads = jax.vmap(
            lambda b: jax.grad(self.loss_fn)(phi, b))(client_results)
        g = weighted_client_mean(grads, weights, axis_name=axis_name)
        return jax.tree.map(
            lambda w, gg: (w - beta * gg).astype(w.dtype), phi, g)


# ---------------------------------------------------------------------------
# TIFeD: integer-only local training with direct feedback alignment
# ---------------------------------------------------------------------------

# Static exponent policy (powers of two throughout, so every requant
# multiplier is an exact fp32 scaling and quantization error is pure
# rounding): inputs land on the 2^EX grid (sine x in [-5, 5] fits int8
# at 2^-4 — the MCU-realistic a-priori input scale), hidden activations
# on 2^ACT as unsigned 7-bit, and the quantized error SERR grid-steps
# below the output accumulator. Weight exponents are tracked per tensor
# (kref.pow2_exponent); biases live at accumulator scale (int32,
# clipped to +-2^23 so downstream products stay fp32-exact).
TIFED_EX = -4
TIFED_ACT = -3
TIFED_SERR = -5


@functools.lru_cache(maxsize=32)
def _tifed_constants(seed, epochs, dims):
    """Fixed DFA feedback matrices + per-epoch stochastic-rounding
    dither planes, as NumPy so they bake into the jit trace as
    constants — stochastic rounding at zero runtime cost. The dither is
    shared across the round's clients (it is a fresh draw per epoch and
    per weight entry, so each client's requantization stays unbiased;
    clients are not mutually decorrelated — documented in
    docs/PLUGINS.md §6)."""
    din, h1, h2, dout = dims
    npr = np.random.default_rng(seed)
    fb = tuple(np.asarray(npr.integers(-127, 128, (dout, h)), np.float32)
               for h in (h1, h2))
    dith = tuple(np.asarray(npr.random((epochs, a, b)), np.float32)
                 for a, b in ((din, h1), (h1, h2), (h2, dout)))
    return fb, dith


def tifed_dequantize(result):
    """Client result tree -> fp32 params: q * 2^exp per leaf (weight
    leaves carry their per-tensor exponent, biases their accumulator
    scale)."""
    out = {}
    for k, q in result["q"].items():
        e = result["exp"][k].astype(jnp.float32)
        out[k] = q.astype(jnp.float32) * jnp.exp2(
            e.reshape(e.shape + (1,) * (q.ndim - e.ndim)))
    return out


def tifed_requantize(phi):
    """Snap fp32 phi back onto the integer grids (weights to their
    per-tensor int8 grid, biases to the matching accumulator grid), so
    the phi the scan carries is always exactly representable — the
    value every client would reconstruct from an int8 broadcast."""
    out = {}
    for i, ea in enumerate((TIFED_EX, TIFED_ACT, TIFED_ACT)):
        q, e = kref.quantize_pow2(phi[f"w{i}"])
        ef = e.astype(jnp.float32)
        out[f"w{i}"] = q * jnp.exp2(ef)
        eb = ef + ea
        out[f"b{i}"] = jnp.clip(
            jnp.round(phi[f"b{i}"] * jnp.exp2(-eb)),
            -kref.BIAS_MAX, kref.BIAS_MAX) * jnp.exp2(eb)
    return out


@dataclasses.dataclass(frozen=True)
class TifedStrategy(FedStrategy):
    """TIFeD [arXiv 2307.03102]: integer-only local training with direct
    feedback alignment, as a first-class engine strategy.

    Clients never touch fp32 weights: phi is quantized to per-tensor
    power-of-two int8 grids, and each local epoch runs an int8 forward
    pass with int32 accumulation, projects the quantized output error
    straight to one layer through a fixed random feedback matrix (no
    backprop transposes), and requantizes that layer's update to int8
    with stochastic rounding (the layer-cyclic single-layer variant:
    epoch t trains layer t mod 3). Learning rates are pure bit-shifts —
    ``lr_shift`` plus log2(support) folds the batch mean in.

    The uplink is the NATIVE int8/int32 result tree
    ``{"q": {w*, b*}, "exp": {w*, b*}}`` (payload_dtype="int8" — the
    engine bills it at 1 byte/param through a non-simulating
    CommChannel("int8", quantize=False); the six scalar exponents ride
    free like PartialCommChannel's chunk-index side channel). The
    server dequantizes, takes the weighted client mean in the same
    single fused psum as every other strategy, Reptile-interpolates,
    and snaps phi back onto the integer grid — so int8 runs keep both
    engine invariants and compose with pool/FedBuff/mesh/schedules
    unchanged.

    ``loss_fn`` is only used by the engine's fp32 eval finetune (use
    ``models.paper_nets.relu_mlp_loss``: the integer forward is a ReLU
    MLP, not the tanh paper net). Eval finetune rates above ~0.01
    diverge on the ReLU net at k_steps >= 16; the tifed_train wrapper
    defaults accordingly. ``use_pallas`` routes each epoch through the
    fused ``kernels/online_sgd_int8.py`` kernel (None = TPU only; CPU
    uses the oracle math, which XLA fuses at the floor)."""
    epochs: int = 8
    lr_shift: int = 6
    feedback_seed: int = 0
    unroll: int = 2
    use_pallas: Optional[bool] = None

    tracks_inner_loss = True
    payload_dtype = "int8"

    @staticmethod
    def _dims(phi):
        for i in range(3):
            if f"w{i}" not in phi or f"b{i}" not in phi:
                raise ValueError(
                    "TifedStrategy expects the paper MLP pytree "
                    "{w0,b0,w1,b1,w2,b2} (models.paper_nets); got keys "
                    f"{sorted(phi)}")
        return (phi["w0"].shape[0], phi["w0"].shape[1],
                phi["w1"].shape[1], phi["w2"].shape[1])

    def uplink_template(self, phi):
        self._dims(phi)
        q = {f"w{i}": jnp.zeros(phi[f"w{i}"].shape, jnp.int8)
             for i in range(3)}
        q.update({f"b{i}": jnp.zeros(phi[f"b{i}"].shape, jnp.int32)
                  for i in range(3)})
        return {"q": q, "exp": {k: jnp.zeros((), jnp.int32) for k in q}}

    def _run_epochs(self, phi, client_batch, k):
        dims = self._dims(phi)
        x = client_batch["x"].reshape(-1, dims[0])
        y = client_batch["y"].reshape(x.shape[0], dims[3])
        n = x.shape[0]
        # fold the 1/n batch mean into the shift (exact for pow2 n)
        lrs = self.lr_shift + int(np.floor(np.log2(n)))
        fb_np, dith_np = _tifed_constants(self.feedback_seed, self.epochs,
                                          dims)
        fb = tuple(jnp.asarray(f) for f in fb_np)
        dith = tuple(jnp.asarray(d) for d in dith_np)

        f32 = jnp.float32
        ws, ew = [], []
        for i in range(3):
            q, e = kref.quantize_pow2(phi[f"w{i}"])
            ws.append(q)
            ew.append(e)
        ea = (TIFED_EX, TIFED_ACT, TIFED_ACT)
        sacc = [ew[i] + ea[i] for i in range(3)]
        bs = [jnp.clip(jnp.round(phi[f"b{i}"]
                                 * jnp.exp2(-sacc[i].astype(f32))),
                       -kref.BIAS_MAX, kref.BIAS_MAX) for i in range(3)]
        xq = jnp.clip(jnp.round(x * 2.0 ** -TIFED_EX), -127.0, 127.0)
        yal = jnp.round(y * jnp.exp2(-sacc[2].astype(f32)))
        scales = {
            "f0": jnp.exp2((sacc[0] - TIFED_ACT).astype(f32)),
            "f1": jnp.exp2((sacc[1] - TIFED_ACT).astype(f32)),
            "fe": jnp.exp2((sacc[2] - TIFED_SERR).astype(f32)),
            "floss": jnp.exp2(2.0 * sacc[2].astype(f32)) / n,
            "ftw": tuple(
                jnp.exp2((ea[i] + TIFED_SERR - ew[i] - lrs).astype(f32))
                for i in range(3)),
            "ftb": tuple(
                jnp.exp2((TIFED_SERR - sacc[i] - lrs).astype(f32))
                for i in range(3)),
        }
        use_pallas = (jax.default_backend() == "tpu"
                      if self.use_pallas is None else self.use_pallas)
        if use_pallas:
            from repro.kernels import ops as kops
            epoch_fn = kops.dfa_epoch_int8
            init = (tuple(w.astype(jnp.int8) for w in ws),
                    tuple(b.astype(jnp.int32) for b in bs))
            xq_n, yal_n = xq.astype(jnp.int8), yal.astype(jnp.int32)
        else:
            epoch_fn = kref.dfa_int8_epoch
            init = (tuple(ws), tuple(bs))
            xq_n, yal_n = xq, yal

        def run_one(carry, layer, dither):
            cw, cb = carry
            nw, nb, loss = epoch_fn(cw, cb, xq_n, yal_n, layer, fb,
                                    dither, scales)
            return (nw, nb), loss

        def epoch(carry, xs):
            if k is None:
                layer, d0, d1, d2 = xs
                return run_one(carry, layer, (d0, d1, d2))
            idx, layer, d0, d1, d2 = xs
            return jax.lax.cond(
                idx < k,
                lambda c: run_one(c, layer, (d0, d1, d2)),
                lambda c: (c, jnp.float32(0.0)), carry)

        layers = jnp.arange(self.epochs, dtype=jnp.int32) % 3
        xs = (layers,) + dith
        if k is not None:
            xs = (jnp.arange(self.epochs, dtype=jnp.int32),) + xs
        (cw, cb), losses = jax.lax.scan(epoch, init, xs,
                                        unroll=self.unroll)
        result = {
            "q": {"w0": cw[0].astype(jnp.int8),
                  "w1": cw[1].astype(jnp.int8),
                  "w2": cw[2].astype(jnp.int8),
                  "b0": cb[0].astype(jnp.int32),
                  "b1": cb[1].astype(jnp.int32),
                  "b2": cb[2].astype(jnp.int32)},
            "exp": {"w0": ew[0], "w1": ew[1], "w2": ew[2],
                    "b0": sacc[0], "b1": sacc[1], "b2": sacc[2]},
        }
        return result, losses

    def client_update(self, phi, client_batch, beta):
        del beta                      # learning rate is the bit-shift
        return self._run_epochs(phi, client_batch, None)

    def local_step_budget(self, support):
        return self.epochs

    def client_update_steps(self, phi, client_batch, beta, k):
        """Straggler clients complete only their first k integer epochs
        (masked epochs pass the carry through and report loss 0, which
        the engine's weighted round loss expects)."""
        del beta
        return self._run_epochs(phi, client_batch, k)

    def server_aggregate(self, phi, client_results, alpha_t, beta):
        deq = jax.vmap(tifed_dequantize)(client_results)
        mean = jax.tree.map(lambda q: jnp.mean(q, axis=0), deq)
        return tifed_requantize(meta_interpolate(phi, mean, alpha_t))

    def server_aggregate_weighted(self, phi, client_results, alpha_t,
                                  beta, weights, axis_name=None):
        """Quantization-aware weighted aggregation: dequantize each
        client's int8 tree, weighted-mean in the SAME single fused psum
        as the fp32 strategies (the dequantized leaves join
        weighted_client_mean's one multi-operand all-reduce),
        Reptile-interpolate,
        requantize phi back onto the integer grid."""
        deq = jax.vmap(tifed_dequantize)(client_results)
        mean = weighted_client_mean(deq, weights, axis_name=axis_name)
        return tifed_requantize(meta_interpolate(phi, mean, alpha_t))
