"""Federated strategies: each core/ algorithm as pure-JAX hooks.

A ``FedStrategy`` tells the round engine (repro.core.engine) WHAT a
client computes and HOW the server folds the results back; the engine
owns everything else (scheduling, scanning, metering, annealing, eval).
All hooks must be jax-traceable — ``client_update`` runs under ``vmap``
across the round's clients inside a ``lax.scan`` over rounds:

  client_update(phi, client_batch, beta) -> (result_tree, inner_losses)
      phi: broadcast parameters; client_batch: {"x","y"} with leading
      support dim; beta: client learning rate (fp32 scalar).
  server_aggregate(phi, client_results, alpha_t, beta) -> phi
      client_results: result_tree with a leading clients_per_round axis;
      alpha_t: the (possibly annealed) server rate for this round.

Heterogeneity-scheduled runs (any ``SamplingPolicy`` whose
``schedule_kind`` != "uniform", see repro.core.pipeline) use the
schedule-aware variants instead:

  client_update_steps(phi, client_batch, beta, k)
      k: this client's TRACED local step budget from the round's
      ClientSchedule, in the strategy's own units (stream samples for
      TinyReptile, epochs for Reptile/FedAVG). The default ignores k —
      right for one-shot workloads (FedSGD's single gradient, Transfer's
      raw-batch forward) that have no straggler axis.
  server_aggregate_weighted(phi, client_results, alpha_t, beta, weights,
                            axis_name=None)
      weights: (clients,) per-round-normalized aggregation weights
      (0 for non-participants) — partial participation, arrival-weighted
      straggler aggregation, AND FedBuff-style buffered flushes
      (repro.core.pool.BufferedAggregation: the buffered updates arrive
      with a leading buffer-capacity axis and staleness-discounted
      weights, zeros on empty slots) all reduce to this one hook.
      ``axis_name`` is the COLLECTIVE form (mesh-sharded engine runs,
      see run_federated(mesh=...)): client_results and weights then
      carry only this device's cohort shard, and the hook must reduce
      the weighted sum across the named mesh axis (``psum``) — routing
      through ``weighted_client_mean(..., axis_name=...)`` gives that
      for free. ``axis_name=None`` (the default, and the only form the
      engine uses when mesh is None) is bit-for-bit the pre-mesh hook.
  local_step_budget(support) -> int
      The full per-client workload in scheduler units; scheduling
      policies draw each k_i from [1, budget].

A new algorithm is one strategy object — not a new file-long loop.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.engine import meta_interpolate
from repro.core.meta import (finetune_batch, finetune_batch_masked,
                             finetune_online, finetune_online_masked)


def weighted_client_mean(trees, weights, axis_name=None):
    """sum_c weights[c] * tree_c along the leading clients axis, in fp32.
    With per-round-normalized weights this is the participation-weighted
    client mean (uniform weights 1/C recover the plain mean).

    Zero-weight clients are truly INERT: their results are zeroed before
    the sum, so a scheduled-out client whose hook still ran on its
    zeroed batch (one-shot strategies ignore local_steps) cannot poison
    the round with a NaN/inf — 0 * NaN would otherwise be NaN.

    ``axis_name`` is the collective form for mesh-sharded runs: the
    leading axis then holds only this device's cohort shard (weights
    likewise), and the local partial sum is all-reduced across the
    named mesh axis. Because the weights are normalized over the FULL
    cohort, psum of the per-shard partial sums IS the global weighted
    mean. The per-leaf partials are flattened and concatenated into ONE
    psum — XLA CPU (and most backends) execute each all-reduce as its
    own synchronization, so a per-leaf psum would pay one cross-device
    rendezvous per parameter tensor per round; bitwise the same sums
    either way."""
    def local_sum(q):
        qf = q.astype(jnp.float32)
        w = weights.reshape((-1,) + (1,) * (qf.ndim - 1))
        return jnp.sum(w * jnp.where(w > 0, qf, 0.0), axis=0)
    local = jax.tree.map(local_sum, trees)
    if axis_name is None:
        return local
    leaves, treedef = jax.tree.flatten(local)
    if not leaves:
        return local
    if len(leaves) == 1:
        return jax.tree.unflatten(treedef,
                                  [jax.lax.psum(leaves[0], axis_name)])
    flat = jax.lax.psum(
        jnp.concatenate([l.ravel() for l in leaves]), axis_name)
    out, off = [], 0
    for l in leaves:
        out.append(flat[off:off + l.size].reshape(l.shape))
        off += l.size
    return jax.tree.unflatten(treedef, out)


def reptile_aggregate(phi, phi_hats, alpha_t, *,
                      use_pallas: Optional[bool] = None):
    """Server update shared by TinyReptile (C=1) and batched Reptile:
    phi <- phi + alpha_t * (mean_c(phi_hat_c) - phi). The client mean is
    taken in fp32; the interpolation (dtype policy, Pallas routing) is
    engine.meta_interpolate's."""
    mean = jax.tree.map(
        lambda q: jnp.mean(q.astype(jnp.float32), axis=0), phi_hats)
    return meta_interpolate(phi, mean, alpha_t, use_pallas=use_pallas)


def reptile_aggregate_weighted(phi, phi_hats, alpha_t, weights, *,
                               use_pallas: Optional[bool] = None,
                               axis_name=None):
    """Participation/arrival-weighted Reptile server update:
    phi <- phi + alpha_t * (sum_c w_c phi_hat_c - phi). Weights are the
    round's normalized ClientSchedule weights; zero-weight (scheduled
    out) clients contribute nothing. ``axis_name`` reduces the weighted
    client mean across a mesh axis (sharded cohorts / pod clients)."""
    mean = weighted_client_mean(phi_hats, weights, axis_name=axis_name)
    return meta_interpolate(phi, mean, alpha_t, use_pallas=use_pallas)


@dataclasses.dataclass(frozen=True)
class FedStrategy:
    """Base strategy. Subclasses set the class attributes and hooks."""
    loss_fn: Callable

    data_mode = "batch"          # "batch" | "stream" client data layout
    meters_comm = True           # account CommChannel bytes + report them
    tracks_inner_loss = False    # report last-round client loss at evals
    uplink_ref = "params"        # what a partial uplink falls back to for
    #                              untransmitted entries: "params" (the
    #                              broadcast phi — model-returning
    #                              uplinks), "zeros" (gradient uplinks),
    #                              or "none" (no reference; transmit the
    #                              result tree as-is)

    def client_update(self, phi, client_batch, beta):
        raise NotImplementedError

    def server_aggregate(self, phi, client_results, alpha_t, beta):
        raise NotImplementedError

    def local_step_budget(self, support: int) -> int:
        """Full per-client workload in scheduler units. Default: one
        unit per support sample (stream strategies); epoch-loop and
        one-shot strategies override."""
        return support

    def client_update_steps(self, phi, client_batch, beta, k):
        """Schedule-aware client hook: honor a traced local step budget
        k. Default ignores k (one-shot workloads); strategies with a
        real local loop mask steps >= k via the lax.cond machinery."""
        del k
        return self.client_update(phi, client_batch, beta)

    def server_aggregate_weighted(self, phi, client_results, alpha_t,
                                  beta, weights, axis_name=None):
        raise NotImplementedError(
            f"{type(self).__name__} does not implement weighted "
            "aggregation; define server_aggregate_weighted to run under "
            "scheduled sampling policies (partial participation / "
            "stragglers) — accept axis_name=None too if the strategy "
            "should run on a client-sharded mesh")


@dataclasses.dataclass(frozen=True)
class TinyReptileStrategy(FedStrategy):
    """Paper Algorithm 1: the client consumes its support STREAM one
    sample at a time (online SGD); the server interpolates toward the
    returned phi_hat."""
    use_pallas: Optional[bool] = None

    data_mode = "stream"
    tracks_inner_loss = True

    def client_update(self, phi, client_batch, beta):
        return finetune_online(self.loss_fn, phi,
                               client_batch["x"], client_batch["y"], beta)

    def client_update_steps(self, phi, client_batch, beta, k):
        """Straggler clients consume only their first k stream samples."""
        return finetune_online_masked(self.loss_fn, phi, client_batch["x"],
                                      client_batch["y"], beta, k)

    def server_aggregate(self, phi, client_results, alpha_t, beta):
        return reptile_aggregate(phi, client_results, alpha_t,
                                 use_pallas=self.use_pallas)

    def server_aggregate_weighted(self, phi, client_results, alpha_t,
                                  beta, weights, axis_name=None):
        return reptile_aggregate_weighted(phi, client_results, alpha_t,
                                          weights,
                                          use_pallas=self.use_pallas,
                                          axis_name=axis_name)


@dataclasses.dataclass(frozen=True)
class ReptileStrategy(FedStrategy):
    """Reptile [Nichol et al. 2018]: the client trains on its whole
    support set for E epochs; server averages pseudo-gradients. C=1 is
    serial Reptile, C>1 batched Reptile."""
    epochs: int = 8
    use_pallas: Optional[bool] = None

    tracks_inner_loss = True

    def client_update(self, phi, client_batch, beta):
        return finetune_batch(self.loss_fn, phi, client_batch,
                              self.epochs, beta)

    def local_step_budget(self, support):
        return self.epochs

    def client_update_steps(self, phi, client_batch, beta, k):
        """Straggler clients complete only their first k local epochs."""
        return finetune_batch_masked(self.loss_fn, phi, client_batch,
                                     self.epochs, beta, k)

    def server_aggregate(self, phi, client_results, alpha_t, beta):
        return reptile_aggregate(phi, client_results, alpha_t,
                                 use_pallas=self.use_pallas)

    def server_aggregate_weighted(self, phi, client_results, alpha_t,
                                  beta, weights, axis_name=None):
        return reptile_aggregate_weighted(phi, client_results, alpha_t,
                                          weights,
                                          use_pallas=self.use_pallas,
                                          axis_name=axis_name)


@dataclasses.dataclass(frozen=True)
class FedAvgStrategy(FedStrategy):
    """FedAVG [McMahan et al. 2016]: E local epochs, server averages the
    MODELS (the Eq.-2 objective the paper shows failing in the meta
    regime)."""
    epochs: int = 8

    def client_update(self, phi, client_batch, beta):
        return finetune_batch(self.loss_fn, phi, client_batch,
                              self.epochs, beta)

    def local_step_budget(self, support):
        return self.epochs

    def client_update_steps(self, phi, client_batch, beta, k):
        return finetune_batch_masked(self.loss_fn, phi, client_batch,
                                     self.epochs, beta, k)

    def server_aggregate(self, phi, client_results, alpha_t, beta):
        n = jax.tree.leaves(client_results)[0].shape[0]
        return jax.tree.map(lambda q: q.sum(0) / n, client_results)

    def server_aggregate_weighted(self, phi, client_results, alpha_t,
                                  beta, weights, axis_name=None):
        """Weighted model average over the participating clients only."""
        avg = weighted_client_mean(client_results, weights,
                                   axis_name=axis_name)
        return jax.tree.map(lambda p, q: q.astype(p.dtype), phi, avg)


@dataclasses.dataclass(frozen=True)
class FedSGDStrategy(FedStrategy):
    """FedSGD: every client ships ONE gradient; the server applies the
    mean with the client rate beta."""

    uplink_ref = "zeros"         # untransmitted gradient entries are 0

    def client_update(self, phi, client_batch, beta):
        loss, g = jax.value_and_grad(self.loss_fn)(phi, client_batch)
        return g, loss

    def local_step_budget(self, support):
        return 1                 # one gradient: no straggler axis

    def server_aggregate(self, phi, client_results, alpha_t, beta):
        n = jax.tree.leaves(client_results)[0].shape[0]
        return jax.tree.map(
            lambda p, g: p - beta * g.sum(0) / n, phi, client_results)

    def server_aggregate_weighted(self, phi, client_results, alpha_t,
                                  beta, weights, axis_name=None):
        """Apply the participation-weighted mean gradient."""
        g = weighted_client_mean(client_results, weights,
                                 axis_name=axis_name)
        return jax.tree.map(
            lambda p, gg: (p - beta * gg).astype(p.dtype), phi, g)


@dataclasses.dataclass(frozen=True)
class TransferStrategy(FedStrategy):
    """Joint-training baseline (paper Fig. 1): clients just forward their
    raw batches; the server takes one SGD step on the pooled data. No
    federation, so no comm accounting."""

    meters_comm = False
    uplink_ref = "none"          # raw-data uplink: no phi-shaped reference

    def client_update(self, phi, client_batch, beta):
        return client_batch, jnp.zeros(())

    def local_step_budget(self, support):
        return 1                 # raw-batch forward: no straggler axis

    def server_aggregate(self, phi, client_results, alpha_t, beta):
        pooled = jax.tree.map(
            lambda a: a.reshape(-1, *a.shape[2:]), client_results)
        g = jax.grad(self.loss_fn)(phi, pooled)
        return jax.tree.map(lambda w, gg: w - beta * gg, phi, g)

    def server_aggregate_weighted(self, phi, client_results, alpha_t,
                                  beta, weights, axis_name=None):
        """Per-client pool gradients, weighted — scheduled-out clients'
        (zeroed) batches get weight 0 instead of polluting the pool.
        Mathematically the pooled-gradient with client weights; not
        bitwise the unweighted pool (sum order differs)."""
        grads = jax.vmap(
            lambda b: jax.grad(self.loss_fn)(phi, b))(client_results)
        g = weighted_client_mean(grads, weights, axis_name=axis_name)
        return jax.tree.map(
            lambda w, gg: (w - beta * gg).astype(w.dtype), phi, g)
