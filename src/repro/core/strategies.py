"""Federated strategies: each core/ algorithm as two pure-JAX hooks.

A ``FedStrategy`` tells the round engine (repro.core.engine) WHAT a
client computes and HOW the server folds the results back; the engine
owns everything else (sampling, scanning, metering, annealing, eval).
Both hooks must be jax-traceable — ``client_update`` runs under
``vmap`` across the round's clients inside a ``lax.scan`` over rounds:

  client_update(phi, client_batch, beta) -> (result_tree, inner_losses)
      phi: broadcast parameters; client_batch: {"x","y"} with leading
      support dim; beta: client learning rate (fp32 scalar).
  server_aggregate(phi, client_results, alpha_t, beta) -> phi
      client_results: result_tree with a leading clients_per_round axis;
      alpha_t: the (possibly annealed) server rate for this round.

A new algorithm is one strategy object — not a new file-long loop.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.engine import meta_interpolate
from repro.core.meta import finetune_batch, finetune_online


def reptile_aggregate(phi, phi_hats, alpha_t, *,
                      use_pallas: Optional[bool] = None):
    """Server update shared by TinyReptile (C=1) and batched Reptile:
    phi <- phi + alpha_t * (mean_c(phi_hat_c) - phi). The client mean is
    taken in fp32; the interpolation (dtype policy, Pallas routing) is
    engine.meta_interpolate's."""
    mean = jax.tree.map(
        lambda q: jnp.mean(q.astype(jnp.float32), axis=0), phi_hats)
    return meta_interpolate(phi, mean, alpha_t, use_pallas=use_pallas)


@dataclasses.dataclass(frozen=True)
class FedStrategy:
    """Base strategy. Subclasses set the class attributes and hooks."""
    loss_fn: Callable

    data_mode = "batch"          # "batch" | "stream" client data layout
    meters_comm = True           # account CommChannel bytes + report them
    tracks_inner_loss = False    # report last-round client loss at evals
    uplink_ref = "params"        # what a partial uplink falls back to for
    #                              untransmitted entries: "params" (the
    #                              broadcast phi — model-returning
    #                              uplinks), "zeros" (gradient uplinks),
    #                              or "none" (no reference; transmit the
    #                              result tree as-is)

    def client_update(self, phi, client_batch, beta):
        raise NotImplementedError

    def server_aggregate(self, phi, client_results, alpha_t, beta):
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class TinyReptileStrategy(FedStrategy):
    """Paper Algorithm 1: the client consumes its support STREAM one
    sample at a time (online SGD); the server interpolates toward the
    returned phi_hat."""
    use_pallas: Optional[bool] = None

    data_mode = "stream"
    tracks_inner_loss = True

    def client_update(self, phi, client_batch, beta):
        return finetune_online(self.loss_fn, phi,
                               client_batch["x"], client_batch["y"], beta)

    def server_aggregate(self, phi, client_results, alpha_t, beta):
        return reptile_aggregate(phi, client_results, alpha_t,
                                 use_pallas=self.use_pallas)


@dataclasses.dataclass(frozen=True)
class ReptileStrategy(FedStrategy):
    """Reptile [Nichol et al. 2018]: the client trains on its whole
    support set for E epochs; server averages pseudo-gradients. C=1 is
    serial Reptile, C>1 batched Reptile."""
    epochs: int = 8
    use_pallas: Optional[bool] = None

    tracks_inner_loss = True

    def client_update(self, phi, client_batch, beta):
        return finetune_batch(self.loss_fn, phi, client_batch,
                              self.epochs, beta)

    def server_aggregate(self, phi, client_results, alpha_t, beta):
        return reptile_aggregate(phi, client_results, alpha_t,
                                 use_pallas=self.use_pallas)


@dataclasses.dataclass(frozen=True)
class FedAvgStrategy(FedStrategy):
    """FedAVG [McMahan et al. 2016]: E local epochs, server averages the
    MODELS (the Eq.-2 objective the paper shows failing in the meta
    regime)."""
    epochs: int = 8

    def client_update(self, phi, client_batch, beta):
        return finetune_batch(self.loss_fn, phi, client_batch,
                              self.epochs, beta)

    def server_aggregate(self, phi, client_results, alpha_t, beta):
        n = jax.tree.leaves(client_results)[0].shape[0]
        return jax.tree.map(lambda q: q.sum(0) / n, client_results)


@dataclasses.dataclass(frozen=True)
class FedSGDStrategy(FedStrategy):
    """FedSGD: every client ships ONE gradient; the server applies the
    mean with the client rate beta."""

    uplink_ref = "zeros"         # untransmitted gradient entries are 0

    def client_update(self, phi, client_batch, beta):
        loss, g = jax.value_and_grad(self.loss_fn)(phi, client_batch)
        return g, loss

    def server_aggregate(self, phi, client_results, alpha_t, beta):
        n = jax.tree.leaves(client_results)[0].shape[0]
        return jax.tree.map(
            lambda p, g: p - beta * g.sum(0) / n, phi, client_results)


@dataclasses.dataclass(frozen=True)
class TransferStrategy(FedStrategy):
    """Joint-training baseline (paper Fig. 1): clients just forward their
    raw batches; the server takes one SGD step on the pooled data. No
    federation, so no comm accounting."""

    meters_comm = False
    uplink_ref = "none"          # raw-data uplink: no phi-shaped reference

    def client_update(self, phi, client_batch, beta):
        return client_batch, jnp.zeros(())

    def server_aggregate(self, phi, client_results, alpha_t, beta):
        pooled = jax.tree.map(
            lambda a: a.reshape(-1, *a.shape[2:]), client_results)
        g = jax.grad(self.loss_fn)(phi, pooled)
        return jax.tree.map(lambda w, gg: w - beta * gg, phi, g)
