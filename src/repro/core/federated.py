"""Mesh-scale federated meta-learning (beyond-paper scale, paper-faithful
semantics).

Two mappings of the paper's schema onto the production mesh:

1. COHORT mode (``make_meta_train_step`` in repro.runtime.steps): the
   data-parallel section of the mesh acts as one composite client; the K
   inner SGD steps consume the streaming microbatches; Reptile
   interpolation closes the round. Collective structure: K gradient
   all-reduces over ("pod","data") + the interpolation.

2. POD-CLIENT mode (here): each POD is one federated client. Inner SGD
   all-reduces stay WITHIN the pod (cheap intra-pod ICI); the pods'
   pseudo-gradients are exchanged across the (slow) pod axis ONCE per
   round — TinyReptile's communication thriftiness expressed as a
   collective schedule: O(K) intra-pod collectives, O(1) cross-pod
   collectives.

Pod-client mode no longer hand-rolls the round: it is a thin
CONFIGURATION of the round engine's building blocks — each pod runs
``repro.core.engine.streaming_sgd`` (the engine's inner loop) on its own
client stream, and the server fold is the strategies' collective
aggregation hook (``reptile_aggregate_weighted(..., axis_name="pod")``:
each pod contributes weight 1/n_pods and the weighted client mean
all-reduces across the pod axis — exactly the masked-psum form the
client-sharded engine uses over its "clients" axis, see
``run_federated(mesh=...)``). shard_map (manual over "pod", GSPMD auto
over ("data","model") inside) comes from the shared
``repro.runtime.sharding.shard_map_compat``.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# re-exported: shard_map_compat historically lived here; it is now the
# shared wrapper in repro.runtime.sharding (the round engine's
# client-sharded block runner uses it too)
from repro.runtime.sharding import shard_map_compat  # noqa: F401


def make_pod_client_meta_step(model, mesh, *, beta: float = 0.01,
                              alpha: float = 0.5) -> Callable:
    """TinyReptile round with pods as clients. batch leaves have leading
    dims (K, mb, ...) with mb sharded over ("pod","data"); inside
    shard_map each pod sees mb/n_pods rows = its OWN client stream."""
    if "pod" not in mesh.axis_names:
        raise ValueError("pod-client mode needs the multi-pod mesh")

    # Partial-auto shard_map (manual "pod", GSPMD auto data/model) needs
    # the modern jax.shard_map; the experimental fallback miscompiles
    # partial-manual subgroups (XLA CHECK IsManualSubgroup), so there we
    # go fully manual: every device in a pod computes the pod's whole
    # client batch (replicated instead of data-sharded) — identical
    # numerics, just without intra-pod data parallelism.
    partial_auto = hasattr(jax, "shard_map")
    manual = ("pod",) if partial_auto else tuple(mesh.axis_names)
    n_pods = mesh.shape["pod"]

    def round_body(phi, batch, alpha_t):
        # runs per-pod (manual over "pod"; auto over data/model);
        # internal constraints must not mention the manual axes
        from repro.core.engine import streaming_sgd
        from repro.core.strategies import reptile_aggregate_weighted
        from repro.runtime.shardctx import manual_axes

        with manual_axes(*manual):
            # the engine's inner loop: one SGD step per arriving
            # microbatch, fp32 update math
            phi_hat, losses = streaming_sgd(model.loss_fn, phi, batch,
                                            beta)
            # the engine's server fold: this pod is ONE client of the
            # n_pods cohort (weight 1/n_pods); the weighted client mean
            # all-reduces across "pod" — the O(1) cross-pod exchange
            new_phi = reptile_aggregate_weighted(
                phi, jax.tree.map(lambda q: q[None], phi_hat), alpha_t,
                jnp.full((1,), 1.0 / n_pods, jnp.float32),
                use_pallas=False, axis_name="pod")
            loss = jax.lax.pmean(losses.mean(), "pod")
            return new_phi, {"loss": loss,
                             "inner_first": jax.lax.pmean(losses[0], "pod"),
                             "inner_last": jax.lax.pmean(losses[-1], "pod")}

    def step(phi, batch, alpha_t=None):
        # manual ONLY over "pod": params replicated across pods (each pod =
        # one client starting from the same phi), batch split per pod on
        # the microbatch dim. "data"/"model" stay auto (GSPMD shards them
        # via the model's internal constraints). alpha_t optionally
        # overrides the static server rate with a traced (annealed)
        # scalar — launch/train.py's --mesh pod path.
        if alpha_t is None:
            alpha_t = jnp.float32(alpha)
        in_specs = (
            jax.tree.map(lambda x: P(), phi),
            jax.tree.map(lambda x: P(None, "pod"), batch),
            P(),
        )
        out_specs = (jax.tree.map(lambda x: P(), phi), P())
        fn = shard_map_compat(
            round_body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            manual_axes_names=set(manual))
        return fn(phi, batch, jnp.asarray(alpha_t, jnp.float32))

    return step
