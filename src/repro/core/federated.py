"""Mesh-scale federated meta-learning (beyond-paper scale, paper-faithful
semantics).

Two mappings of the paper's schema onto the production mesh:

1. COHORT mode (``make_meta_train_step`` in repro.runtime.steps): the
   data-parallel section of the mesh acts as one composite client; the K
   inner SGD steps consume the streaming microbatches; Reptile
   interpolation closes the round. Collective structure: K gradient
   all-reduces over ("pod","data") + the interpolation.

2. POD-CLIENT mode (here): each POD is one federated client. Inner SGD
   all-reduces stay WITHIN the pod (cheap intra-pod ICI); the pods'
   pseudo-gradients (phi_hat - phi) are exchanged across the (slow)
   pod axis ONCE per round — TinyReptile's communication thriftiness
   expressed as a collective schedule: O(K) intra-pod collectives,
   O(1) cross-pod collectives.

Pod-client mode uses shard_map manual over "pod" with GSPMD auto over
("data","model") inside.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.runtime.sharding import param_spec as param_spec_rule, _path_str


def shard_map_compat(f, *, mesh, in_specs, out_specs, manual_axes_names):
    """Version-portable shard_map: manual over `manual_axes_names`, GSPMD
    auto over every other mesh axis.

    Newer JAX exposes ``jax.shard_map(..., axis_names=...)`` (manual axes
    named directly); older releases only have
    ``jax.experimental.shard_map.shard_map(..., auto=...)`` (auto axes
    named, i.e. the complement). Resolve whichever exists.
    """
    manual = frozenset(manual_axes_names)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False,
                             axis_names=set(manual))
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - manual
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False, auto=auto)


def make_pod_client_meta_step(model, mesh, *, beta: float = 0.01,
                              alpha: float = 0.5) -> Callable:
    """TinyReptile round with pods as clients. batch: (K, mb, S) arrays
    sharded over ("pod","data") on mb? No — each pod sees its OWN client
    stream: batch leading dims (K, mb, ...) with mb sharded over
    ("pod","data"); inside shard_map each pod gets mb/npods rows = its
    client's stream."""
    if "pod" not in mesh.axis_names:
        raise ValueError("pod-client mode needs the multi-pod mesh")

    # Partial-auto shard_map (manual "pod", GSPMD auto data/model) needs
    # the modern jax.shard_map; the experimental fallback miscompiles
    # partial-manual subgroups (XLA CHECK IsManualSubgroup), so there we
    # go fully manual: every device in a pod computes the pod's whole
    # client batch (replicated instead of data-sharded) — identical
    # numerics, just without intra-pod data parallelism.
    partial_auto = hasattr(jax, "shard_map")
    manual = ("pod",) if partial_auto else tuple(mesh.axis_names)

    def loss_of(phi, micro):
        return model.loss_fn(phi, micro)

    def round_body(phi, batch):
        # runs per-pod (manual over "pod"; auto over data/model);
        # internal constraints must not mention the manual axes
        from repro.runtime.shardctx import manual_axes

        def inner(phi_hat, micro):
            loss, g = jax.value_and_grad(loss_of)(phi_hat, micro)
            # gradient all-reduce over the pod's OWN data section happens
            # automatically via GSPMD (auto axes); only "pod" is manual.
            phi_hat = jax.tree.map(
                lambda p, gg: (p.astype(jnp.float32)
                               - beta * gg.astype(jnp.float32)).astype(p.dtype),
                phi_hat, g)
            return phi_hat, loss

        with manual_axes(*manual):
            phi_hat, losses = jax.lax.scan(inner, phi, batch)
            # pseudo-gradient; cross-pod exchange happens ONCE here
            delta = jax.tree.map(lambda q, p: q - p, phi_hat, phi)
            delta = jax.tree.map(
                lambda d: jax.lax.pmean(d, axis_name="pod"), delta)
            new_phi = jax.tree.map(
                lambda p, d: (p.astype(jnp.float32)
                              + alpha * d.astype(jnp.float32)).astype(p.dtype),
                phi, delta)
            return new_phi, {"loss": jax.lax.pmean(losses.mean(), "pod")}

    def step(phi, batch):
        # manual ONLY over "pod": params replicated across pods (each pod =
        # one client starting from the same phi), batch split per pod on
        # the microbatch dim. "data"/"model" stay auto (GSPMD shards them
        # via the model's internal constraints).
        in_specs = (
            jax.tree.map(lambda x: P(), phi),
            jax.tree.map(lambda x: P(None, "pod"), batch),
        )
        out_specs = (jax.tree.map(lambda x: P(), phi), P())
        fn = shard_map_compat(
            round_body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            manual_axes_names=set(manual))
        return fn(phi, batch)

    return step
