"""Host/device round pipeline: block planning, background prefetch, and
pluggable client-scheduling policies for the federated round engine.

PR 1 moved the round math on-device (vmap x lax.scan); PR 2 closed the
host/device gap (fixed-shape blocks, background prefetch); this module
now also owns the engine's per-round, per-client ROUND STATE:

- ``plan_blocks``: split a run into scan blocks at eval boundaries and
  ``max_block``, and pick ONE fixed padded length for every block in the
  run — the retrace-free shape contract (the block runner compiles once
  per strategy/channel/schedule-shape config; uneven eval/tail blocks
  are padded and masked instead of re-traced).
- ``BlockPrefetcher``: a background producer thread (the levanter
  background-data-loading pattern) that samples and stages block N+1
  while the device runs block N. Double-buffered at depth=2; the
  producer runs strictly in block order, so a seeded host RNG consumed
  inside ``produce`` sees exactly the synchronous draw order — pipelined
  and synchronous runs are bit-for-bit identical.
- ``ClientSchedule``: the structured scan carry that replaced the old
  "scalar validity bit + alpha" tuple. Per padded round it carries the
  validity bit, the annealed server rate, the ABSOLUTE round index
  (rotating ``PartialCommChannel`` masks fold it into their mask key
  inside the scan), and per cohort slot a participation mask, a local
  step count, and an aggregation weight. It is a registered pytree, so
  it device-stages through the prefetcher and slices through
  ``lax.scan`` like any other block input.
- ``SamplingPolicy``: which client tasks feed each round AND what the
  round's schedule looks like. ``UniformSampling`` (the paper's schema:
  everyone shows up, same step count, uniform weights) keeps the
  engine's legacy bit-for-bit fast path; ``PartialParticipation`` and
  ``StragglerSampling`` are the deployment-scenario plugins — a new
  scenario is a policy object, not a sixth training loop. Pooled runs
  (a persistent ``repro.core.pool.ClientPool``) additionally plan a
  per-round ``cohort`` of pool indices via ``plan_pool_schedule``;
  availability processes (diurnal / Markov check-ins) live in
  repro.core.pool and override that hook.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import jax
import numpy as np

SAMPLERS = ("reference", "vectorized")


def plan_blocks(rounds: int, eval_every: int, max_block: int, *,
                start: int = 0,
                ckpt_every: int = 0) -> Tuple[List[Tuple[int, int]], int]:
    """Split ``rounds`` into scan blocks; return ``(blocks, pad)``.

    ``blocks`` is a list of ``(start, end)`` half-open round ranges that
    cover ``[start, rounds)``, cut at every eval boundary (multiples of
    ``eval_every``), at every checkpoint boundary (multiples of
    ``ckpt_every``, when > 0 — snapshots must land on block ends), and
    at most ``max_block`` rounds long. ``pad`` is the single fixed
    length every block is padded to on the host —
    ``min(max_block, stride, ckpt_every, rounds)`` where ``stride`` is
    the eval cadence — so one run uses exactly one block shape
    regardless of ``rounds % eval_every`` or the tail.

    ``start`` > 0 fast-forwards the plan (the checkpoint-resume path):
    cuts are at ABSOLUTE round positions, so resuming from a block
    boundary replays exactly the uninterrupted run's remaining blocks.
    Block splitting itself is bitwise-neutral — the scan executes the
    same per-round ops in the same order however ``[start, rounds)`` is
    chunked — which is what lets checkpoint cuts and resume replans
    preserve bit-for-bit parity.
    """
    if max_block <= 0:
        raise ValueError(f"max_block must be positive, got {max_block!r}")
    if start < 0:
        raise ValueError(f"start must be >= 0, got {start!r}")
    if rounds <= start:
        return [], 0
    stride = eval_every if eval_every else rounds
    blocks: List[Tuple[int, int]] = []
    rnd = start
    while rnd < rounds:
        end = min(rounds, (rnd // stride + 1) * stride, rnd + max_block)
        if ckpt_every:
            end = min(end, (rnd // ckpt_every + 1) * ckpt_every)
        blocks.append((rnd, end))
        rnd = end
    pad = min(max_block, stride, rounds)
    if ckpt_every:
        pad = min(pad, ckpt_every)
    assert all(end - s <= pad for s, end in blocks)
    return blocks, pad


class BlockPrefetcher:
    """Run ``produce(i)`` for ``i in range(n)`` on a daemon thread, keeping
    at most ``depth`` staged results ahead of the consumer.

    ``produce`` typically samples a block on the host and ``device_put``s
    it, so H2D staging of block N+1 hides behind device compute on block N
    (``depth=2`` = classic double buffering). Items are produced strictly
    in order. Producer exceptions are re-raised from :meth:`get`, which
    raises ``StopIteration`` once all ``n`` items were consumed (no
    deadlock on over-consumption); call :meth:`close` (idempotent) to
    stop early without deadlocking the bounded queue.
    """

    _DONE = object()

    def __init__(self, produce: Callable[[int], object], n: int,
                 depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._stop = threading.Event()
        self._done = False
        self._thread = threading.Thread(target=self._run, args=(produce, n),
                                        name="block-prefetch", daemon=True)
        self._thread.start()

    def _put(self, item) -> None:
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return
            except queue.Full:
                continue

    def _run(self, produce, n) -> None:
        try:
            for i in range(n):
                if self._stop.is_set():
                    return
                self._put((None, produce(i)))
            self._put((None, self._DONE))
        except BaseException as exc:  # propagated to the consumer
            self._put((exc, None))

    def get(self):
        """Next staged item, blocking; re-raises producer exceptions and
        raises StopIteration once the stream is exhausted or closed."""
        if self._done:
            raise StopIteration("prefetcher exhausted")
        exc, item = self._q.get()
        if exc is not None:
            self._done = True
            self._stop.set()
            raise exc
        if item is self._DONE:
            self._done = True
            raise StopIteration("prefetcher exhausted")
        return item

    def close(self) -> None:
        """Stop the producer and drain the queue (safe to call twice)."""
        self._done = True
        self._stop.set()
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)


def block_shardings(mesh, axis: str, tree):
    """NamedSharding staging tree for one padded block on a client mesh.

    The prefetch producer's ``device_put`` target when the engine runs
    mesh-sharded: every leaf with a client axis — schedule rows and
    batch arrays, all shaped (padded rounds, clients, ...) — splits its
    dim 1 over the ``axis`` mesh axis so each device receives exactly
    its cohort shard (H2D staging of block N+1 still hides behind
    device compute on block N); per-round vectors (validity, alpha,
    round index) replicate. The cohort axis is already padded to a
    multiple of the shard count by the engine.
    """
    from jax.sharding import NamedSharding, PartitionSpec

    def of(x):
        spec = (PartitionSpec(None, axis) if np.ndim(x) >= 2
                else PartitionSpec())
        return NamedSharding(mesh, spec)

    return jax.tree.map(of, tree)


def single_device_of(tree):
    """The one device every jax leaf of ``tree`` lives on, or None (plain
    NumPy leaves, sharded/multi-device trees, empty trees). Prefetch
    producers must pin ``device_put`` to this explicitly —
    ``jax.default_device`` is thread-local and does not reach the
    background thread."""
    devices = {d for leaf in jax.tree.leaves(tree)
               if hasattr(leaf, "devices") for d in leaf.devices()}
    return devices.pop() if len(devices) == 1 else None


def prefetch_items(produce: Callable[[int], object], n: int,
                   depth: int = 2) -> Iterator[object]:
    """Yield ``produce(i)`` for ``i in range(n)``, staged up to ``depth``
    ahead by a :class:`BlockPrefetcher` thread. ``depth=0`` (or a single
    item) falls back to inline calls — same order, same numerics. The
    producer is shut down when the generator is exhausted or closed
    (``.close()`` / garbage collection), so early consumer exits don't
    leak the thread.
    """
    if depth <= 0 or n <= 1:
        for i in range(n):
            yield produce(i)
        return
    pf = BlockPrefetcher(produce, n, depth=depth)
    try:
        for _ in range(n):
            yield pf.get()
    finally:
        pf.close()


def seat_cohorts(rng, pool_size: int, clients: int,
                 rows: int) -> np.ndarray:
    """Uniform without-replacement cohort seating in O(rows * clients)
    host work, independent of ``pool_size``.

    ``Generator.choice(n, k, replace=False)`` permutes the full
    population internally — at n=10^6 with k=256 seats that is ~4000x
    more work than the seats drawn, and it dominated pooled-run
    planning at fleet scale. For sparse draws (k << n) rejection
    sampling touches O(k) candidates per row (expected collisions
    ~k^2/2n, vanishing as n grows); near-dense rows (8k >= n) keep the
    permutation draw, which is optimal there. Consumes ``rng``
    deterministically in row order — a NEW stream contract for the
    ``sampler="vectorized"`` path (``sampler="reference"`` keeps the
    legacy per-round ``choice`` order bit-for-bit)."""
    out = np.empty((rows, clients), np.int32)
    if clients * 8 >= pool_size:
        for r in range(rows):
            out[r] = rng.choice(pool_size, size=clients, replace=False)
        return out
    for r in range(rows):
        seen = set()
        seats = []
        while len(seats) < clients:
            draw = rng.integers(pool_size,
                                size=clients - len(seats)).tolist()
            for cand in draw:
                if cand not in seen:
                    seen.add(cand)
                    seats.append(cand)
        out[r] = seats
    return out


@dataclasses.dataclass(frozen=True)
class ClientSchedule:
    """Per-round, per-client round state threaded through the block scan.

    One instance describes a whole padded block; ``lax.scan`` slices the
    leading (padded rounds) axis so each scan step sees one round's row.
    It is a registered pytree: it device-stages through the prefetcher
    and scans like any other block input, which is what keeps
    heterogeneous rounds at ZERO per-round host dispatches.

    valid:          (R,)    bool — False on padded rounds AND on pooled
                    rounds where no client checked in (both are runtime
                    no-ops: ``lax.cond`` passes the carry through).
    alpha:          (R,)    f32  — annealed server rate for the round.
    round_index:    (R,)    i32  — ABSOLUTE round number; rotating
                    partial-comm masks fold it into their mask key, and
                    pooled runs use it to stamp ``PoolState.last_seen``
                    and the FedBuff buffer's staleness tags.
    participation:  (R, C)  bool — which cohort slots train (and pay
                    transport) this round.
    local_steps:    (R, C)  i32  — per-client local step budget k_i, in
                    the strategy's own units (stream samples / epochs).
    weights:        (R, C)  f32  — aggregation weights, normalized per
                    round (0 for non-participants).
    cohort:         (R, C)  i32 or None — WHICH persistent pool client
                    occupies each cohort slot this round (indices into a
                    ``repro.core.pool.ClientPool``; unique per round).
                    The block runner gathers/scatters the pool's
                    cross-round state by these indices inside the scan.
                    None on legacy (pool-free) runs, where cohort slots
                    are anonymous and resampled every round.
    """
    valid: object
    alpha: object
    round_index: object
    participation: object
    local_steps: object
    weights: object
    cohort: object = None

    _FIELDS = ("valid", "alpha", "round_index", "participation",
               "local_steps", "weights", "cohort")


jax.tree_util.register_pytree_node(
    ClientSchedule,
    lambda s: (tuple(getattr(s, f) for f in ClientSchedule._FIELDS), None),
    lambda _, children: ClientSchedule(*children))


class SamplingPolicy:
    """Decides which client tasks feed each round of a block AND what the
    round's heterogeneity schedule is (who shows up, how many local steps
    each client runs, how the server weights their results).

    Both hooks must consume ``rng`` deterministically (the prefetch
    pipeline replays them strictly in block order): the engine calls
    ``plan_schedule`` first, then ``sample_block`` with the resulting
    participation mask.

    ``schedule_kind`` is a STATIC descriptor baked into the block
    runner's cache key: "uniform" keeps the legacy unweighted scan body
    (bit-for-bit identical to the pre-schedule engine), anything else
    selects the schedule-aware body (weighted aggregation + per-client
    step masking). It must be decidable at policy-construction time — the
    runner compiles once per (strategy, beta, channel, schedule_kind).
    """

    schedule_kind = "scheduled"
    sampler = "reference"        # subclasses usually expose this as a field

    def plan_schedule(self, rng, start: int, end: int, clients: int,
                      budget: int) -> Dict[str, np.ndarray]:
        """Schedule rows for rounds [start, end): a dict of NumPy arrays
        ``participation`` (blk, clients) bool, ``local_steps`` (blk,
        clients) int32, and per-round-normalized ``weights`` (blk,
        clients) float32. ``budget`` is the strategy's full per-client
        workload (``FedStrategy.local_step_budget``). The default is the
        homogeneous fleet: everyone participates, full budget, uniform
        weights — and consumes NO rng."""
        blk = end - start
        return {
            "participation": np.ones((blk, clients), bool),
            "local_steps": np.full((blk, clients), budget, np.int32),
            "weights": np.full((blk, clients), 1.0 / clients, np.float32),
        }

    def plan_pool_schedule(self, rng, start: int, end: int, clients: int,
                           budget: int,
                           pool_size: int) -> Dict[str, np.ndarray]:
        """Pooled-run schedule: ``plan_schedule``'s rows plus a
        ``cohort`` array ((blk, clients) int32) naming WHICH of the
        ``pool_size`` persistent clients occupies each cohort slot that
        round (indices must be unique within a round — the engine
        scatters per-client state by them). The default seats a uniform
        without-replacement draw each round, then delegates the
        heterogeneity rows to ``plan_schedule`` — so every existing
        policy (uniform, partial participation, stragglers) composes
        with a pool unchanged. RNG order: cohort draws first, then the
        ``plan_schedule`` draws; deterministic, block-ordered (the
        prefetch-parity contract). Availability processes
        (repro.core.pool) override this wholesale: who is in the cohort
        IS the schedule there."""
        blk = end - start
        if pool_size < clients:
            raise ValueError(f"pool_size={pool_size} is smaller than the "
                             f"cohort ({clients} slots): persistent "
                             f"clients cannot repeat within a round")
        if not blk:
            cohort = np.zeros((0, clients), np.int64)
        elif self.sampler == "vectorized":
            cohort = seat_cohorts(rng, pool_size, clients, blk)
        else:
            cohort = np.stack([
                rng.choice(pool_size, size=clients, replace=False)
                for _ in range(blk)])
        plan = self.plan_schedule(rng, start, end, clients, budget)
        plan["cohort"] = cohort.astype(np.int32)
        return plan

    def sample_block(self, task_dist, rng, rounds: int, clients: int,
                     support: int, data_mode: str,
                     participation: Optional[np.ndarray] = None) -> Dict:
        """Default data path shared by every shipped policy: dispatch to
        the distribution's ``sampler`` flavour ("reference" replays the
        legacy per-task RNG order; "vectorized" is the one-allocation
        fast path), schedule-driven by the participation mask."""
        if self.sampler == "vectorized":
            return task_dist.sample_support_block(
                rng, rounds, clients, support, data_mode,
                participation=participation)
        return task_dist.sample_support_block_reference(
            rng, rounds, clients, support, data_mode,
            participation=participation)

    def state_dict(self) -> Dict:
        """JSON-able cross-block host state, captured into round-state
        checkpoints (repro.checkpoint) so a resumed run continues the
        policy exactly where the interrupted one stopped. Stateless
        policies — every shipped one except
        ``repro.core.pool.MarkovAvailability``, whose two-state chain
        lives outside the rng stream — return {}."""
        return {}

    def load_state_dict(self, state: Dict, rng=None) -> None:
        """Restore a ``state_dict`` snapshot at resume. ``rng`` is the
        run's (already-restored) host generator, for policies whose
        stashed state is keyed by the stream driving it."""

    def _validate_sampler(self):
        if self.sampler not in SAMPLERS:
            raise ValueError(f"unknown sampler {self.sampler!r}; "
                             f"expected one of {SAMPLERS}")


@dataclasses.dataclass(frozen=True)
class UniformSampling(SamplingPolicy):
    """Every round draws ``clients`` fresh tasks i.i.d. — the paper's
    serial (C=1) and batched schema. The trivial schedule (full
    participation, full budget, uniform weights) keeps the engine on its
    legacy fast path: schedule_kind == "uniform" selects the unweighted
    scan body, so runs are bit-for-bit identical to the pre-schedule
    engine.

    sampler="reference" replays the legacy per-task RNG order bit-for-bit
    (seeded parity with the pre-engine loops); "vectorized" uses the
    distribution's batched ``sample_support_block`` (block RNG order, one
    allocation — the fast host path).
    """
    sampler: str = "reference"

    schedule_kind = "uniform"

    def __post_init__(self):
        self._validate_sampler()


@dataclasses.dataclass(frozen=True)
class PartialParticipation(SamplingPolicy):
    """TinyMetaFed-style partial participation: each round only
    ``max(1, round(fraction * clients))`` cohort slots check in, train,
    and pay transport; the server averages over exactly the participants
    (weights 1/m on participants, 0 elsewhere).

    Scheduled-out slots draw NO task data from the host rng under the
    "reference" sampler (their batch slots stay zero) — the host-side
    sampling work scales with the fraction, which is where TinyMetaFed's
    savings come from. The "vectorized" sampler samples the full block in
    one allocation and zeroes the scheduled-out slots afterwards.
    """
    fraction: float = 0.5
    sampler: str = "reference"

    def __post_init__(self):
        if not 0.0 < self.fraction <= 1.0:
            raise ValueError(f"fraction must be in (0, 1], got "
                             f"{self.fraction!r}")
        self._validate_sampler()

    def cohort(self, clients: int) -> int:
        """Participants per round."""
        return max(1, int(round(self.fraction * clients)))

    def plan_schedule(self, rng, start, end, clients, budget):
        blk, m = end - start, self.cohort(clients)
        part = np.zeros((blk, clients), bool)
        for r in range(blk):                 # one small choice per round
            part[r, rng.choice(clients, size=m, replace=False)] = True
        return {
            "participation": part,
            "local_steps": np.where(part, budget, 0).astype(np.int32),
            "weights": (part.astype(np.float32) / m),
        }


@dataclasses.dataclass(frozen=True)
class StragglerSampling(SamplingPolicy):
    """Heterogeneous-device straggler simulation: every client shows up,
    but each draws an i.i.d. local step budget k_i uniformly from
    ``[ceil(min_steps_frac * budget), budget]`` (slow MCUs deliver fewer
    local steps by the server's deadline). Aggregation is
    arrival-weighted: w_i = k_i / sum_j k_j, so a client that completed
    twice the local work moves the server twice as far. Everyone still
    ships a full (fraction-scaled, if the channel is partial) payload.

    The per-step masking rides the engine's existing lax.cond/validity
    machinery (steps >= k_i are runtime no-ops inside the client scan),
    so blocks stay fixed-shape and the runner still traces exactly once.
    """
    min_steps_frac: float = 0.25
    sampler: str = "reference"

    def __post_init__(self):
        if not 0.0 < self.min_steps_frac <= 1.0:
            raise ValueError(f"min_steps_frac must be in (0, 1], got "
                             f"{self.min_steps_frac!r}")
        self._validate_sampler()

    def plan_schedule(self, rng, start, end, clients, budget):
        blk = end - start
        lo = max(1, int(np.ceil(self.min_steps_frac * budget)))
        steps = rng.integers(lo, budget + 1,
                             size=(blk, clients)).astype(np.int32)
        weights = steps / steps.sum(axis=1, keepdims=True)
        return {
            "participation": np.ones((blk, clients), bool),
            "local_steps": steps,
            "weights": weights.astype(np.float32),
        }
