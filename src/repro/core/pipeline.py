"""Host/device round pipeline: block planning, background prefetch, and
pluggable client-sampling policies for the federated round engine.

PR 1 moved the round math on-device (vmap x lax.scan); this module closes
the remaining host/device gap:

- ``plan_blocks``: split a run into scan blocks at eval boundaries and
  ``max_block``, and pick ONE fixed padded length for every block in the
  run — the retrace-free shape contract (the block runner compiles once
  per strategy/channel config; uneven eval/tail blocks are padded and
  masked instead of re-traced).
- ``BlockPrefetcher``: a background producer thread (the levanter
  background-data-loading pattern) that samples and stages block N+1
  while the device runs block N. Double-buffered at depth=2; the
  producer runs strictly in block order, so a seeded host RNG consumed
  inside ``produce`` sees exactly the synchronous draw order — pipelined
  and synchronous runs are bit-for-bit identical.
- ``SamplingPolicy`` / ``UniformSampling``: which client tasks feed each
  round is a policy object. Uniform i.i.d. sampling (the paper's schema)
  is the default; partial-participation / straggler policies plug in here
  without touching the engine.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Dict, Iterator, List, Tuple

import jax

SAMPLERS = ("reference", "vectorized")


def plan_blocks(rounds: int, eval_every: int,
                max_block: int) -> Tuple[List[Tuple[int, int]], int]:
    """Split ``rounds`` into scan blocks; return ``(blocks, pad)``.

    ``blocks`` is a list of ``(start, end)`` half-open round ranges that
    cover ``[0, rounds)``, cut at every eval boundary (multiples of
    ``eval_every``) and at most ``max_block`` rounds long. ``pad`` is the
    single fixed length every block is padded to on the host —
    ``min(max_block, stride, rounds)`` where ``stride`` is the eval
    cadence — so one run uses exactly one block shape regardless of
    ``rounds % eval_every`` or the tail.
    """
    if max_block <= 0:
        raise ValueError(f"max_block must be positive, got {max_block!r}")
    if rounds <= 0:
        return [], 0
    stride = eval_every if eval_every else rounds
    blocks: List[Tuple[int, int]] = []
    rnd = 0
    while rnd < rounds:
        eval_boundary = min(rounds, (rnd // stride + 1) * stride)
        end = min(eval_boundary, rnd + max_block)
        blocks.append((rnd, end))
        rnd = end
    pad = min(max_block, stride, rounds)
    assert all(end - start <= pad for start, end in blocks)
    return blocks, pad


class BlockPrefetcher:
    """Run ``produce(i)`` for ``i in range(n)`` on a daemon thread, keeping
    at most ``depth`` staged results ahead of the consumer.

    ``produce`` typically samples a block on the host and ``device_put``s
    it, so H2D staging of block N+1 hides behind device compute on block N
    (``depth=2`` = classic double buffering). Items are produced strictly
    in order. Producer exceptions are re-raised from :meth:`get`, which
    raises ``StopIteration`` once all ``n`` items were consumed (no
    deadlock on over-consumption); call :meth:`close` (idempotent) to
    stop early without deadlocking the bounded queue.
    """

    _DONE = object()

    def __init__(self, produce: Callable[[int], object], n: int,
                 depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._stop = threading.Event()
        self._done = False
        self._thread = threading.Thread(target=self._run, args=(produce, n),
                                        name="block-prefetch", daemon=True)
        self._thread.start()

    def _put(self, item) -> None:
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.1)
                return
            except queue.Full:
                continue

    def _run(self, produce, n) -> None:
        try:
            for i in range(n):
                if self._stop.is_set():
                    return
                self._put((None, produce(i)))
            self._put((None, self._DONE))
        except BaseException as exc:  # propagated to the consumer
            self._put((exc, None))

    def get(self):
        """Next staged item, blocking; re-raises producer exceptions and
        raises StopIteration once the stream is exhausted or closed."""
        if self._done:
            raise StopIteration("prefetcher exhausted")
        exc, item = self._q.get()
        if exc is not None:
            self._done = True
            self._stop.set()
            raise exc
        if item is self._DONE:
            self._done = True
            raise StopIteration("prefetcher exhausted")
        return item

    def close(self) -> None:
        """Stop the producer and drain the queue (safe to call twice)."""
        self._done = True
        self._stop.set()
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)


def single_device_of(tree):
    """The one device every jax leaf of ``tree`` lives on, or None (plain
    NumPy leaves, sharded/multi-device trees, empty trees). Prefetch
    producers must pin ``device_put`` to this explicitly —
    ``jax.default_device`` is thread-local and does not reach the
    background thread."""
    devices = {d for leaf in jax.tree.leaves(tree)
               if hasattr(leaf, "devices") for d in leaf.devices()}
    return devices.pop() if len(devices) == 1 else None


def prefetch_items(produce: Callable[[int], object], n: int,
                   depth: int = 2) -> Iterator[object]:
    """Yield ``produce(i)`` for ``i in range(n)``, staged up to ``depth``
    ahead by a :class:`BlockPrefetcher` thread. ``depth=0`` (or a single
    item) falls back to inline calls — same order, same numerics. The
    producer is shut down when the generator is exhausted or closed
    (``.close()`` / garbage collection), so early consumer exits don't
    leak the thread.
    """
    if depth <= 0 or n <= 1:
        for i in range(n):
            yield produce(i)
        return
    pf = BlockPrefetcher(produce, n, depth=depth)
    try:
        for _ in range(n):
            yield pf.get()
    finally:
        pf.close()


class SamplingPolicy:
    """Decides which client tasks feed each round of a block.

    ``sample_block`` must consume ``rng`` deterministically (the prefetch
    pipeline replays it in block order) and return NumPy arrays shaped
    ``{"x": (rounds, clients, support, ...), "y": ...}``.
    """

    def sample_block(self, task_dist, rng, rounds: int, clients: int,
                     support: int, data_mode: str) -> Dict:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class UniformSampling(SamplingPolicy):
    """Every round draws ``clients`` fresh tasks i.i.d. — the paper's
    serial (C=1) and batched schema.

    sampler="reference" replays the legacy per-task RNG order bit-for-bit
    (seeded parity with the pre-engine loops); "vectorized" uses the
    distribution's batched ``sample_support_block`` (block RNG order, one
    allocation — the fast host path).
    """
    sampler: str = "reference"

    def __post_init__(self):
        if self.sampler not in SAMPLERS:
            raise ValueError(f"unknown sampler {self.sampler!r}; "
                             f"expected one of {SAMPLERS}")

    def sample_block(self, task_dist, rng, rounds, clients, support,
                     data_mode):
        if self.sampler == "vectorized":
            return task_dist.sample_support_block(rng, rounds, clients,
                                                  support, data_mode)
        return task_dist.sample_support_block_reference(
            rng, rounds, clients, support, data_mode)
