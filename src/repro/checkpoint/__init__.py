from repro.checkpoint.ckpt import (AsyncCheckpointWriter,  # noqa: F401
                                   RoundState, latest_checkpoint,
                                   list_checkpoints, load_params,
                                   restore_checkpoint, restore_round_state,
                                   save_checkpoint, save_round_state,
                                   verify_checkpoint)
