"""Pytree checkpointing to .npz (offline container: no orbax).

Leaves are stored under their tree paths; restore validates structure
against a template pytree. Supports step-tagged files + a LATEST pointer,
atomic writes (tmp + rename) — enough substrate for real training loops.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Optional

import jax
import numpy as np

from repro.runtime.sharding import _path_str


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {(_path_str(path) or f"leaf{i}"): np.asarray(leaf)
            for i, (path, leaf) in enumerate(leaves)}


def save_checkpoint(directory: str, tree: Any, step: int,
                    extra: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, __step__=step,
                 __extra__=json.dumps(extra or {}), **flat)
    os.replace(tmp, path)
    with open(os.path.join(directory, "LATEST"), "w") as f:
        f.write(os.path.basename(path))
    return path


def latest_checkpoint(directory: str) -> Optional[str]:
    marker = os.path.join(directory, "LATEST")
    if not os.path.exists(marker):
        return None
    with open(marker) as f:
        return os.path.join(directory, f.read().strip())


def restore_checkpoint(directory_or_file: str, template: Any):
    """Returns (tree, step, extra). Template provides structure/dtypes."""
    path = directory_or_file
    if os.path.isdir(path):
        path = latest_checkpoint(path)
        if path is None:
            raise FileNotFoundError(f"no checkpoint in {directory_or_file}")
    data = np.load(path, allow_pickle=False)
    leaves_with_path = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    out = []
    for i, (p, leaf) in enumerate(leaves_with_path):
        key = _path_str(p) or f"leaf{i}"
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        if arr.shape != np.shape(leaf):
            raise ValueError(f"{key}: shape {arr.shape} != {np.shape(leaf)}")
        out.append(arr.astype(np.asarray(leaf).dtype))
    step = int(data["__step__"])
    extra = json.loads(str(data["__extra__"]))
    return jax.tree_util.tree_unflatten(treedef, out), step, extra
