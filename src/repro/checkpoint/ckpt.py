"""Pytree checkpointing to .npz (offline container: no orbax).

Leaves are stored under their tree paths; restore validates structure,
shapes, AND dtypes against a template pytree (pass ``cast=True`` to
opt back into casting — an fp32 file silently cast into an int8
template would corrupt a quantized grid). Files are step-tagged
(``ckpt_<step>.npz``) next to a per-file checksum manifest
(``ckpt_<step>.json``) and a LATEST pointer; every write — payload,
manifest, pointer — is atomic (tmp + ``os.replace``). Restoring from a
directory walks snapshots newest-first and SKIPS torn or corrupted
files (checksum mismatch, truncated zip) with a warning, so a crash
mid-write degrades to the newest valid snapshot instead of killing the
resume.

On top of that substrate sits the round-state layer used by
``repro.core.engine.run_federated`` for preemption-safe runs:

- :class:`RoundState` — the complete federated scan carry at a block
  boundary (phi, PoolState with its FedBuff slabs, transport bills,
  eval history, and the host-side RNG/policy state that makes resume
  bit-for-bit);
- :func:`save_round_state` / :func:`restore_round_state` — its
  (de)serialization through the generic checkpoint format;
- :class:`AsyncCheckpointWriter` — a background thread that performs
  the device->host transfer and the file writes off the training
  thread, behind a bounded queue, with retention of the last K
  snapshots.
"""
from __future__ import annotations

import dataclasses
import io
import json
import logging
import os
import queue
import re
import threading
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.runtime.sharding import _path_str

logger = logging.getLogger(__name__)

_CKPT_RE = re.compile(r"^ckpt_(\d{8})\.npz$")

#: test-only fault-injection hook (see repro.testing.faults): called as
#: hook(step) after a snapshot is fully durable (payload + manifest +
#: LATEST on disk). None in production.
_post_save_hook: Optional[Callable[[int], None]] = None


def _flatten(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {(_path_str(path) or f"leaf{i}"): np.asarray(leaf)
            for i, (path, leaf) in enumerate(leaves)}


def _jsonable(obj):
    """Recursively coerce NumPy scalars/arrays so ``extra`` dicts (eval
    history rows, RNG bit-generator states) survive json.dumps."""
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    return obj


def _crc32(path: str, chunk: int = 1 << 20) -> int:
    crc = 0
    with open(path, "rb") as f:
        while True:
            block = f.read(chunk)
            if not block:
                break
            crc = zlib.crc32(block, crc)
    return crc & 0xFFFFFFFF


def _atomic_write_bytes(path: str, data: bytes) -> None:
    # fixed per-process tmp name instead of mkstemp: the writer is
    # single-threaded per process and atomicity comes from os.replace,
    # so the mkstemp open/close round-trip is pure hot-path overhead
    tmp = f"{path}.tmp{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def _atomic_write_text(path: str, text: str) -> None:
    _atomic_write_bytes(path, text.encode())


def manifest_path(payload_path: str) -> str:
    """The checksum manifest sitting next to ``ckpt_<step>.npz``."""
    root, _ = os.path.splitext(payload_path)
    return root + ".json"


def list_checkpoints(directory: str) -> List[str]:
    """All ``ckpt_*.npz`` payload paths in ``directory``, oldest first."""
    try:
        names = os.listdir(directory)
    except OSError:
        return []
    found = [(int(m.group(1)), os.path.join(directory, name))
             for name in names for m in [_CKPT_RE.match(name)] if m]
    return [p for _, p in sorted(found)]


def _apply_retention(directory: str, keep: int) -> None:
    if keep < 1:
        return
    for path in list_checkpoints(directory)[:-keep]:
        for victim in (path, manifest_path(path)):
            try:
                os.remove(victim)
            except OSError:
                pass


def save_checkpoint(directory: str, tree: Any, step: int,
                    extra: Optional[dict] = None,
                    keep: Optional[int] = None) -> str:
    """Write ``ckpt_<step>.npz`` + its checksum manifest, update LATEST,
    and (with ``keep``) prune all but the newest ``keep`` snapshots.
    Every file lands via tmp + ``os.replace``, so readers never observe
    a half-written payload under its final name."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    # serialize in memory: one write syscall per file and the checksum
    # comes from the buffer, not a re-read of what was just written —
    # keeps the per-snapshot GIL-held time off the engine's hot path
    buf = io.BytesIO()
    np.savez(buf, __step__=step,
             __extra__=json.dumps(_jsonable(extra or {})), **flat)
    payload = buf.getvalue()
    _atomic_write_bytes(path, payload)
    _atomic_write_text(manifest_path(path), json.dumps({
        "file": os.path.basename(path), "step": int(step),
        "size": len(payload),
        "crc32": zlib.crc32(payload) & 0xFFFFFFFF}))
    _atomic_write_text(os.path.join(directory, "LATEST"),
                       os.path.basename(path))
    if keep is not None:
        _apply_retention(directory, keep)
    hook = _post_save_hook
    if hook is not None:
        hook(int(step))
    return path


def verify_checkpoint(path: str) -> bool:
    """True iff ``path`` exists and matches its manifest (size + crc32).
    A payload without a manifest (legacy or foreign file) passes — a
    torn zip there is still caught at load time."""
    if not os.path.exists(path):
        return False
    man = manifest_path(path)
    if not os.path.exists(man):
        return True
    try:
        with open(man) as f:
            meta = json.load(f)
    except (OSError, ValueError):
        return False
    if meta.get("size") != os.path.getsize(path):
        return False
    return meta.get("crc32") == _crc32(path)


def latest_checkpoint(directory: str) -> Optional[str]:
    """Newest checkpoint payload path in ``directory``, or None.

    Trusts the LATEST pointer only when it names an existing
    ``ckpt_*.npz``; a stale or missing pointer falls back to scanning
    the directory (with a warning), so a crash between the payload
    write and the pointer update never strands the run."""
    marker = os.path.join(directory, "LATEST")
    if os.path.exists(marker):
        with open(marker) as f:
            name = f.read().strip()
        cand = os.path.join(directory, name)
        if name and _CKPT_RE.match(name) and os.path.exists(cand):
            return cand
        logger.warning(
            "checkpoint LATEST pointer in %s is stale (%r); falling back "
            "to a directory scan", directory, name)
    paths = list_checkpoints(directory)
    return paths[-1] if paths else None


def _read_npz(path: str) -> Dict[str, np.ndarray]:
    """Load and fully materialize every member — member reads hit the
    zip CRCs, so truncation/corruption raises here, not mid-restore."""
    with np.load(path, allow_pickle=False) as data:
        return {k: data[k] for k in data.files}


def _restore_from_data(data, template, cast: bool):
    leaves_with_path = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    out = []
    for i, (p, leaf) in enumerate(leaves_with_path):
        key = _path_str(p) or f"leaf{i}"
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        if arr.shape != np.shape(leaf):
            raise ValueError(f"{key}: shape {arr.shape} != {np.shape(leaf)}")
        want = np.asarray(leaf).dtype
        if arr.dtype != want:
            if not cast:
                raise TypeError(
                    f"{key}: checkpoint dtype {arr.dtype} != template "
                    f"{want}; refusing to cast silently (a float file "
                    f"restored into a quantized template would corrupt "
                    f"it) — pass cast=True to opt in")
            arr = arr.astype(want)
        out.append(arr)
    step = int(data["__step__"])
    extra = json.loads(str(data["__extra__"]))
    return jax.tree_util.tree_unflatten(treedef, out), step, extra


def restore_checkpoint(directory_or_file: str, template: Any,
                       cast: bool = False):
    """Returns (tree, step, extra). Template provides structure, shapes,
    and dtypes; a dtype mismatch RAISES unless ``cast=True``.

    Given a directory, snapshots are tried newest-first and torn or
    corrupted files (checksum-manifest mismatch, unreadable zip) are
    skipped with a warning — graceful fallback to the newest valid
    snapshot. Structural mismatches against the template (missing leaf,
    wrong shape/dtype) are NOT swallowed: they indicate a config
    mismatch, not a bad file."""
    path = directory_or_file
    if not os.path.isdir(path):
        if not verify_checkpoint(path):
            raise ValueError(f"checkpoint {path} fails its checksum "
                             f"manifest (torn or corrupted write)")
        return _restore_from_data(_read_npz(path), template, cast)

    candidates = list(reversed(list_checkpoints(path)))
    pointed = latest_checkpoint(path)
    if pointed in candidates:
        candidates.remove(pointed)
        candidates.insert(0, pointed)
    if not candidates:
        raise FileNotFoundError(f"no checkpoint in {directory_or_file}")
    for cand in candidates:
        if not verify_checkpoint(cand):
            logger.warning(
                "checkpoint %s fails its checksum manifest (torn or "
                "corrupted write); falling back to the next snapshot",
                cand)
            continue
        try:
            data = _read_npz(cand)
        except Exception as exc:
            logger.warning(
                "checkpoint %s is unreadable (%s); falling back to the "
                "next snapshot", cand, exc)
            continue
        return _restore_from_data(data, template, cast)
    raise ValueError(
        f"every checkpoint in {directory_or_file} is torn or corrupted "
        f"({len(candidates)} candidates tried)")


# ---------------------------------------------------------------------------
# Round-state layer: the federated engine's full scan carry.

@dataclasses.dataclass
class RoundState:
    """The complete ``run_federated`` carry at a block boundary.

    round:            completed rounds — the block cursor; resume
                      replans blocks from here
                      (``plan_blocks(..., start=round)``).
    phi:              server params pytree (device or host arrays).
    pool_state:       ``repro.core.pool.PoolState`` (incl. int8 FedBuff
                      buffer slabs and flush counters) or None.
    per_client_bytes: (N,) int64 per-client transport bills.
    comm_bytes:       total transport billed so far.
    history:          eval rows appended so far (JSON-able dicts).
    host:             host-side state that makes resume bit-for-bit:
                      ``{"rng": <bit_generator state>,
                      "pool": ClientPool.host_state(),
                      "sampling": SamplingPolicy.state_dict()}`` —
                      captured on the prefetch producer right after the
                      block's draws, so the stream continues exactly
                      where the uninterrupted run would.
    fingerprint:      config identity (seed, cohort, pool size, shards,
                      strategy name, ...) checked at resume.
    """
    round: int
    phi: Any
    pool_state: Any = None
    per_client_bytes: Any = None
    comm_bytes: int = 0
    history: list = dataclasses.field(default_factory=list)
    host: dict = dataclasses.field(default_factory=dict)
    fingerprint: dict = dataclasses.field(default_factory=dict)


def round_state_payload(state: RoundState) -> Tuple[dict, int, dict]:
    """(tree, step, extra) for the generic checkpoint format — the
    arrays ride the npz, everything host-side rides the extra JSON."""
    tree = {"phi": state.phi}
    if state.pool_state is not None:
        tree["pool"] = state.pool_state
    if state.per_client_bytes is not None:
        tree["bills"] = np.asarray(state.per_client_bytes)
    extra = {"comm_bytes": int(state.comm_bytes),
             "history": state.history, "host": state.host,
             "fingerprint": state.fingerprint}
    return tree, int(state.round), extra


def save_round_state(directory: str, state: RoundState,
                     keep: Optional[int] = None) -> str:
    tree, step, extra = round_state_payload(state)
    return save_checkpoint(directory, jax.device_get(tree), step,
                           extra=extra, keep=keep)


def restore_round_state(directory: str, *, phi, pool_state=None,
                        per_client_bytes=None,
                        cast: bool = False) -> RoundState:
    """Restore the newest valid :class:`RoundState`; the keyword
    templates fix shapes/dtypes (mesh-sharded templates are fine — only
    their shapes are read). Raises FileNotFoundError when the directory
    holds no snapshot at all."""
    template = {"phi": phi}
    if pool_state is not None:
        template["pool"] = pool_state
    if per_client_bytes is not None:
        template["bills"] = np.asarray(per_client_bytes)
    tree, step, extra = restore_checkpoint(directory, template, cast=cast)
    return RoundState(
        round=step, phi=tree["phi"], pool_state=tree.get("pool"),
        per_client_bytes=tree.get("bills"),
        comm_bytes=int(extra.get("comm_bytes", 0)),
        history=list(extra.get("history", [])),
        host=dict(extra.get("host", {})),
        fingerprint=dict(extra.get("fingerprint", {})))


def load_params(directory_or_file: str, template, *,
                cast: bool = False):
    """Load JUST the phi/params tree for serving — the
    `serving.AdaptationServer` side of a training checkpoint.

    Accepts either a ``run_federated(ckpt_dir=...)`` round-state
    directory/file (the phi sub-tree is extracted, pool state and bills
    ignored) or a plain ``save_checkpoint`` snapshot whose tree IS the
    params. ``template`` fixes structure/shapes/dtypes as in
    :func:`restore_checkpoint`. Returns the params pytree (host numpy
    leaves; pass straight to ``AdaptationServer``)."""
    try:
        tree, _, _ = restore_checkpoint(directory_or_file,
                                        {"phi": template}, cast=cast)
        return tree["phi"]
    except KeyError:
        tree, _, _ = restore_checkpoint(directory_or_file, template,
                                        cast=cast)
        return tree


class AsyncCheckpointWriter:
    """Background-thread snapshot writer: ``submit`` enqueues a
    (device-resident) pytree and returns immediately; the writer thread
    performs the device->host transfer (``jax.device_get``) and the
    atomic ``save_checkpoint`` off the training thread. The queue is
    BOUNDED (``depth``): when the writer falls that many snapshots
    behind, ``submit`` blocks — backpressure instead of unbounded host
    memory. Writer-side exceptions surface on the caller thread at the
    next ``submit``/``wait``/``close``.

    The engine hands this thread block-boundary COPIES
    (``jax.tree.map(jnp.copy, ...)``) — the live carry is donated to
    the next block, so the writer must never hold the original buffers.
    """

    _DONE = object()

    def __init__(self, directory: str, keep: Optional[int] = 3,
                 depth: int = 2):
        self.directory = directory
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=max(1, depth))
        self._error: Optional[BaseException] = None
        self._closed = False
        self._thread = threading.Thread(target=self._run,
                                        name="ckpt-writer", daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is self._DONE:
                self._q.task_done()
                return
            tree, step, extra = item
            try:
                host = jax.device_get(tree)
                save_checkpoint(self.directory, host, step, extra=extra,
                                keep=self.keep)
            except BaseException as exc:
                self._error = exc
            self._q.task_done()

    def _check(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def submit(self, tree, step: int, extra: Optional[dict] = None) -> None:
        if self._closed:
            raise RuntimeError("AsyncCheckpointWriter is closed")
        self._check()
        self._q.put((tree, int(step), extra))

    def submit_state(self, state: RoundState) -> None:
        tree, step, extra = round_state_payload(state)
        self.submit(tree, step, extra)

    def wait(self) -> None:
        """Block until every submitted snapshot is durable; re-raise
        any writer error."""
        self._q.join()
        self._check()

    def close(self, raise_errors: bool = True) -> None:
        """Drain the queue, stop the thread (idempotent); with
        ``raise_errors`` re-raise any pending writer exception."""
        if not self._closed:
            self._closed = True
            if self._thread.is_alive():
                self._q.put(self._DONE)
            self._thread.join(timeout=120.0)
        if raise_errors:
            self._check()
