"""Chunked SSD (Mamba2 state-space duality) scan kernel.

The SSM compute hot-spot: per (batch, head) the sequence is processed in
chunks; within a chunk the quadratic 'attention-like' term runs on the
MXU, and the inter-chunk state recurrence is carried in VMEM scratch
across the sequential chunk grid dimension — the HBM traffic is one pass
over x/B/C/dt plus one (P, N) state resident in VMEM, never the (S, S)
semiseparable matrix.

Grid: (B, H, nc) with the chunk dim innermost (sequential on TPU).
Per step the kernel owns:
  xd    (Q, P)   dt-scaled inputs for this chunk
  dA    (Q,)     dt * A log-decay increments   (passed as (Q, 1))
  Bm,Cm (Q, N)   input/output maps (ngroups=1: shared across H)
  state (P, N)   VMEM scratch carried across chunks
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.meta_update import pltpu_interpret


def _ssd_kernel(xd_ref, dA_ref, B_ref, C_ref, y_ref, state_ref, *, chunk):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xd = xd_ref[0, 0, 0].astype(jnp.float32)        # (Q, P)
    dA = dA_ref[0, 0, 0, :, 0].astype(jnp.float32)  # (Q,)
    Bm = B_ref[0, 0].astype(jnp.float32)            # (Q, N)
    Cm = C_ref[0, 0].astype(jnp.float32)            # (Q, N)

    dA_cs = jnp.cumsum(dA)                       # (Q,)
    # intra-chunk: L[i,j] = exp(dA_cs[i]-dA_cs[j]) for i>=j
    diff = dA_cs[:, None] - dA_cs[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.exp(jnp.where(mask, diff, -1e30))  # mask inside exp (grad-safe)
    CB = Cm @ Bm.T                               # (Q, Q)
    y = (CB * L) @ xd                            # (Q, P)

    # contribution of the carried state
    state = state_ref[...]                       # (P, N)
    y += jnp.exp(dA_cs)[:, None] * (Cm @ state.T)

    # update state: decay full chunk + inject this chunk
    decay_out = jnp.exp(dA_cs[-1] - dA_cs)       # (Q,)
    state_ref[...] = (state * jnp.exp(dA_cs[-1])
                      + xd.T @ (Bm * decay_out[:, None]))
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


def ssd_scan(xd, dA, Bm, Cm, *, interpret=None) -> jax.Array:
    """xd: (B, H, nc, Q, P); dA: (B, H, nc, Q); Bm/Cm: (B, nc, Q, N).

    Returns y: (B, H, nc, Q, P) float32 (matches kernels.ref.ssd_scan).
    """
    B, H, nc, Q, P = xd.shape
    N = Bm.shape[-1]
    kernel = functools.partial(_ssd_kernel, chunk=Q)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, 1),
                         lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Q, P),
                               lambda b, h, c: (b, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nc, Q, P), jnp.float32),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=pltpu_interpret() if interpret is None else interpret,
    )(xd, dA[..., None], Bm, Cm)
