"""Flash-decode kernel: single-token GQA attention against a long KV
cache with online softmax over KV blocks (optionally sliding-window).

This is the serving hot-spot for decode_32k / long_500k. The kernel
streams the cache HBM->VMEM block by block; running max / denominator /
accumulator live in VMEM scratch across the sequential KV grid dim, so
the S x H score matrix never materializes.

Grid: (B, Kv, S // BLOCK_S) — the KV dim is innermost (sequential on
TPU; scratch carries across it). Per step the kernel owns:
  q     (R, hd)        one kv-group's query heads
  k/v   (BLOCK_S, hd)  one cache block
  out   (R, hd)        written at the last block
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.meta_update import pltpu_interpret

DEFAULT_BLOCK_S = 512
NEG_INF = -1e30


def _flash_decode_kernel(len_ref, q_ref, k_ref, v_ref, out_ref,
                         m_ref, l_ref, acc_ref, *, block_s, window,
                         num_blocks):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cache_len = len_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)           # (R, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)     # (block_s, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    hd = q.shape[-1]
    s = (q * hd ** -0.5) @ k.T                    # (R, block_s)

    pos = j * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = pos < cache_len
    if window:
        valid &= pos >= cache_len - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                           # (R, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + p @ v
    m_ref[...] = m_new

    @pl.when(j == num_blocks - 1)
    def _finish():
        out_ref[0, 0] = (acc_ref[...] /
                         jnp.maximum(l_ref[...], 1e-30)).astype(out_ref.dtype)


def flash_decode(q, k_cache, v_cache, cache_len, *, window: int = 0,
                 block_s: int = DEFAULT_BLOCK_S) -> jax.Array:
    """q: (B, H, hd); k_cache/v_cache: (B, S, Kv, hd); cache_len: scalar.

    Returns (B, H, hd) in q.dtype. H = Kv * R.
    """
    B, H, hd = q.shape
    S, Kv = k_cache.shape[1], k_cache.shape[2]
    R = H // Kv
    block_s = min(block_s, S)
    assert S % block_s == 0, (S, block_s)
    num_blocks = S // block_s
    qg = q.reshape(B, Kv, R, hd)

    kernel = functools.partial(_flash_decode_kernel, block_s=block_s,
                               window=window, num_blocks=num_blocks)
    out = pl.pallas_call(
        kernel,
        grid=(B, Kv, num_blocks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, R, hd), lambda b, k, j: (b, k, 0, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda b, k, j: (b, j, k, 0)),
            pl.BlockSpec((1, block_s, 1, hd), lambda b, k, j: (b, j, k, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, R, hd), lambda b, k, j: (b, k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Kv, R, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, 1), jnp.float32),
            pltpu.VMEM((R, hd), jnp.float32),
        ],
        interpret=pltpu_interpret(),
    )(jnp.asarray([cache_len], jnp.int32), qg, k_cache, v_cache)
    return out.reshape(B, H, hd)
