"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def meta_update(w, w_hat, alpha):
    """Reptile interpolation: w + alpha * (w_hat - w), fp32 math."""
    w32 = w.astype(jnp.float32)
    return (w32 + alpha * (w_hat.astype(jnp.float32) - w32)).astype(w.dtype)


def online_sgd(p, g, lr, m=None, momentum=0.0):
    """Streaming SGD step; optional momentum (fp32 state)."""
    if m is None:
        p32 = p.astype(jnp.float32)
        return (p32 - lr * g.astype(jnp.float32)).astype(p.dtype)
    m_new = momentum * m + g.astype(jnp.float32)
    p_new = (p.astype(jnp.float32) - lr * m_new).astype(p.dtype)
    return p_new, m_new


def flash_decode(q, k_cache, v_cache, cache_len, *, window=0):
    """Decode attention oracle. q: (B, H, hd); caches: (B, S, Kv, hd);
    cache_len: scalar int. Returns (B, H, hd) fp32."""
    B, H, hd = q.shape
    S, Kv = k_cache.shape[1], k_cache.shape[2]
    R = H // Kv
    qg = q.reshape(B, Kv, R, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bkrh,bskh->bkrs", qg, k_cache.astype(jnp.float32))
    pos = jnp.arange(S)
    valid = pos < cache_len
    if window:
        valid &= pos >= cache_len - window
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkrs,bskh->bkrh", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, hd)


def ssd_scan(xd, dA, Bm, Cm):
    """Chunked SSD oracle (matches kernels/ssd_scan.py layout).

    xd: (B, H, nc, Q, P)  — dt-scaled inputs
    dA: (B, H, nc, Q)     — dt * A (negative decay log-increments)
    Bm: (B, nc, Q, N), Cm: (B, nc, Q, N) — shared across heads (ngroups=1)
    Returns y: (B, H, nc, Q, P) fp32.
    """
    B, H, nc, Q, P = xd.shape
    N = Bm.shape[-1]
    xd = xd.astype(jnp.float32)
    dA = dA.astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)
    dA_cs = jnp.cumsum(dA, axis=-1)                       # (B,H,nc,Q)
    # intra-chunk
    diff = dA_cs[..., :, None] - dA_cs[..., None, :]      # (B,H,nc,Q,Q)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.exp(jnp.where(mask, diff, -1e30))  # mask inside exp (grad-safe)
    CB = jnp.einsum("bcin,bcjn->bcij", Cm, Bm)            # (B,nc,Q,Q)
    y_diag = jnp.einsum("bhcij,bcij,bhcjp->bhcip", L, CB, xd)
    # chunk states
    decay_out = jnp.exp(dA_cs[..., -1:] - dA_cs)          # (B,H,nc,Q)
    states = jnp.einsum("bcln,bhcl,bhclp->bhcpn", Bm, decay_out, xd)
    chunk_decay = jnp.exp(dA_cs[..., -1])                 # (B,H,nc)

    def step(state, inp):
        st, dec = inp
        return state * dec[..., None, None] + st, state

    init = jnp.zeros((B, H, P, N), jnp.float32)
    _, prev = jax.lax.scan(
        step, init, (states.transpose(2, 0, 1, 3, 4),
                     chunk_decay.transpose(2, 0, 1)))
    prev = prev.transpose(1, 2, 0, 3, 4)                  # (B,H,nc,P,N)
    y_off = jnp.einsum("bcln,bhcpn,bhcl->bhclp", Cm, prev, jnp.exp(dA_cs))
    return y_diag + y_off
