"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def meta_update(w, w_hat, alpha):
    """Reptile interpolation: w + alpha * (w_hat - w), fp32 math."""
    w32 = w.astype(jnp.float32)
    return (w32 + alpha * (w_hat.astype(jnp.float32) - w32)).astype(w.dtype)


def online_sgd(p, g, lr, m=None, momentum=0.0):
    """Streaming SGD step; optional momentum (fp32 state)."""
    if m is None:
        p32 = p.astype(jnp.float32)
        return (p32 - lr * g.astype(jnp.float32)).astype(p.dtype)
    m_new = momentum * m + g.astype(jnp.float32)
    p_new = (p.astype(jnp.float32) - lr * m_new).astype(p.dtype)
    return p_new, m_new


def flash_decode(q, k_cache, v_cache, cache_len, *, window=0):
    """Decode attention oracle. q: (B, H, hd); caches: (B, S, Kv, hd);
    cache_len: scalar int. Returns (B, H, hd) fp32."""
    B, H, hd = q.shape
    S, Kv = k_cache.shape[1], k_cache.shape[2]
    R = H // Kv
    qg = q.reshape(B, Kv, R, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bkrh,bskh->bkrs", qg, k_cache.astype(jnp.float32))
    pos = jnp.arange(S)
    valid = pos < cache_len
    if window:
        valid &= pos >= cache_len - window
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkrs,bskh->bkrh", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, hd)


# ---------------------------------------------------------------------------
# TIFeD integer DFA (oracle for kernels/online_sgd_int8.py)
# ---------------------------------------------------------------------------
#
# The reference carries every integer quantity in fp32 arrays holding
# EXACT integer values: all intermediates stay below 2^24 (activations
# <= 127, int8 x int8 dot over S <= 512 samples peaks around 8.3e6), so
# fp32 arithmetic on them is bit-exact against the kernel's native
# int8/int32 arithmetic. That makes the parity tests exact-equality,
# not allclose.

INT8_MAX = 127.0
BIAS_MAX = 2.0 ** 23          # biases live at accumulator scale, int32-safe
DFA_SHIFT = 7                 # feedback projections are scaled by 2^-7
_DN = (((0,), (0,)), ((), ()))   # contract the sample axis; vmap batches


def pow2_exponent(maxabs, limit=INT8_MAX):
    """Smallest power-of-two exponent e with maxabs * 2^-e <= limit.

    The ceil/log2 form can land one short of the true ceiling when
    maxabs/limit sits exactly on a power of two boundary in fp32, so a
    single correction step nudges it up; the floor of -24 keeps
    all-zero tensors on a sane grid."""
    e = jnp.ceil(jnp.log2(jnp.maximum(maxabs, 1e-30) / limit))
    e = jnp.where(maxabs * jnp.exp2(-e) > limit, e + 1, e)
    return jnp.maximum(e, -24).astype(jnp.int32)


def quantize_pow2(w, limit=INT8_MAX):
    """Per-tensor power-of-two symmetric quantization.

    Returns (q, e): the int-valued fp32 code array in [-limit, limit]
    and the int32 exponent with w ~= q * 2^e."""
    e = pow2_exponent(jnp.max(jnp.abs(w)), limit)
    q = jnp.clip(jnp.round(w * jnp.exp2(-e.astype(jnp.float32))),
                 -limit, limit)
    return q, e


def stochastic_round(v, dither):
    """Unbiased stochastic rounding: floor(v + u) with u ~ U[0, 1).

    The dither plane is supplied by the caller (baked trace constants in
    the tifed strategy) so the operation itself is deterministic."""
    return jnp.floor(v + dither)


def dfa_int8_epoch(ws, bs, xq, yal, layer, fb, dither, scales):
    """One TIFeD epoch: int8 forward + single-layer DFA update.

    The layer-cyclic single-layer variant of TIFeD: each epoch runs the
    full integer forward pass but updates only ``layer`` (0, 1, or 2),
    selected at runtime by lax.switch so the scan over epochs stays one
    trace. Direct feedback alignment replaces the backprop transposes
    with fixed random matrices ``fb``; weight requantization uses
    stochastic rounding driven by ``dither``.

    All arrays are fp32 carrying exact integers (see module comment):

      ws:     (w0 (din,H1), w1 (H1,H2), w2 (H2,dout)) int8-valued
      bs:     (b0, b1, b2) int32-valued, at accumulator scale
      xq:     (S, din) int8-valued quantized inputs
      yal:    (S, dout) targets pre-scaled to the output accumulator grid
      layer:  int32 scalar in {0, 1, 2} — which layer trains this epoch
      fb:     (fb1 (dout,H1), fb2 (dout,H2)) int8-valued feedback
      dither: (d0 (din,H1), d1 (H1,H2), d2 (H2,dout)) U[0,1) fp32
      scales: dict of fp32 power-of-two multipliers —
              f0/f1 (activation requant), fe (error quant),
              floss (loss rescale incl. the 1/S mean),
              ftw/ftb (3-tuples: weight/bias learning-rate requant)

    Returns ((w0', w1', w2'), (b0', b1', b2'), loss)."""
    w0, w1, w2 = ws
    b0, b1, b2 = bs
    fb1, fb2 = fb
    d0_, d1_, d2_ = dither

    z0 = (xq * w0 if w0.shape[0] == 1 else xq @ w0) + b0
    a1 = jnp.clip(jnp.round(jnp.maximum(z0, 0.0) * scales["f0"]),
                  0.0, INT8_MAX)
    z1 = a1 @ w1 + b1
    a2 = jnp.clip(jnp.round(jnp.maximum(z1, 0.0) * scales["f1"]),
                  0.0, INT8_MAX)
    z2 = a2 @ w2 + b2
    err = z2 - yal
    eq = jnp.clip(jnp.round(err * scales["fe"]), -INT8_MAX, INT8_MAX)
    loss = jnp.sum(jnp.square(err)) * scales["floss"]
    ftw, ftb = scales["ftw"], scales["ftb"]

    def proj(fbm):
        # error fed straight back to the hidden layer; dout==1 is a
        # broadcast, larger heads contract the output axis
        return eq * fbm if fbm.shape[0] == 1 else eq @ fbm

    def hidden_update(i, z, a_in, fbm, dith, c):
        d = jnp.round(jnp.where(z > 0, proj(fbm), 0.0) * 2.0 ** -DFA_SHIFT)
        g = ((a_in * d).sum(0, keepdims=True) if a_in.shape[1] == 1
             else jax.lax.dot_general(a_in, d, _DN))
        w = jnp.clip(c[i] - stochastic_round(g * ftw[i], dith),
                     -INT8_MAX, INT8_MAX)
        b = jnp.clip(c[3 + i] - jnp.round(d.sum(0) * ftb[i]),
                     -BIAS_MAX, BIAS_MAX)
        return tuple(w if j == i else b if j == 3 + i else c[j]
                     for j in range(6))

    def u0(c):
        return hidden_update(0, z0, xq, fb1, d0_, c)

    def u1(c):
        return hidden_update(1, z1, a1, fb2, d1_, c)

    def u2(c):
        g = jax.lax.dot_general(a2, eq, _DN)
        w = jnp.clip(c[2] - stochastic_round(g * ftw[2], d2_),
                     -INT8_MAX, INT8_MAX)
        b = jnp.clip(c[5] - jnp.round(eq.sum(0) * ftb[2]),
                     -BIAS_MAX, BIAS_MAX)
        return (c[0], c[1], w, c[3], c[4], b)

    c = jax.lax.switch(layer, (u0, u1, u2), (w0, w1, w2, b0, b1, b2))
    return (c[0], c[1], c[2]), (c[3], c[4], c[5]), loss


def ssd_scan(xd, dA, Bm, Cm):
    """Chunked SSD oracle (matches kernels/ssd_scan.py layout).

    xd: (B, H, nc, Q, P)  — dt-scaled inputs
    dA: (B, H, nc, Q)     — dt * A (negative decay log-increments)
    Bm: (B, nc, Q, N), Cm: (B, nc, Q, N) — shared across heads (ngroups=1)
    Returns y: (B, H, nc, Q, P) fp32.
    """
    B, H, nc, Q, P = xd.shape
    N = Bm.shape[-1]
    xd = xd.astype(jnp.float32)
    dA = dA.astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)
    dA_cs = jnp.cumsum(dA, axis=-1)                       # (B,H,nc,Q)
    # intra-chunk
    diff = dA_cs[..., :, None] - dA_cs[..., None, :]      # (B,H,nc,Q,Q)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.exp(jnp.where(mask, diff, -1e30))  # mask inside exp (grad-safe)
    CB = jnp.einsum("bcin,bcjn->bcij", Cm, Bm)            # (B,nc,Q,Q)
    y_diag = jnp.einsum("bhcij,bcij,bhcjp->bhcip", L, CB, xd)
    # chunk states
    decay_out = jnp.exp(dA_cs[..., -1:] - dA_cs)          # (B,H,nc,Q)
    states = jnp.einsum("bcln,bhcl,bhclp->bhcpn", Bm, decay_out, xd)
    chunk_decay = jnp.exp(dA_cs[..., -1])                 # (B,H,nc)

    def step(state, inp):
        st, dec = inp
        return state * dec[..., None, None] + st, state

    init = jnp.zeros((B, H, P, N), jnp.float32)
    _, prev = jax.lax.scan(
        step, init, (states.transpose(2, 0, 1, 3, 4),
                     chunk_decay.transpose(2, 0, 1)))
    prev = prev.transpose(1, 2, 0, 3, 4)                  # (B,H,nc,P,N)
    y_off = jnp.einsum("bcln,bhcpn,bhcl->bhclp", Cm, prev, jnp.exp(dA_cs))
    return y_diag + y_off
