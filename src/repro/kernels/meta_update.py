"""Fused Reptile interpolation kernel: w <- w + alpha (w_hat - w).

The paper's server update (Algorithm 1, line 12) applied to multi-GB
parameter tensors. XLA's default emits (read w, read w_hat, subtract,
scale, add, write) with fp32 temporaries; the fused kernel is a single
HBM pass per operand at bf16 width with fp32 math in VREGs — the update
becomes purely HBM-bandwidth-bound at its floor.

Tiling: params are flattened and padded to (rows, LANE) with LANE=1024
(8 x 128 VREG-aligned); each grid step owns an (8, 1024) VMEM tile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 1024
SUBLANE = 8
BLOCK = (SUBLANE, LANE)


def _meta_update_kernel(alpha_ref, w_ref, wh_ref, out_ref):
    a = alpha_ref[0]
    w = w_ref[...].astype(jnp.float32)
    wh = wh_ref[...].astype(jnp.float32)
    out_ref[...] = (w + a * (wh - w)).astype(out_ref.dtype)


def meta_update_2d(w2d, wh2d, alpha) -> jax.Array:
    """w2d, wh2d: (R, LANE) with R % SUBLANE == 0."""
    rows = w2d.shape[0]
    grid = (rows // SUBLANE,)
    alpha_arr = jnp.asarray([alpha], jnp.float32)
    return pl.pallas_call(
        _meta_update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(BLOCK, lambda i: (i, 0)),
            pl.BlockSpec(BLOCK, lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec(BLOCK, lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(w2d.shape, w2d.dtype),
        interpret=pltpu_interpret(),
    )(alpha_arr, w2d, wh2d)


def pltpu_interpret() -> bool:
    """TPU targets run compiled; everywhere else interpret=True."""
    return jax.default_backend() != "tpu"
