"""Public jit'd wrappers around the Pallas kernels.

Handle arbitrary parameter pytree leaves by flattening + padding to the
kernel's (rows, LANE) tiling, and restore shape/dtype afterwards.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_decode as _fd
from repro.kernels import meta_update as _mu
from repro.kernels import online_sgd as _sgd

_TILE = _mu.SUBLANE * _mu.LANE


def _to_2d(x):
    flat = x.reshape(-1)
    pad = (-flat.size) % _TILE
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, _mu.LANE), x.shape, x.size


def _from_2d(y2d, shape, size):
    return y2d.reshape(-1)[:size].reshape(shape)


@functools.partial(jax.jit, static_argnums=())
def meta_update(w, w_hat, alpha):
    """Fused Reptile interpolation on one leaf (any shape/dtype)."""
    w2d, shape, size = _to_2d(w)
    wh2d, _, _ = _to_2d(w_hat.astype(w.dtype))
    out = _mu.meta_update_2d(w2d, wh2d, jnp.asarray(alpha, jnp.float32))
    return _from_2d(out, shape, size)


@jax.jit
def online_sgd(p, g, lr):
    p2d, shape, size = _to_2d(p)
    g2d, _, _ = _to_2d(g.astype(p.dtype))
    out = _sgd.online_sgd_2d(p2d, g2d, jnp.asarray(lr, jnp.float32))
    return _from_2d(out, shape, size)


@jax.jit
def online_sgd_momentum(p, g, m, lr, momentum):
    p2d, shape, size = _to_2d(p)
    g2d, _, _ = _to_2d(g.astype(p.dtype))
    m2d, _, _ = _to_2d(m.astype(jnp.float32))
    p_new, m_new = _sgd.online_sgd_momentum_2d(
        p2d, g2d, m2d, jnp.asarray(lr, jnp.float32),
        jnp.asarray(momentum, jnp.float32))
    return _from_2d(p_new, shape, size), _from_2d(m_new, shape, size)


def tree_meta_update(phi, phi_hat, alpha):
    """Reptile interpolation over a whole parameter pytree."""
    return jax.tree.map(lambda w, wh: meta_update(w, wh, alpha),
                        phi, phi_hat)


def tree_online_sgd(params, grads, lr):
    """Fused SGD step over a whole parameter pytree — the serving hot
    path's Pallas route (`serving.Fp32Adapter(use_pallas=True)`)."""
    return jax.tree.map(lambda p, g: online_sgd(p, g, lr), params, grads)


@functools.partial(jax.jit, static_argnames=("window", "block_s"))
def flash_decode(q, k_cache, v_cache, cache_len, *, window=0,
                 block_s=_fd.DEFAULT_BLOCK_S):
    return _fd.flash_decode(q, k_cache, v_cache, cache_len,
                            window=window, block_s=block_s)


def ssd_scan(xd, dA, Bm, Cm):
    from repro.kernels.ssd_scan import ssd_scan as _ssd
    return _ssd(xd, dA, Bm, Cm)


def dfa_epoch_int8(ws, bs, xq, yal, layer, fb, dither, scales):
    """Fused int8 TIFeD epoch (DFA forward + single-layer update).

    Native int8/int32 contract — no (rows, LANE) retiling: the kernel
    takes the paper MLP's tensors as whole-array blocks. The oracle is
    ``ref.dfa_int8_epoch`` (fp32-exact integers, exact-equality tests).
    """
    from repro.kernels.online_sgd_int8 import dfa_epoch_int8 as _dfa
    return _dfa(ws, bs, xq, yal, layer, fb, dither, scales)
