# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# Current kernels: meta_update (Reptile server interpolation),
# online_sgd (streaming finetune), online_sgd_int8 (fused int8 TIFeD
# DFA epoch), flash_decode, ssd_scan. Each has a pure-jnp oracle in
# ref.py and a public wrapper in ops.py.
