"""Fused int8 TIFeD epoch kernel: DFA forward + single-layer update.

One client epoch of TIFeD integer training (arXiv 2307.03102 applied to
the paper's sine MLPs): an int8 forward pass with int32 accumulation,
direct-feedback-alignment error projection through fixed random int8
matrices, and a stochastic-rounding requantized update of the one layer
scheduled this epoch — all in a single kernel invocation, so the whole
local step is one fused VMEM-resident pass with no fp32 weight
round-trips to HBM.

Arithmetic contract: int8 operands, int32 accumulators
(``preferred_element_type``), fp32 only for the power-of-two requant
multipliers (exact scalings) and the loss. The pure-jnp oracle is
``kernels.ref.dfa_int8_epoch`` — it carries the same integers in fp32,
every intermediate stays below 2^24, so the parity tests are
exact-equality on weights/biases, not allclose.

Blocking: the paper models are tiny (a few KB), so each operand is one
whole-array block and the grid is trivial; scalars ride SMEM like
``online_sgd.py``. A large-model variant would tile the hidden axis.
Off-TPU this runs in interpret mode (``pltpu_interpret``), matching the
other kernels; the engine's tifed strategy only routes through it on
TPU and uses the oracle math on CPU, where XLA's fusion is already at
the floor for these shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.meta_update import pltpu_interpret
from repro.kernels.ref import BIAS_MAX, DFA_SHIFT, INT8_MAX

_DN_SAMPLE = (((0,), (0,)), ((), ()))   # contract the sample axis


def _idot(a, b, dims=(((1,), (0,)), ((), ()))):
    return jax.lax.dot_general(a, b, dims,
                               preferred_element_type=jnp.int32)


def _dfa_epoch_kernel(scal_ref, layer_ref, xq_ref, yal_ref,
                      w0_ref, w1_ref, w2_ref, b0_ref, b1_ref, b2_ref,
                      fb1_ref, fb2_ref, d0_ref, d1_ref, d2_ref,
                      ow0_ref, ow1_ref, ow2_ref,
                      ob0_ref, ob1_ref, ob2_ref, loss_ref):
    f32, i32 = jnp.float32, jnp.int32
    f0, f1, fe, floss = (scal_ref[0], scal_ref[1], scal_ref[2], scal_ref[3])
    ftw = (scal_ref[4], scal_ref[5], scal_ref[6])
    ftb = (scal_ref[7], scal_ref[8], scal_ref[9])
    layer = layer_ref[0]

    x = xq_ref[...].astype(i32)
    w0, w1, w2 = (w0_ref[...].astype(i32), w1_ref[...].astype(i32),
                  w2_ref[...].astype(i32))
    b0, b1, b2 = b0_ref[...], b1_ref[...], b2_ref[...]

    # int8 forward, int32 accumulation; activations requantized to uint7
    z0 = (x * w0 if w0.shape[0] == 1 else _idot(x, w0)) + b0
    a1 = jnp.clip(jnp.round(jnp.maximum(z0, 0).astype(f32) * f0),
                  0.0, INT8_MAX).astype(i32)
    z1 = _idot(a1, w1) + b1
    a2 = jnp.clip(jnp.round(jnp.maximum(z1, 0).astype(f32) * f1),
                  0.0, INT8_MAX).astype(i32)
    z2 = _idot(a2, w2) + b2
    err = (z2 - yal_ref[...]).astype(f32)
    eq = jnp.clip(jnp.round(err * fe), -INT8_MAX, INT8_MAX).astype(i32)
    loss_ref[0] = jnp.sum(err * err) * floss

    def proj(fbm_ref):
        # DFA: error hits the hidden layer through a fixed random matrix
        fbm = fbm_ref[...].astype(i32)
        return (eq * fbm if fbm.shape[0] == 1
                else _idot(eq, fbm)).astype(f32)

    def delta(z, fbm_ref):
        d = jnp.round(jnp.where(z > 0, proj(fbm_ref), 0.0)
                      * 2.0 ** -DFA_SHIFT).astype(i32)
        return d

    def grad(a_in, d):
        return ((a_in * d).sum(0, keepdims=True) if a_in.shape[1] == 1
                else _idot(a_in, d, _DN_SAMPLE))

    def wstep(w_ref, g, ftw_i, dith_ref):
        # stochastic rounding: floor(v + u), dither baked by the caller
        wn = (w_ref[...].astype(f32)
              - jnp.floor(g.astype(f32) * ftw_i + dith_ref[...]))
        return jnp.clip(wn, -INT8_MAX, INT8_MAX)

    def bstep(b_ref, dsum, ftb_i):
        bn = b_ref[...].astype(f32) - jnp.round(dsum.astype(f32) * ftb_i)
        return jnp.clip(bn, -BIAS_MAX, BIAS_MAX)

    d0 = delta(z0, fb1_ref)
    d1 = delta(z1, fb2_ref)
    cand = (
        (wstep(w0_ref, grad(x, d0), ftw[0], d0_ref),
         bstep(b0_ref, d0.sum(0), ftb[0])),
        (wstep(w1_ref, grad(a1, d1), ftw[1], d1_ref),
         bstep(b1_ref, d1.sum(0), ftb[1])),
        (wstep(w2_ref, grad(a2, eq), ftw[2], d2_ref),
         bstep(b2_ref, eq.sum(0), ftb[2])),
    )
    # all three candidates are computed; `layer` selects which one lands
    # (the others write back unchanged) — a runtime select keeps the
    # epoch scan at one trace
    for i, (w_ref, b_ref, ow_ref, ob_ref) in enumerate(
            ((w0_ref, b0_ref, ow0_ref, ob0_ref),
             (w1_ref, b1_ref, ow1_ref, ob1_ref),
             (w2_ref, b2_ref, ow2_ref, ob2_ref))):
        ow_ref[...] = jnp.where(layer == i, cand[i][0],
                                w_ref[...].astype(f32)).astype(jnp.int8)
        ob_ref[...] = jnp.where(layer == i, cand[i][1],
                                b_ref[...].astype(f32)).astype(i32)


def dfa_epoch_int8(ws, bs, xq, yal, layer, fb, dither, scales):
    """One TIFeD epoch on native dtypes (contract of ref.dfa_int8_epoch).

    ws: 3-tuple of int8 weights, bs: 3-tuple of int32 biases (at
    accumulator scale), xq: (S, din) int8, yal: (S, dout) int32,
    layer: int32 scalar in {0,1,2}, fb: (fb1, fb2) int8 feedback,
    dither: 3 fp32 U[0,1) planes, scales: the fp32 multiplier dict
    (f0, f1, fe, floss, ftw, ftb). Returns (ws', bs', loss)."""
    scal = jnp.stack([jnp.asarray(s, jnp.float32) for s in
                      (scales["f0"], scales["f1"], scales["fe"],
                       scales["floss"], *scales["ftw"], *scales["ftb"])])
    lay = jnp.asarray(layer, jnp.int32).reshape(1)
    ws = tuple(w.astype(jnp.int8) for w in ws)
    bs = tuple(b.astype(jnp.int32) for b in bs)
    fb = tuple(f.astype(jnp.int8) for f in fb)
    outs = pl.pallas_call(
        _dfa_epoch_kernel,
        in_specs=([pl.BlockSpec(memory_space=pltpu.SMEM)] * 2
                  + [pl.BlockSpec()] * 13),
        out_specs=[pl.BlockSpec()] * 7,
        out_shape=([jax.ShapeDtypeStruct(w.shape, jnp.int8) for w in ws]
                   + [jax.ShapeDtypeStruct(b.shape, jnp.int32) for b in bs]
                   + [jax.ShapeDtypeStruct((1,), jnp.float32)]),
        interpret=pltpu_interpret(),
    )(scal, lay, xq.astype(jnp.int8), yal.astype(jnp.int32),
      *ws, *bs, *fb, *dither)
    return tuple(outs[:3]), tuple(outs[3:6]), outs[6][0]
