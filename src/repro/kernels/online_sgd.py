"""Fused streaming-SGD update kernel: p <- p - lr * g (optional momentum).

TinyReptile's inner loop (Algorithm 1, line 9) performs one SGD update
per arriving sample; at mesh scale this is the K-times-per-round param
sweep. Fusing it keeps the inner loop at one read + one write per
parameter, bf16 storage with fp32 arithmetic — mirroring the paper's
observation that per-sample updates tolerate low precision well when the
accumulation is done carefully.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.meta_update import BLOCK, LANE, SUBLANE, pltpu_interpret


def _sgd_kernel(lr_ref, p_ref, g_ref, out_ref):
    lr = lr_ref[0]
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    out_ref[...] = (p - lr * g).astype(out_ref.dtype)


def _sgd_momentum_kernel(sc_ref, p_ref, g_ref, m_ref, out_p_ref, out_m_ref):
    lr, mu = sc_ref[0], sc_ref[1]
    g = g_ref[...].astype(jnp.float32)
    m_new = mu * m_ref[...] + g
    out_m_ref[...] = m_new
    p = p_ref[...].astype(jnp.float32)
    out_p_ref[...] = (p - lr * m_new).astype(out_p_ref.dtype)


def online_sgd_2d(p2d, g2d, lr) -> jax.Array:
    grid = (p2d.shape[0] // SUBLANE,)
    return pl.pallas_call(
        _sgd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(BLOCK, lambda i: (i, 0)),
            pl.BlockSpec(BLOCK, lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec(BLOCK, lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(p2d.shape, p2d.dtype),
        interpret=pltpu_interpret(),
    )(jnp.asarray([lr], jnp.float32), p2d, g2d)


def online_sgd_momentum_2d(p2d, g2d, m2d, lr, momentum):
    grid = (p2d.shape[0] // SUBLANE,)
    return pl.pallas_call(
        _sgd_momentum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(BLOCK, lambda i: (i, 0)),
            pl.BlockSpec(BLOCK, lambda i: (i, 0)),
            pl.BlockSpec(BLOCK, lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec(BLOCK, lambda i: (i, 0)),
            pl.BlockSpec(BLOCK, lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(p2d.shape, p2d.dtype),
            jax.ShapeDtypeStruct(m2d.shape, jnp.float32),
        ],
        interpret=pltpu_interpret(),
    )(jnp.asarray([lr, momentum], jnp.float32), p2d, g2d, m2d)
