from repro.data.tasks import (KWSTasks, OmniglotTasks, SineTasks,  # noqa: F401
                              TaskDistribution)
from repro.data.lm import (LMClientStream, LmTaskDistribution,  # noqa: F401
                           lm_loss)
